"""L1: Pallas kernels for the FluxAttention attention modes.

All kernels run under interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); each has a pure-jnp oracle in ref.py enforced by pytest.
"""

from .full_attn import full_attention_pallas
from .ssa import ssa_attention_pallas
from .triangle import triangle_attention_pallas
from .xattn import (
    xattn_scores_pallas,
    select_blocks,
    block_sparse_attention_pallas,
    xattn_attention_pallas,
)
from .router_pool import (
    prefill_suffix_pool_pallas,
    router_mlp_pallas,
    prefill_suffix_pool_ref,
    router_mlp_ref,
)
from .decode import fa_decode_pallas, sa_decode_pallas
from . import ref

__all__ = [
    "full_attention_pallas",
    "ssa_attention_pallas",
    "triangle_attention_pallas",
    "xattn_scores_pallas",
    "select_blocks",
    "block_sparse_attention_pallas",
    "xattn_attention_pallas",
    "prefill_suffix_pool_pallas",
    "router_mlp_pallas",
    "prefill_suffix_pool_ref",
    "router_mlp_ref",
    "fa_decode_pallas",
    "sa_decode_pallas",
    "ref",
]
