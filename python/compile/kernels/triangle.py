"""Pallas triangle attention (TA): streaming band + dense last-q rows.

TriangleMix observes that during decoding the contribution of the
"middle" of the prefill attention matrix is negligible except for the
final query rows. TA therefore keeps (a) the sink columns, (b) the local
band, and (c) full attention for the last `last_q` query rows.

Structurally, only query blocks that overlap the last-q region run the
extra middle kv loop; all other blocks execute the same O(sink + local)
schedule as SSA. The middle loop's trip count collapses to zero for
non-dense query blocks, so no HBM traffic is issued for skipped blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

BQ = 64
BK = 64


def _ta_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, sink, local, last_q,
               seq_len):
    h = pl.program_id(0)
    qi = pl.program_id(1)
    d = q_ref.shape[-1]
    q = pl.load(q_ref, (h, pl.ds(qi * bq, bq), slice(None)))
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def body(kj, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        v = pl.load(v_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        s = jnp.dot(q, k.T) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        streaming = (cols < sink) | (rows - cols < local)
        dense = rows >= seq_len - last_q
        visible = (cols <= rows) & (streaming | dense)
        s = jnp.where(visible, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    n_sink_b = -(-sink // bk)
    local_start = jnp.maximum(n_sink_b, (qi * bq - (local - 1)) // bk)
    a = jnp.minimum(n_sink_b, qi + 1)
    b = jnp.maximum(a, jnp.minimum(local_start, qi + 1))
    # does any row of this q block fall in the dense last-q region?
    is_dense = (qi + 1) * bq > seq_len - last_q
    # middle range [a, b) is visited only by dense blocks
    mid_end = jnp.where(is_dense, b, a)

    carry = jax.lax.fori_loop(0, a, body, (m0, l0, acc0))        # sink
    carry = jax.lax.fori_loop(a, mid_end, body, carry)           # middle
    carry = jax.lax.fori_loop(b, qi + 1, body, carry)            # window
    m, l, acc = carry
    out = acc / l[:, None]
    pl.store(o_ref, (h, pl.ds(qi * bq, bq), slice(None)), out)


@functools.partial(jax.jit,
                   static_argnames=("sink", "local", "last_q", "bq", "bk"))
def triangle_attention_pallas(q, k, v, sink: int, local: int, last_q: int,
                              bq: int = BQ, bk: int = BK):
    """Triangle attention. q, k, v: (H, S, D); returns (H, S, D)."""
    h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    return pl.pallas_call(
        functools.partial(_ta_kernel, bq=bq, bk=bk, sink=sink, local=local,
                          last_q=last_q, seq_len=s),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        grid=(h, s // bq),
        interpret=True,
    )(q, k, v)
