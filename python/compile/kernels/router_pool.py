"""Pallas kernels for the Layer Router: boundary pooling + MLP head.

The router (paper section 3.1) receives the incoming query tensor, applies
Prefill-Suffix Pooling over the boundary tokens, passes the pooled
descriptor through a Context Encoder MLP and a Router Head MLP, and emits
unnormalized logits (pi_FA, pi_SA).

Because mean pooling commutes with the linear Q projection
(pool(W x) = W pool(x)), pooling the layer input and letting the Context
Encoder's first matrix absorb W_q is an exact reparameterization of
pooling x_Q itself -- see DESIGN.md section 1. The descriptor is
fixed-shape (2 d_model), which is what makes the router length-invariant
(paper Fig. 9).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, pool, s):
    """Mean of the first `pool` and last `pool` rows of x (s, d)."""
    d = x_ref.shape[-1]
    prefix = pl.load(x_ref, (pl.ds(0, pool), slice(None)))
    suffix = pl.load(x_ref, (pl.ds(s - pool, pool), slice(None)))
    pl.store(o_ref, (pl.ds(0, d),), prefix.mean(axis=0))
    pl.store(o_ref, (pl.ds(d, d),), suffix.mean(axis=0))


@functools.partial(jax.jit, static_argnames=("pool",))
def prefill_suffix_pool_pallas(x, pool: int):
    """x: (S, D) hidden states -> (2D,) descriptor."""
    s, d = x.shape
    pool = min(pool, s)
    return pl.pallas_call(
        functools.partial(_pool_kernel, pool=pool, s=s),
        out_shape=jax.ShapeDtypeStruct((2 * d,), jnp.float32),
        interpret=True,
    )(x)


def _router_kernel(desc_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    desc = desc_ref[...]
    hidden = jax.nn.gelu(desc @ w1_ref[...] + b1_ref[...])
    logits = hidden @ w2_ref[...] + b2_ref[...]
    o_ref[...] = logits


@jax.jit
def router_mlp_pallas(desc, w1, b1, w2, b2):
    """Context Encoder + Router Head. desc: (2D,) -> logits (2,): [SA, FA]."""
    return pl.pallas_call(
        _router_kernel,
        out_shape=jax.ShapeDtypeStruct((w2.shape[-1],), jnp.float32),
        interpret=True,
    )(desc, w1, b1, w2, b2)


# pure-jnp reference (oracle for pytest)

def prefill_suffix_pool_ref(x, pool: int):
    s, d = x.shape
    pool = min(pool, s)
    return jnp.concatenate([x[:pool].mean(axis=0), x[s - pool:].mean(axis=0)])


def router_mlp_ref(desc, w1, b1, w2, b2):
    return jax.nn.gelu(desc @ w1 + b1) @ w2 + b2
