"""Pure-jnp reference oracles for every attention kernel.

These are the ground truth the Pallas kernels (and the rust-executed HLO)
are validated against in python/tests/. They are also used as the fast
training-time implementations in model.py -- the Pallas kernels lower to
the same math under interpret=True, and parity is enforced by pytest.

All prefill functions take (H, S, D) tensors and return (H, S, D).
All decode functions take a single query (H, D) plus a KV buffer.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mask builders (shared by refs, kernel tests and the model)
# ---------------------------------------------------------------------------

def causal_mask(s: int) -> jnp.ndarray:
    """(s, s) bool: True where query i may attend key j (j <= i)."""
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    return j <= i


def ssa_mask(s: int, sink: int, local: int) -> jnp.ndarray:
    """Streaming sparse attention: causal AND (sink cols OR local band).

    Matches StreamingLLM-style attention-sink + sliding-window geometry
    (paper eq. 2 with K~,V~ = sink union window).
    """
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    return (j <= i) & ((j < sink) | (i - j < local))


def triangle_mask(s: int, sink: int, local: int, last_q: int) -> jnp.ndarray:
    """TriangleMix-style: streaming band plus dense last-q rows.

    The bottom `last_q` query rows attend densely (they dominate
    decoding-time contribution); earlier rows use sink+local only.
    """
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    streaming = (j < sink) | (i - j < local)
    dense_rows = i >= (s - last_q)
    return (j <= i) & (streaming | dense_rows)


def xattn_block_scores(q: jnp.ndarray, k: jnp.ndarray, block: int,
                       stride: int) -> jnp.ndarray:
    """Antidiagonal block importance scores (XAttention, scaled).

    For each (q-block, kv-block) pair, sums |q_i . k_j| over strided
    antidiagonal positions of the block -- the antidiagonal crosses every
    row and column of the block, giving a cheap unbiased probe of block
    mass. Returns (H, nb, nb) scores.
    """
    h, s, d = q.shape
    nb = s // block
    scores = jnp.einsum("hid,hjd->hij", q, k) / jnp.sqrt(d)
    scores = jnp.abs(scores).reshape(h, nb, block, nb, block)
    # strided antidiagonal positions (r, (block - 1 - r) % block)
    rows = jnp.arange(0, block, stride)
    cols = (block - 1 - rows) % block
    picked = scores[:, :, rows, :, :]                  # (h, nb, nr, nb, block)
    picked = jnp.take_along_axis(
        picked, cols[None, None, :, None, None], axis=4)  # (h, nb, nr, nb, 1)
    return picked[..., 0].sum(axis=2)                  # (h, nb, nb)


def xattn_block_mask(q: jnp.ndarray, k: jnp.ndarray, block: int, stride: int,
                     keep_ratio: float, sink: int, local: int) -> jnp.ndarray:
    """(s, s) bool mask keeping top-k scored causal kv blocks per q block.

    The diagonal block, the sink blocks and the local band are always
    kept; the remaining budget goes to the highest-scoring blocks. Scores
    are summed over heads -- the mask is shared by all heads of a layer
    (layer-level routing keeps memory access contiguous).
    """
    h, s, d = q.shape
    nb = s // block
    scores = xattn_block_scores(q, k, block, stride).sum(axis=0)  # (nb, nb)
    bi = jnp.arange(nb)[:, None]
    bj = jnp.arange(nb)[None, :]
    causal_b = bj <= bi
    scores = jnp.where(causal_b, scores, NEG_INF)
    keep = max(1, int(nb * keep_ratio))
    thresh = jnp.sort(scores, axis=-1)[:, -keep][:, None]
    selected = (scores >= thresh) & causal_b
    # always-on structural blocks: sink blocks, diagonal, local band
    sink_b = bj < max(1, sink // block)
    local_b = (bi - bj) < max(1, local // block)
    selected = selected | ((sink_b | local_b) & causal_b)
    # expand block mask to token mask, then AND with token-level causality
    tok = jnp.repeat(jnp.repeat(selected, block, axis=0), block, axis=1)
    return tok & causal_mask(s)


# ---------------------------------------------------------------------------
# prefill attention references
# ---------------------------------------------------------------------------

def _masked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      mask: jnp.ndarray) -> jnp.ndarray:
    d = q.shape[-1]
    scores = jnp.einsum("hid,hjd->hij", q, k) / jnp.sqrt(d)
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hij,hjd->hid", probs, v)


def full_attention(q, k, v):
    """Causal full attention (paper eq. 1)."""
    return _masked_attention(q, k, v, causal_mask(q.shape[1]))


def ssa_attention(q, k, v, sink: int, local: int):
    """Streaming sparse attention (paper eq. 2, SSA mode)."""
    return _masked_attention(q, k, v, ssa_mask(q.shape[1], sink, local))


def triangle_attention(q, k, v, sink: int, local: int, last_q: int):
    """Triangle attention (TA mode)."""
    return _masked_attention(
        q, k, v, triangle_mask(q.shape[1], sink, local, last_q))


def xattn_attention(q, k, v, block: int, stride: int, keep_ratio: float,
                    sink: int, local: int):
    """XAttention block-sparse attention (XA mode)."""
    mask = xattn_block_mask(q, k, block, stride, keep_ratio, sink, local)
    return _masked_attention(q, k, v, mask)


# ---------------------------------------------------------------------------
# decode-step references (single query token)
# ---------------------------------------------------------------------------

def fa_decode(q, k_cache, v_cache, valid_len):
    """Full-KV decode: q (H, D); caches (H, Kmax, D); mask j < valid_len."""
    h, kmax, d = k_cache.shape
    scores = jnp.einsum("hd,hjd->hj", q, k_cache) / jnp.sqrt(d)
    valid = jnp.arange(kmax)[None, :] < valid_len
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hj,hjd->hd", probs, v_cache)


def sa_decode(q, k_buf, v_buf, valid_len):
    """Sparse decode over the sink+local ring buffer (same math, small K).

    The buffer layout (sink tokens first, then the local window) is
    managed by the rust KV-cache; numerically the kernel is
    position-agnostic given RoPE was applied at append time.
    """
    return fa_decode(q, k_buf, v_buf, valid_len)
