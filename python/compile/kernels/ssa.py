"""Pallas streaming sparse attention (SSA): attention sink + local window.

This is the paper's SA prefill mode (eq. 2) with K~,V~ = the sink tokens
plus a sliding local window (StreamingLLM geometry, scaled per DESIGN.md).

The efficiency claim is structural: per query block the kernel visits
only (a) the sink kv blocks and (b) the kv blocks intersecting the local
window -- two disjoint fori_loops whose combined trip count is
O(sink + local), independent of sequence length. Blocks outside
sink union window are never loaded from HBM, which is exactly how
layer-level sparsity turns bandwidth savings into wall-clock savings.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

BQ = 64
BK = 64


def _make_block_body(q, k_ref, v_ref, h, qi, *, bq, bk, sink, local, scale):
    """Shared streaming-softmax block step with the exact SSA mask."""

    def body(kj, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        v = pl.load(v_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        s = jnp.dot(q, k.T) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        visible = (cols <= rows) & ((cols < sink) | (rows - cols < local))
        s = jnp.where(visible, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # fully-masked blocks contribute exp(NEG_INF - m) = 0 -- exact
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc

    return body


def _ssa_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, sink, local):
    h = pl.program_id(0)
    qi = pl.program_id(1)
    d = q_ref.shape[-1]
    q = pl.load(q_ref, (h, pl.ds(qi * bq, bq), slice(None)))
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    body = _make_block_body(q, k_ref, v_ref, h, qi,
                            bq=bq, bk=bk, sink=sink, local=local, scale=scale)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    n_sink_b = -(-sink // bk)  # ceil: blocks that contain any sink column
    # the local window of the *last* row of this q block reaches back
    # `local` tokens; the first kv block any row of the block can see
    # through the window is:
    local_start = jnp.maximum(n_sink_b, (qi * bq - (local - 1)) // bk)

    # disjoint ranges: sink blocks [0, a), window blocks [max(a, ls), qi+1)
    a = jnp.minimum(n_sink_b, qi + 1)
    carry = jax.lax.fori_loop(0, a, body, (m0, l0, acc0))
    carry = jax.lax.fori_loop(jnp.maximum(a, local_start), qi + 1, body, carry)
    m, l, acc = carry
    out = acc / l[:, None]
    pl.store(o_ref, (h, pl.ds(qi * bq, bq), slice(None)), out)


@functools.partial(jax.jit, static_argnames=("sink", "local", "bq", "bk"))
def ssa_attention_pallas(q, k, v, sink: int, local: int,
                         bq: int = BQ, bk: int = BK):
    """Streaming sparse attention. q, k, v: (H, S, D); returns (H, S, D)."""
    h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    return pl.pallas_call(
        functools.partial(_ssa_kernel, bq=bq, bk=bk, sink=sink, local=local),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        grid=(h, s // bq),
        interpret=True,
    )(q, k, v)
