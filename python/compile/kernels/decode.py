"""Pallas single-token decode attention kernels.

`fa_decode_pallas` attends one query against a bucketed full KV cache with
a valid-length mask -- the memory-bandwidth-bound op the paper's decode
analysis (section 2.3, Fig 1b) is about: latency is proportional to the KV
bytes streamed.

`sa_decode_pallas` is the same math over the fixed-size sink+local ring
buffer; its cost is constant in context length, which is where the
layer-level sparse-decode speedup comes from (the full historical KV for
routed-sparse layers is never touched, or even retained, after prefill).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

BK = 64


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, bk, kmax):
    h = pl.program_id(0)
    d = q_ref.shape[-1]
    q = pl.load(q_ref, (h, slice(None)))  # (d,)
    valid_len = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def body(kj, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        v = pl.load(v_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        s = (k @ q) * scale  # (bk,)
        cols = kj * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.where(cols < valid_len, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum()
        acc = acc * alpha + p @ v
        return m_new, l_new, acc

    # stream only the blocks containing valid entries
    n_blocks = (valid_len + bk - 1) // bk
    m0 = jnp.asarray(NEG_INF, jnp.float32)
    l0 = jnp.asarray(0.0, jnp.float32)
    acc0 = jnp.zeros((d,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    pl.store(o_ref, (h, slice(None)), acc / l)


@functools.partial(jax.jit, static_argnames=("bk",))
def fa_decode_pallas(q, k_cache, v_cache, valid_len, bk: int = BK):
    """q: (H, D); caches: (H, Kmax, D); valid_len: (1,) i32 -> (H, D)."""
    h, kmax, d = k_cache.shape
    bk = min(bk, kmax)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, kmax=kmax),
        out_shape=jax.ShapeDtypeStruct((h, d), jnp.float32),
        grid=(h,),
        interpret=True,
    )(q, k_cache, v_cache, valid_len)


def sa_decode_pallas(q, k_buf, v_buf, valid_len, bk: int = 32):
    """Sparse decode over the sink+local buffer (fixed small Kmax)."""
    return fa_decode_pallas(q, k_buf, v_buf, valid_len, bk=bk)
