"""Pallas causal full-attention kernel (flash-style online softmax).

Hardware adaptation (DESIGN.md section 3): the CUDA threadblock tiling of
FlashAttention becomes a Pallas grid over (head, query-block); each grid
step streams KV blocks HBM->VMEM with `pl.load` + `pl.ds` and carries the
streaming (max, sum, acc) softmax state across blocks. The kv loop upper
bound is `qi + 1`, so blocks strictly above the causal diagonal are never
loaded -- the TPU analogue of never issuing those HBM transactions.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

BQ = 64  # query block rows   (MXU-aligned at 2x the 32-lane half tile)
BK = 64  # key/value block columns


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int):
    h = pl.program_id(0)
    qi = pl.program_id(1)
    d = q_ref.shape[-1]

    q = pl.load(q_ref, (h, pl.ds(qi * bq, bq), slice(None)))  # (bq, d)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def body(kj, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (h, pl.ds(kj * bk, bk), slice(None)))  # (bk, d)
        v = pl.load(v_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        s = jnp.dot(q, k.T) * scale  # (bq, bk)
        # exact elementwise causal mask (only the diagonal block needs it,
        # but computing it unconditionally keeps the body branch-free)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
        # streaming softmax update
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    # causal bound: kv blocks after the diagonal are never visited
    m, l, acc = jax.lax.fori_loop(0, qi + 1, body, (m0, l0, acc0))
    out = acc / l[:, None]
    pl.store(o_ref, (h, pl.ds(qi * bq, bq), slice(None)), out)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def full_attention_pallas(q, k, v, bq: int = BQ, bk: int = BK):
    """Causal full attention. q, k, v: (H, S, D) f32; returns (H, S, D)."""
    h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    return pl.pallas_call(
        functools.partial(_fa_kernel, bq=bq, bk=bk),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        grid=(h, s // bq),
        interpret=True,
    )(q, k, v)
