"""Pallas XAttention (XA): antidiagonal block scoring + block-sparse attn.

Two-stage pipeline, following XAttention (Xu et al., ICML'25), scaled per
DESIGN.md:

  1. `xattn_scores_pallas` -- a cheap probe kernel that estimates each
     (q-block, kv-block) importance by summing |q_r . k_c| over strided
     antidiagonal positions. The antidiagonal crosses every row and
     column of a block, so the probe touches 1/stride of the block's
     rows while remaining sensitive to any hot row/column.
  2. top-k selection over the scores (plain jnp inside the same jitted
     L2 function) producing a per-q-block kv-block mask; the structural
     sink/local/diagonal blocks are always kept.
  3. `block_sparse_attention_pallas` -- consumes the block mask; its kv
     loop wraps the block step in `lax.cond`, so deselected blocks are
     genuinely skipped at runtime (no score compute, no HBM loads).

Parity contract (pytest): stage 1 matches ref.xattn_block_scores; the
composed pipeline matches ref.xattn_attention exactly, because both use
the same selection rule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

BQ = 64
BK = 64


# ---------------------------------------------------------------------------
# stage 1: antidiagonal probe scores
# ---------------------------------------------------------------------------

def _score_kernel(q_ref, k_ref, o_ref, *, block, stride, nb):
    """Grid (nb,): scores for one q block row against all kv blocks."""
    qi = pl.program_id(0)
    h, s, d = q_ref.shape
    nr = (block + stride - 1) // stride
    rows = jax.lax.iota(jnp.int32, nr) * stride
    cols = (block - 1 - rows) % block
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def body(kj, scores):
        acc = jnp.zeros((), jnp.float32)

        def head_body(hh, acc):
            # strided q rows of this block and the matching k columns
            qs = pl.load(q_ref, (hh, pl.ds(qi * block, block), slice(None)))
            ks = pl.load(k_ref, (hh, pl.ds(kj * block, block), slice(None)))
            qr = qs[rows]            # (nr, d)
            kc = ks[cols]            # (nr, d)
            dots = jnp.abs(jnp.sum(qr * kc, axis=-1) * scale)
            return acc + dots.sum()

        acc = jax.lax.fori_loop(0, h, head_body, acc)
        return scores.at[kj].set(acc)

    scores = jax.lax.fori_loop(0, nb, body, jnp.zeros((nb,), jnp.float32))
    pl.store(o_ref, (qi, slice(None)), scores)


@functools.partial(jax.jit, static_argnames=("block", "stride"))
def xattn_scores_pallas(q, k, block: int, stride: int):
    """Head-summed block scores. q, k: (H, S, D); returns (nb, nb)."""
    h, s, d = q.shape
    nb = s // block
    return pl.pallas_call(
        functools.partial(_score_kernel, block=block, stride=stride, nb=nb),
        out_shape=jax.ShapeDtypeStruct((nb, nb), jnp.float32),
        grid=(nb,),
        interpret=True,
    )(q, k)


# ---------------------------------------------------------------------------
# stage 2: selection (shared with ref -- same rule, so parity is exact)
# ---------------------------------------------------------------------------

def select_blocks(scores, block: int, keep_ratio: float, sink: int,
                  local: int):
    """Top-k + structural block mask from (nb, nb) scores."""
    nb = scores.shape[0]
    bi = jnp.arange(nb)[:, None]
    bj = jnp.arange(nb)[None, :]
    causal_b = bj <= bi
    scores = jnp.where(causal_b, scores, NEG_INF)
    keep = max(1, int(nb * keep_ratio))
    thresh = jnp.sort(scores, axis=-1)[:, -keep][:, None]
    selected = (scores >= thresh) & causal_b
    sink_b = bj < max(1, sink // block)
    local_b = (bi - bj) < max(1, local // block)
    return selected | ((sink_b | local_b) & causal_b)


# ---------------------------------------------------------------------------
# stage 3: block-sparse attention over the selected blocks
# ---------------------------------------------------------------------------

def _bs_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, bq, bk, blocks_per_q):
    h = pl.program_id(0)
    qi = pl.program_id(1)
    d = q_ref.shape[-1]
    q = pl.load(q_ref, (h, pl.ds(qi * bq, bq), slice(None)))
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def compute(kj, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        v = pl.load(v_ref, (h, pl.ds(kj * bk, bk), slice(None)))
        s = jnp.dot(q, k.T) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc

    def body(j, carry):
        # kv blocks per q block: block-mask granularity is `bk`-aligned
        # because select_blocks ran at kernel block size (see wrapper).
        keep = pl.load(mask_ref, (qi * blocks_per_q, j))
        return jax.lax.cond(keep, lambda c: compute(j, c), lambda c: c, carry)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, qi + 1, body, (m0, l0, acc0))
    out = acc / l[:, None]
    pl.store(o_ref, (h, pl.ds(qi * bq, bq), slice(None)), out)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def block_sparse_attention_pallas(q, k, v, block_mask, bq: int = BQ,
                                  bk: int = BK):
    """Block-sparse attention. block_mask: (S//bk, S//bk) bool, kernel-block
    aligned (every kernel kv block is uniformly kept or skipped)."""
    h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    return pl.pallas_call(
        functools.partial(_bs_kernel, bq=bq, bk=bk, blocks_per_q=1),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        grid=(h, s // bq),
        interpret=True,
    )(q, k, v, block_mask)


def coarsen_mask(fine_mask, fine_block: int, coarse_block: int):
    """OR-reduce a fine (nb_f, nb_f) block mask to kernel granularity.

    Selection runs at the paper's block size (16); the attention kernel
    tiles at 64 for MXU alignment. A coarse block is kept iff any fine
    block inside it is kept; exact per-fine-block masking is then applied
    elementwise (handled by the wrapper below re-running the fine mask).
    """
    r = coarse_block // fine_block
    nbf = fine_mask.shape[0]
    nbc = nbf // r
    m = fine_mask.reshape(nbc, r, nbc, r)
    return m.any(axis=(1, 3))


def xattn_attention_pallas(q, k, v, block: int, stride: int,
                           keep_ratio: float, sink: int, local: int):
    """Composed XA pipeline at selection granularity == kernel granularity.

    Runs the kernel with bq = bk = `block` so that the fine-grained
    selection mask is applied exactly (parity with ref.xattn_attention).
    """
    scores = xattn_scores_pallas(q, k, block, stride)
    mask = select_blocks(scores, block, keep_ratio, sink, local)
    return block_sparse_attention_pallas(q, k, v, mask, bq=block, bk=block)
