"""AOT export: lower every executable variant to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (/opt/xla-example/README.md).

Lowering recipe (mirrors /opt/xla-example/gen_hlo.py):

    lowered = jax.jit(fn).lower(*specs)
    mlir    = lowered.compiler_ir("stablehlo")
    comp    = xla_client._xla.mlir.mlir_module_to_xla_computation(
                  str(mlir), use_tuple_args=False, return_tuple=True)
    text    = comp.as_hlo_text()

Everything is lowered with return_tuple=True; the rust runtime unwraps
with `to_tuple1()`/tuple indexing.

Also exports the trained weights as a raw f32 blob + JSON manifest
(weights.bin / weights.json) and the full model/runtime configuration
(model_meta.json) for the rust loader.

Usage: python -m compile.aot [--out-dir ../artifacts] [--skip-weights]
"""

import argparse
import functools
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import (MODEL, SPARSITY, ROUTER, PREFILL_BUCKETS,
                     DECODE_KV_BUCKETS, dump_meta)
from . import model as M

# sparse-decode ring buffer size: sink + local + current, rounded up to
# the decode kernel block (64)
SA_BUF = ((SPARSITY.sa_decode_window + 63) // 64) * 64  # 192


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def executable_specs():
    """Every (name, fn, specs) triple to lower. See DESIGN.md section 1."""
    d, ff, h, dd, v = (MODEL.d_model, MODEL.d_ff, MODEL.n_heads,
                       MODEL.head_dim, MODEL.vocab_size)
    rh = ROUTER.d_hidden
    layer_w = [f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d), f32(d),
               f32(d, ff), f32(ff, d)]
    out = []
    for s in PREFILL_BUCKETS:
        for mode in M.MODES:
            out.append((
                f"layer_{mode}_prefill_{s}",
                functools.partial(M.prefill_layer_step, mode),
                [f32(s, d)] + layer_w,
            ))
    out.append(("decode_qkv",
                M.decode_qkv_step,
                [f32(d), i32(1), f32(d), f32(d, d), f32(d, d), f32(d, d)]))
    for k in DECODE_KV_BUCKETS:
        out.append((
            f"decode_attend_fa_{k}",
            M.decode_attend_step,
            [f32(d), f32(h, dd), f32(h, k, dd), f32(h, k, dd), i32(1),
             f32(d, d), f32(d), f32(d, ff), f32(ff, d)],
        ))
    out.append((
        "decode_attend_sa",
        M.decode_attend_step,
        [f32(d), f32(h, dd), f32(h, SA_BUF, dd), f32(h, SA_BUF, dd), i32(1),
         f32(d, d), f32(d), f32(d, ff), f32(ff, d)],
    ))
    out.append(("router",
                M.router_step,
                [f32(2 * d), f32(2 * d, rh), f32(rh), f32(rh, 2), f32(2)]))
    out.append(("lm_head", M.lm_head_step, [f32(d), f32(d), f32(d, v)]))
    return out


def export_weights(out_dir):
    """model.npz + router_*.npz -> raw f32 blob(s) + manifest for rust."""
    from .train import export_flat_bin
    exported = []
    model_npz = os.path.join(out_dir, "model.npz")
    if os.path.exists(model_npz):
        d = dict(np.load(model_npz))
        export_flat_bin(d, os.path.join(out_dir, "weights.bin"),
                        os.path.join(out_dir, "weights.json"))
        exported.append("weights.bin")
    for fn in sorted(os.listdir(out_dir)):
        if fn.startswith("router_") and fn.endswith(".npz"):
            name = fn[:-4]
            d = dict(np.load(os.path.join(out_dir, fn)))
            export_flat_bin(d, os.path.join(out_dir, f"{name}.bin"),
                            os.path.join(out_dir, f"{name}.json"))
            exported.append(f"{name}.bin")
    cont = os.path.join(out_dir, "model_continued.npz")
    if os.path.exists(cont):
        d = dict(np.load(cont))
        export_flat_bin(d, os.path.join(out_dir, "weights_continued.bin"),
                        os.path.join(out_dir, "weights_continued.json"))
        exported.append("weights_continued.bin")
    return exported


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--skip-weights", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated exe-name substrings to lower")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"executables": [], "weights": []}
    for name, fn, specs in executable_specs():
        if args.only and not any(p in name for p in args.only.split(",")):
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        text = to_hlo_text(fn, *specs)
        with open(path, "w") as f:
            f.write(text)
        manifest["executables"].append(name)
        print(f"lowered {name}: {len(text)} chars ({time.time()-t0:.1f}s)",
              flush=True)

    if not args.skip_weights:
        manifest["weights"] = export_weights(args.out_dir)

    dump_meta(os.path.join(args.out_dir, "model_meta.json"))
    # extend meta with runtime constants the rust side needs
    meta_path = os.path.join(args.out_dir, "model_meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["sa_buf"] = SA_BUF
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)

    # never clobber a fuller manifest with a partial/weights-only run:
    # the executable list is always recovered from the directory contents
    manifest["executables"] = sorted(
        f[:-len(".hlo.txt")] for f in os.listdir(args.out_dir)
        if f.endswith(".hlo.txt"))
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['executables'])} executables")


if __name__ == "__main__":
    main()
