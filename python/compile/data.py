"""Synthetic long-context task suite (training-time mirror of rust workload/).

Thirteen LongBench-E proxy tasks across the paper's six categories, plus
the RULER needle ladder and the reasoning/math proxies used in Table 2.
Each task is engineered to sit in the same *sparsity-sensitivity class*
as its LongBench counterpart (DESIGN.md section 2):

  retrieval-intensive  -- the answer depends on an exact lookup of a
      token placed at an arbitrary (often deep) position; truncating
      historical KV destroys it.
  context-holistic     -- the answer is recoverable from coarse local
      statistics (majority markers, repeated ICL mappings, local code
      patterns); a sink+window view suffices.

Token map (vocab 512):
  0 PAD  1 BOS  2 EOS  3 SEP  4 QUERY  5 ANSWER  6..31 task tags
  32..511 content tokens.

The rust `workload` module reimplements exactly these generators (same
layout, same seeds via SplitMix64 -> independent streams; parity is not
required across languages, only distributional equivalence).
"""

import numpy as np

PAD, BOS, EOS, SEP, QUERY, ANSWER = 0, 1, 2, 3, 4, 5
TAG_BASE = 6
CONTENT = 32
VOCAB = 512
NCONTENT = VOCAB - CONTENT  # 480

RETRIEVAL_TASKS = ("qasper", "mfen", "hotqa", "wiki2", "pcount", "pre")
HOLISTIC_TASKS = ("gov", "mnews", "trec", "tqa", "sams", "rbp", "lcc")
TASKS = RETRIEVAL_TASKS + HOLISTIC_TASKS

CATEGORY = {  # LongBench-E category per task
    "qasper": "sdocqa", "mfen": "sdocqa",
    "hotqa": "mdocqa", "wiki2": "mdocqa",
    "gov": "summ", "mnews": "summ",
    "trec": "icl", "tqa": "icl", "sams": "icl",
    "pcount": "synthetic", "pre": "synthetic",
    "rbp": "code", "lcc": "code",
}

TAG = {t: TAG_BASE + i for i, t in enumerate(
    TASKS + ("ruler", "lbv2e", "lbv2h", "gsm", "aime"))}


def _tok(i):
    return CONTENT + int(i) % NCONTENT


def _filler(rng, n):
    return rng.integers(CONTENT, VOCAB, size=n).tolist()


class Sample(dict):
    """tokens (exact length), answer span, category flags."""

    def __init__(self, tokens, ans_start, ans_len, task):
        super().__init__(tokens=np.asarray(tokens, np.int32),
                         ans_start=ans_start, ans_len=ans_len, task=task,
                         retrieval=task not in HOLISTIC_TASKS)


def _assemble(task, rng, seq_len, ctx_builder, query, answer):
    """[BOS TAG ctx... QUERY q... ANSWER a... EOS], sized to seq_len."""
    overhead = 2 + 1 + len(query) + 1 + len(answer) + 1
    ctx = ctx_builder(seq_len - overhead)
    toks = ([BOS, TAG[task]] + ctx + [QUERY] + query + [ANSWER]
            + answer + [EOS])
    assert len(toks) == seq_len, (task, len(toks), seq_len)
    ans_start = 2 + len(ctx) + 1 + len(query) + 1
    return Sample(toks, ans_start, len(answer), task)


def _scatter(rng, n, items):
    """Spread token groups across n filler slots; returns token list of
    exactly n tokens with each group inserted at a distinct depth."""
    out = _filler(rng, n)
    total = sum(len(it) for it in items)
    assert total <= n
    # non-overlapping random offsets
    free = n - total
    gaps = rng.multinomial(free, np.ones(len(items) + 1) / (len(items) + 1))
    pos = 0
    cursor = 0
    for gap, it in zip(gaps[:-1], items):
        cursor += gap
        out[cursor:cursor + len(it)] = it
        cursor += len(it)
    return out[:n]


# --------------------------- retrieval-intensive ---------------------------

def gen_qasper(rng, seq_len):
    """Single-doc QA: facts (SEP key val), query one key."""
    nfacts = max(2, min(16, seq_len // 48))
    keys = rng.choice(NCONTENT, nfacts, replace=False)
    vals = rng.integers(0, NCONTENT, nfacts)
    facts = [[SEP, _tok(k), _tok(v)] for k, v in zip(keys, vals)]
    t = rng.integers(nfacts)
    return _assemble("qasper", rng, seq_len,
                     lambda n: _scatter(rng, n, facts),
                     [_tok(keys[t])], [_tok(vals[t])])


def gen_mfen(rng, seq_len):
    """Multi-field QA: (SEP entity field value); query entity+field."""
    nent = max(2, min(10, seq_len // 64))
    ents = rng.choice(NCONTENT // 2, nent, replace=False)
    f1 = rng.integers(0, NCONTENT, nent)
    f2 = rng.integers(0, NCONTENT, nent)
    fields = [NCONTENT // 2, NCONTENT // 2 + 1]  # two field tags
    facts = []
    for e, a, b in zip(ents, f1, f2):
        facts.append([SEP, _tok(e), _tok(fields[0]), _tok(a)])
        facts.append([SEP, _tok(e), _tok(fields[1]), _tok(b)])
    t = rng.integers(nent)
    fsel = rng.integers(2)
    val = (f1 if fsel == 0 else f2)[t]
    return _assemble("mfen", rng, seq_len,
                     lambda n: _scatter(rng, n, facts),
                     [_tok(ents[t]), _tok(fields[fsel])], [_tok(val)])


def gen_hotqa(rng, seq_len):
    """2-hop: (A -> B), (B -> C); query A, answer C."""
    nchains = max(2, min(8, seq_len // 96))
    a = rng.choice(NCONTENT // 3, nchains, replace=False)
    b = rng.choice(NCONTENT // 3, nchains, replace=False) + NCONTENT // 3
    c = rng.integers(0, NCONTENT, nchains)
    hops = []
    for i in range(nchains):
        hops.append([SEP, _tok(a[i]), _tok(b[i])])
        hops.append([SEP, _tok(b[i]), _tok(c[i])])
    t = rng.integers(nchains)
    return _assemble("hotqa", rng, seq_len,
                     lambda n: _scatter(rng, n, hops),
                     [_tok(a[t])], [_tok(c[t])])


def gen_wiki2(rng, seq_len):
    """3-hop chain resolution."""
    nchains = max(2, min(6, seq_len // 128))
    base = NCONTENT // 4
    a = rng.choice(base, nchains, replace=False)
    b = rng.choice(base, nchains, replace=False) + base
    c = rng.choice(base, nchains, replace=False) + 2 * base
    d = rng.integers(0, NCONTENT, nchains)
    hops = []
    for i in range(nchains):
        hops += [[SEP, _tok(a[i]), _tok(b[i])],
                 [SEP, _tok(b[i]), _tok(c[i])],
                 [SEP, _tok(c[i]), _tok(d[i])]]
    t = rng.integers(nchains)
    return _assemble("wiki2", rng, seq_len,
                     lambda n: _scatter(rng, n, hops),
                     [_tok(a[t])], [_tok(d[t])])


def gen_pcount(rng, seq_len):
    """Count marker occurrences (mod 32). Globally hard for everyone."""
    marker = _tok(rng.integers(NCONTENT))
    count = int(rng.integers(1, 24))

    def build(n):
        return _scatter(rng, n, [[marker]] * count)

    return _assemble("pcount", rng, seq_len, build, [marker],
                     [_tok(count)])


def gen_pre(rng, seq_len):
    """Passage retrieval / passkey at a uniform random depth."""
    key = _tok(rng.integers(NCONTENT))
    val = _tok(rng.integers(NCONTENT))

    def build(n):
        out = _filler(rng, n)
        pos = int(rng.integers(0, max(1, n - 3)))
        out[pos:pos + 3] = [SEP, key, val]
        return out[:n]

    return _assemble("pre", rng, seq_len, build, [key], [val])


# ----------------------------- context-holistic ----------------------------

def gen_gov(rng, seq_len):
    """Majority topic marker: (SEP topic) markers; majority ~ 60%."""
    topics = rng.choice(NCONTENT, 3, replace=False)
    nmark = max(6, seq_len // 24)
    probs = np.array([0.6, 0.25, 0.15])
    draws = rng.choice(3, nmark, p=probs)
    marks = [[SEP, _tok(topics[i])] for i in draws]
    maj = topics[np.bincount(draws, minlength=3).argmax()]
    return _assemble("gov", rng, seq_len,
                     lambda n: _scatter(rng, n, marks),
                     [SEP], [_tok(maj)])


def gen_mnews(rng, seq_len):
    """Most frequent headline token after QUERY-marker sentences."""
    heads = rng.choice(NCONTENT, 4, replace=False)
    nsent = max(6, seq_len // 32)
    probs = np.array([0.55, 0.2, 0.15, 0.1])
    draws = rng.choice(4, nsent, p=probs)
    sents = [[SEP, _tok(heads[i]), *_filler(rng, 2)] for i in draws]
    maj = heads[np.bincount(draws, minlength=4).argmax()]
    return _assemble("mnews", rng, seq_len,
                     lambda n: _scatter(rng, n, sents),
                     [SEP, SEP], [_tok(maj)])


def _icl_task(name, rng, seq_len, npat):
    """Repeated pattern->label pairs; query pattern appears densely, so a
    recent in-window example always exists (holistic-robust)."""
    pats = rng.choice(NCONTENT // 2, npat, replace=False)
    labels = rng.choice(NCONTENT // 2, npat, replace=False) + NCONTENT // 2
    t = rng.integers(npat)

    def build(n):
        out = []
        while len(out) + 3 <= n:
            i = rng.integers(npat) if rng.random() > 0.3 else t
            out += [SEP, _tok(pats[i]), _tok(labels[i])]
        out += _filler(rng, n - len(out))
        return out[:n]

    return _assemble(name, rng, seq_len, build, [_tok(pats[t])],
                     [_tok(labels[t])])


def gen_trec(rng, seq_len):
    return _icl_task("trec", rng, seq_len, 6)


def gen_tqa(rng, seq_len):
    return _icl_task("tqa", rng, seq_len, 10)


def gen_sams(rng, seq_len):
    """Dominant-speaker summarization over dialogue turns."""
    speakers = rng.choice(NCONTENT, 3, replace=False)
    probs = np.array([0.55, 0.25, 0.2])
    nturn = max(6, seq_len // 24)
    draws = rng.choice(3, nturn, p=probs)
    turns = [[SEP, _tok(speakers[i]), *_filler(rng, 3)] for i in draws]
    maj = speakers[np.bincount(draws, minlength=3).argmax()]
    return _assemble("sams", rng, seq_len,
                     lambda n: _scatter(rng, n, turns),
                     [SEP, QUERY], [_tok(maj)])


def gen_rbp(rng, seq_len):
    """Repo-level next-line prediction: line_{i+1}[0] = line_i[0] + step.
    Purely local pattern continuation."""
    step = int(rng.integers(1, 7))
    start = int(rng.integers(NCONTENT))
    width = 4
    n_ctx = seq_len - 7  # overhead of [BOS TAG ... QUERY q ANSWER a EOS]
    nlines = n_ctx // (width + 1)
    out = []
    for i in range(nlines):
        out += [SEP, _tok(start + i * step), *_filler(rng, width - 1)]
    out += [SEP] * (n_ctx - len(out))
    next_tok = _tok(start + nlines * step)
    return _assemble("rbp", rng, seq_len, lambda n: out[:n], [SEP],
                     [next_tok])


def gen_lcc(rng, seq_len):
    """Local code completion: repeating k-period token sequence; answer
    is the continuation of the period."""
    period = int(rng.integers(3, 8))
    motif = [_tok(x) for x in rng.integers(0, NCONTENT, period)]
    n_ctx = seq_len - 7
    out = (motif * (n_ctx // period + 1))[:n_ctx]
    next_tok = motif[n_ctx % period]
    return _assemble("lcc", rng, seq_len, lambda n: out[:n], [SEP],
                     [next_tok])


# -------------------- Table-2 proxies (RULER / LB-v2 / math) ---------------

def gen_ruler(rng, seq_len):
    """RULER needle ladder: passkey at controlled depth (== pre)."""
    s = gen_pre(rng, seq_len)
    s["task"] = "ruler"
    return s


def _chain_task(name, rng, seq_len, hops):
    """k-hop variable resolution with distractor chains (LongBench-v2)."""
    nchains = 4
    per = NCONTENT // (hops + 1)
    chains = []
    finals = []
    heads = rng.choice(per, nchains, replace=False)
    for ci in range(nchains):
        cur = heads[ci]
        toks = []
        for hp in range(hops):
            nxt = int(rng.integers(per)) + (hp + 1) * per
            toks.append([SEP, _tok(cur), _tok(nxt)])
            cur = nxt
        chains += toks
        finals.append(cur)
    t = rng.integers(nchains)
    return _assemble(name, rng, seq_len,
                     lambda n: _scatter(rng, n, chains),
                     [_tok(heads[t])], [_tok(finals[t])])


def gen_lbv2_easy(rng, seq_len):
    return _chain_task("lbv2e", rng, seq_len, hops=2)


def gen_lbv2_hard(rng, seq_len):
    return _chain_task("lbv2h", rng, seq_len, hops=4)


def _arith_task(name, rng, seq_len, ops, mul):
    """Chained modular arithmetic: running value over ops steps, mod 97.

    Sequence [SEP op operand] triples in order; answer = final value.
    Requires carrying state across the whole chain (reasoning proxy).
    """
    mod = 97
    val = int(rng.integers(mod))
    triples = [[SEP, QUERY, _tok(val)]]  # initial value statement
    for _ in range(ops):
        x = int(rng.integers(1, 10))
        if mul and rng.random() < 0.3:
            val = (val * x) % mod
            triples.append([SEP, _tok(NCONTENT - 2), _tok(x)])
        else:
            val = (val + x) % mod
            triples.append([SEP, _tok(NCONTENT - 1), _tok(x)])

    def build(n):
        flat = [t for tr in triples for t in tr]
        return (flat + _filler(rng, n))[:n] if len(flat) <= n else flat[:n]

    return _assemble(name, rng, seq_len, build, [SEP], [_tok(val)])


def gen_gsm(rng, seq_len):
    return _arith_task("gsm", rng, seq_len, ops=6, mul=False)


def gen_aime(rng, seq_len):
    return _arith_task("aime", rng, seq_len, ops=10, mul=True)


GENERATORS = {
    "qasper": gen_qasper, "mfen": gen_mfen, "hotqa": gen_hotqa,
    "wiki2": gen_wiki2, "gov": gen_gov, "mnews": gen_mnews,
    "trec": gen_trec, "tqa": gen_tqa, "sams": gen_sams,
    "pcount": gen_pcount, "pre": gen_pre, "rbp": gen_rbp, "lcc": gen_lcc,
    "ruler": gen_ruler, "lbv2e": gen_lbv2_easy, "lbv2h": gen_lbv2_hard,
    "gsm": gen_gsm, "aime": gen_aime,
}

RETRIEVAL_SET = set(RETRIEVAL_TASKS) | {"ruler", "lbv2e", "lbv2h", "gsm",
                                        "aime"}


def make_batch(rng, tasks, batch, seq_len):
    """Batch of Samples from a task list -> (tokens (B,S), weights (B,S),
    ans_starts, ans_lens, is_retrieval)."""
    toks = np.zeros((batch, seq_len), np.int32)
    w = np.zeros((batch, seq_len), np.float32)
    starts, lens, retr = [], [], []
    for i in range(batch):
        task = tasks[int(rng.integers(len(tasks)))]
        s = GENERATORS[task](rng, seq_len)
        toks[i] = s["tokens"]
        # next-token prediction: weight 1 everywhere except PAD, 5x on the
        # answer span (targets are shifted by the training loop)
        w[i] = (s["tokens"] != PAD).astype(np.float32)
        a0, al = s["ans_start"], s["ans_len"]
        w[i, a0:a0 + al] = 5.0
        starts.append(a0)
        lens.append(al)
        retr.append(s["retrieval"])
    return toks, w, np.array(starts), np.array(lens), np.array(retr)
