"""Build-time training for the FluxAttention reproduction.

Stages (all offline; nothing here runs at serving time):

  pretrain   -- train the tiny backbone from scratch on the synthetic
                task mixture (substitute for the public Qwen3/Llama
                checkpoints, DESIGN.md section 2). Full attention.
  router     -- the paper's contribution: freeze the backbone, train the
                per-layer Layer Router with Gumbel-Softmax soft routing
                (eq. 4-5), temperature annealing, and the Lagrangian
                sparsity objective (eq. 6) with task-dependent targets
                and dual ascent on lambda1/lambda2. Emits the trajectory
                JSON used for paper Figs 5, 7, 8, 10.
  continued  -- freeze the trained router (hard routing), unfreeze the
                backbone, continue training on the mixture (paper
                section 5.3 / Fig 6).
  eval       -- teacher-forced answer accuracy per task under a routing
                policy; used for the python-side sanity numbers (the
                authoritative tables are produced by the rust harness).

Usage:  python -m compile.train --stage pretrain
        python -m compile.train --stage router --name balanced
        python -m compile.train --stage router --name unbalanced --data-mix unbalanced
        python -m compile.train --stage router --name t35 --t-retrieval 0.35
        python -m compile.train --stage router --name pool8 --pool 8
        python -m compile.train --stage continued
"""

import argparse
import functools
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import data
from .config import MODEL, ROUTER, TRAIN, SPARSITY
from .model import (Params, RouterParams, init_params, init_router,
                    forward_train, routed_forward_train, cross_entropy,
                    router_logits_all_layers, forward_hard_routed)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
CURVES = os.path.join(ART, "curves")


# ---------------------------------------------------------------------------
# minimal AdamW (no optax in the image)
# ---------------------------------------------------------------------------

def adamw_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, b1=TRAIN.adam_b1,
                 b2=TRAIN.adam_b2, eps=1e-8, wd=TRAIN.weight_decay):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"],
                     grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mhat, vhat)
    return new, {"m": m, "v": v, "t": t}


def cosine_lr(step, total, base, warmup_ratio=TRAIN.warmup_ratio):
    warm = max(1, int(total * warmup_ratio))
    lin = step / warm
    cos = 0.5 * (1 + jnp.cos(jnp.pi * (step - warm) / max(1, total - warm)))
    return base * jnp.where(step < warm, lin, cos)


# ---------------------------------------------------------------------------
# (de)serialization: flat npz + raw little-endian binary for rust
# ---------------------------------------------------------------------------

def params_to_dict(params: Params):
    # lm_head is materialized as embed.T for the rust runtime (the
    # backbone itself is weight-tied; see model.Params)
    d = {"embed": params.embed, "norm_f": params.norm_f,
         "lm_head": params.embed.T}
    for f in params.layers._fields:
        d[f"layers.{f}"] = getattr(params.layers, f)
    return {k: np.asarray(v) for k, v in d.items()}


def dict_to_params(d) -> Params:
    from .model import LayerParams
    return Params(
        embed=jnp.asarray(d["embed"]),
        layers=LayerParams(**{f: jnp.asarray(d[f"layers.{f}"])
                              for f in LayerParams._fields}),
        norm_f=jnp.asarray(d["norm_f"]),
    )


def router_to_dict(rp: RouterParams):
    return {f: np.asarray(getattr(rp, f)) for f in rp._fields}


def dict_to_router(d) -> RouterParams:
    return RouterParams(**{f: jnp.asarray(d[f]) for f in RouterParams._fields})


def save_npz(path, d):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **d)


def load_npz(path):
    return dict(np.load(path))


def export_flat_bin(d, bin_path, manifest_path):
    """Raw f32 little-endian blob + JSON manifest for the rust loader."""
    entries = []
    with open(bin_path, "wb") as f:
        off = 0
        for name in sorted(d):
            arr = np.ascontiguousarray(d[name], np.float32)
            f.write(arr.tobytes())
            entries.append({"name": name, "offset": off,
                            "shape": list(arr.shape)})
            off += arr.nbytes
    with open(manifest_path, "w") as f:
        json.dump(entries, f, indent=1)


# ---------------------------------------------------------------------------
# stage: pretrain
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0, 1))
def _pretrain_step(params, opt, tokens, weights, lr):
    def loss_fn(p):
        logits = forward_train(p, tokens)
        return cross_entropy(logits[:, :-1], tokens[:, 1:],
                             weights[:, 1:])
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = adamw_update(params, grads, opt, lr)
    return params, opt, loss


def stage_pretrain(args):
    rng = np.random.default_rng(TRAIN.seed)
    key = jax.random.PRNGKey(TRAIN.seed)
    mpath = os.path.join(ART, "model.npz")
    if args.resume and os.path.exists(mpath):
        params = dict_to_params(load_npz(mpath))
        print("[pretrain] resumed from artifacts/model.npz")
    else:
        params = init_params(key)
    opt = adamw_init(params)
    steps = args.steps or TRAIN.pretrain_steps
    log, t0 = [], time.time()
    # curriculum: short sequences first (retrieval circuits form fast),
    # then longer batches so RoPE sees longer positions. The answer
    # span dominates the loss (unlearnable iid filler is downweighted).
    for step in range(steps):
        frac = step / max(1, steps)
        b, s = (32, 64) if frac < 0.55 else (16, 128) if frac < 0.8 else (8, 256)
        tasks = (os.environ.get("PRETRAIN_TASKS", "").split(",")
                 if os.environ.get("PRETRAIN_TASKS") else list(data.TASKS))
        toks, w, *_ = data.make_batch(rng, tasks, b, s)
        w = np.where(w == 5.0, 25.0, 0.25).astype(np.float32) * (toks != 0)
        lr = cosine_lr(step, steps, args.lr or TRAIN.pretrain_lr)
        params, opt, loss = _pretrain_step(params, opt, jnp.asarray(toks),
                                           jnp.asarray(w), lr)
        if step % 20 == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss),
                        "elapsed": time.time() - t0})
            print(f"[pretrain] step {step} loss {float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        if step % 200 == 199:
            acc = evaluate(params, None, rng, tasks=("pre", "lcc"),
                           n_batches=1, seq_len=128,
                           fixed_modes=["fa"] * MODEL.n_layers)
            print(f"[pretrain] step {step} acc "
                  f"{ {k: round(v['acc'], 2) for k, v in acc.items()} }",
                  flush=True)
    save_npz(os.path.join(ART, "model.npz"), params_to_dict(params))
    os.makedirs(CURVES, exist_ok=True)
    with open(os.path.join(CURVES, "pretrain.json"), "w") as f:
        json.dump(log, f)
    print("saved artifacts/model.npz")


# ---------------------------------------------------------------------------
# stage: router (the paper's training objective)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(1, 2),
                   static_argnames=("sa_mode", "pool"))
def _router_step(params, rp, opt, tokens, weights, key, tau, lam1, lam2,
                 t_target, lr, sa_mode="ssa", pool=SPARSITY.pool_size):
    def loss_fn(r):
        logits, r_soft = routed_forward_train(params, r, tokens, key, tau,
                                              sa_mode=sa_mode, pool=pool)
        lm = cross_entropy(logits[:, :-1], tokens[:, 1:], weights[:, 1:])
        # L_diff = E[1 - r_soft] - t  (expected SA fraction vs budget)
        l_diff = jnp.mean(1.0 - r_soft) - t_target
        reg = lam1 * l_diff + lam2 * l_diff * l_diff
        return lm + reg, (lm, l_diff, jnp.mean(1.0 - r_soft))
    (loss, (lm, l_diff, sa_frac)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(rp)
    rp, opt = adamw_update(rp, grads, opt, lr, wd=0.0)
    return rp, opt, lm, l_diff, sa_frac


def stage_router(args):
    rng = np.random.default_rng(TRAIN.seed + 1)
    params = dict_to_params(load_npz(os.path.join(ART, "model.npz")))
    rp = init_router(jax.random.PRNGKey(TRAIN.seed + 2))
    opt = adamw_init(rp)
    key = jax.random.PRNGKey(TRAIN.seed + 3)
    steps = args.steps or TRAIN.router_steps
    pool = args.pool or SPARSITY.pool_size
    t_retr = args.t_retrieval if args.t_retrieval is not None \
        else ROUTER.t_retrieval
    t_hol = ROUTER.t_holistic
    # per-category Lagrange multipliers, dual ascent (paper eq. 6)
    lam = {"retr": [0.5, 0.5], "hol": [0.5, 0.5]}
    slack = 0.05  # non-tight constraint slack
    retr = [t for t in data.TASKS if t in data.RETRIEVAL_SET]
    hol = [t for t in data.TASKS if t not in data.RETRIEVAL_SET]
    # unbalanced mix (paper Fig 7 right): dominated by holistic tasks
    p_retr = 0.5 if args.data_mix == "balanced" else 0.1
    traj = []
    t0 = time.time()
    for step in range(steps):
        is_retr = rng.random() < p_retr
        cat = "retr" if is_retr else "hol"
        tasks = retr if is_retr else hol
        t_target = t_retr if is_retr else t_hol
        toks, w, *_ = data.make_batch(rng, tasks, TRAIN.router_batch,
                                      args.seq or TRAIN.router_seq)
        tau = ROUTER.tau_start + (ROUTER.tau_end - ROUTER.tau_start) * (
            step / max(1, steps - 1))
        key, sub = jax.random.split(key)
        lr = cosine_lr(step, steps, TRAIN.router_lr)
        rp, opt, lm, l_diff, sa_frac = _router_step(
            params, rp, opt, jnp.asarray(toks), jnp.asarray(w), sub,
            jnp.float32(tau), jnp.float32(lam[cat][0]),
            jnp.float32(lam[cat][1]), jnp.float32(t_target), lr,
            pool=pool)
        # dual ascent on the multipliers (gradient ascent of eq. 6 in
        # lambda, with a slack so the constraint is non-tight)
        ld = float(l_diff)
        lam[cat][0] = float(np.clip(lam[cat][0] + TRAIN.lambda_lr * ld
                                    * 100, 0.0, 10.0))
        lam[cat][1] = float(np.clip(
            lam[cat][1] + TRAIN.lambda_lr * (ld * ld - slack ** 2) * 100,
            0.0, 10.0))
        traj.append({"step": step, "cat": cat, "lm_loss": float(lm),
                     "l_diff": ld, "sa_frac": float(sa_frac), "tau": tau,
                     "lam1_retr": lam["retr"][0], "lam2_retr": lam["retr"][1],
                     "lam1_hol": lam["hol"][0], "lam2_hol": lam["hol"][1]})
        if step % 10 == 0 or step == steps - 1:
            print(f"[router/{args.name}] step {step} cat {cat} "
                  f"lm {float(lm):.3f} sa {float(sa_frac):.3f} tau {tau:.2f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    save_npz(os.path.join(ART, f"router_{args.name}.npz"),
             router_to_dict(rp))
    os.makedirs(CURVES, exist_ok=True)
    with open(os.path.join(CURVES, f"router_{args.name}.json"), "w") as f:
        json.dump({"config": {"t_retrieval": t_retr, "pool": pool,
                              "data_mix": args.data_mix,
                              "steps": steps}, "trajectory": traj}, f)
    print(f"saved artifacts/router_{args.name}.npz")


# ---------------------------------------------------------------------------
# stage: continued training with frozen router (paper Fig 6)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0, 2))
def _continued_step(params, rp, opt, tokens, weights, lr):
    def loss_fn(p):
        # near-hard routing with a frozen router: tau ~ 0 saturates the
        # soft weights to 0/1, so gradients flow through the selected
        # branch only (the selection itself is non-differentiable)
        logits, _ = routed_forward_train(
            p, rp, tokens, jax.random.PRNGKey(0), 1e-3)
        return cross_entropy(logits[:, :-1], tokens[:, 1:], weights[:, 1:])
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = adamw_update(params, grads, opt, lr)
    return params, opt, loss


def stage_continued(args):
    rng = np.random.default_rng(TRAIN.seed + 5)
    params = dict_to_params(load_npz(os.path.join(ART, "model.npz")))
    rp = dict_to_router(load_npz(os.path.join(ART, "router_balanced.npz")))
    opt = adamw_init(params)
    steps = args.steps or TRAIN.continued_steps
    traj = []
    t0 = time.time()
    for step in range(steps):
        toks, w, *_ = data.make_batch(rng, list(data.TASKS), 4, 256)
        lr = cosine_lr(step, steps, TRAIN.continued_lr)
        params, opt, loss = _continued_step(params, rp, opt,
                                            jnp.asarray(toks),
                                            jnp.asarray(w), lr)
        if step % 10 == 0 or step == steps - 1:
            acc = evaluate(params, rp, rng, tasks=("pre", "gov", "trec"),
                           n_batches=2, seq_len=256)
            mean_acc = float(np.mean([a["acc"] for a in acc.values()]))
            traj.append({"step": step, "loss": float(loss),
                         "acc": mean_acc})
            print(f"[continued] step {step} loss {float(loss):.3f} "
                  f"acc {mean_acc:.3f} ({time.time()-t0:.0f}s)", flush=True)
    save_npz(os.path.join(ART, "model_continued.npz"),
             params_to_dict(params))
    with open(os.path.join(CURVES, "continued.json"), "w") as f:
        json.dump(traj, f)
    print("saved artifacts/model_continued.npz")


# ---------------------------------------------------------------------------
# evaluation (teacher-forced answer accuracy)
# ---------------------------------------------------------------------------

def evaluate(params, rp, rng, tasks, n_batches=4, seq_len=512, batch=8,
             sa_mode="ssa", fixed_modes=None, pool=SPARSITY.pool_size):
    """Answer-position argmax accuracy per task.

    rp: RouterParams for dynamic routing, or None with fixed_modes (a
    list of L mode strings) for static baselines.
    """
    out = {}
    for task in tasks:
        hits, total, sa_layers, n_routed = 0, 0, 0, 0
        for _ in range(n_batches):
            toks, w, starts, lens, _ = data.make_batch(
                rng, [task], batch, seq_len)
            jtoks = jnp.asarray(toks)
            if fixed_modes is not None:
                logits = forward_hard_routed(params, jtoks, fixed_modes)
            else:
                logits, modes = _routed_eval_forward(params, rp, jtoks,
                                                     pool, sa_mode)
                sa_layers += int((~np.asarray(modes)).sum())
                n_routed += modes.size
            pred = np.asarray(jnp.argmax(logits, axis=-1))
            for i in range(batch):
                a0, al = int(starts[i]), int(lens[i])
                # logits at position p predict token p+1
                ok = all(pred[i, a0 - 1 + j] == toks[i, a0 + j]
                         for j in range(al))
                hits += int(ok)
                total += 1
        out[task] = {"acc": hits / total,
                     "omsr": (sa_layers / n_routed) if n_routed else None}
    return out


@functools.partial(jax.jit, static_argnames=("pool", "sa_mode"))
def _routed_eval_forward(params, rp, tokens, pool, sa_mode):
    """Hard-routed forward (per-sample routing). Returns (logits, modes)
    with modes (L, B) bool (True = FA)."""
    from .model import (rope_tables, rms_norm, pool_descriptor,
                        _layer_fwd_b)
    b, s = tokens.shape
    x = params.embed[tokens]
    cos, sin = rope_tables(jnp.arange(s))
    modes = []
    for i in range(MODEL.n_layers):
        lp = jax.tree.map(lambda a: a[i], params.layers)
        desc = jax.vmap(pool_descriptor, in_axes=(0, None))(x, pool)
        logits = jax.nn.gelu(desc @ rp.w1[i] + rp.b1[i]) @ rp.w2[i] + rp.b2[i]
        is_fa = logits[:, 1] > logits[:, 0]
        y_fa = _layer_fwd_b(lp, x, cos, sin, "fa")
        y_sa = _layer_fwd_b(lp, x, cos, sin, sa_mode)
        x = jnp.where(is_fa[:, None, None], y_fa, y_sa)
        modes.append(is_fa)
    return rms_norm(x, params.norm_f) @ params.embed.T, jnp.stack(modes)


def stage_eval(args):
    rng = np.random.default_rng(TRAIN.seed + 9)
    params = dict_to_params(load_npz(os.path.join(ART, "model.npz")))
    rp = dict_to_router(load_npz(
        os.path.join(ART, f"router_{args.name}.npz")))
    res = evaluate(params, rp, rng, tasks=list(data.TASKS),
                   n_batches=args.n_batches, seq_len=args.seq or 512)
    print(json.dumps(res, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage", required=True,
                    choices=["pretrain", "router", "continued", "eval"])
    ap.add_argument("--name", default="balanced")
    ap.add_argument("--data-mix", default="balanced",
                    choices=["balanced", "unbalanced"])
    ap.add_argument("--t-retrieval", type=float, default=None)
    ap.add_argument("--pool", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--n-batches", type=int, default=2)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    {"pretrain": stage_pretrain, "router": stage_router,
     "continued": stage_continued, "eval": stage_eval}[args.stage](args)


if __name__ == "__main__":
    main()
