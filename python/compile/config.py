"""Scaled hyperparameter configuration for the FluxAttention reproduction.

Mirrors Table 3 of the paper, scaled ~32x down in context length (paper
trains at 65,536 tokens on 8xA800; we train at <=1,024 on CPU) with the
sparse-attention geometry (sink/local/block sizes) scaled by the same
factor so the context/window ratios -- which drive the FA-vs-SA
behavioural crossovers -- are preserved. See DESIGN.md section 2.
"""

from dataclasses import dataclass, field, asdict
import json


@dataclass(frozen=True)
class ModelConfig:
    """Backbone transformer configuration (the frozen "pretrained LLM")."""

    vocab_size: int = 512
    d_model: int = 128
    n_layers: int = 8
    n_heads: int = 4
    head_dim: int = 32  # d_model / n_heads
    d_ff: int = 512
    max_seq_len: int = 2048
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    def __post_init__(self):
        assert self.n_heads * self.head_dim == self.d_model


@dataclass(frozen=True)
class SparsityConfig:
    """Sparse-attention geometry (paper Table 3, "Sparsity Config", /16).

    Paper (64K contexts): sink 128, local 2048, block 64, stride 16.
    Ours (2K contexts):   sink 16,  local 128,  block 16, stride 4.
    """

    sink_size: int = 16
    local_size: int = 128
    block_size: int = 16
    xattn_stride: int = 4
    xattn_keep_ratio: float = 0.25  # fraction of kv blocks kept per q block
    triangle_last_q: int = 64  # dense rows at the bottom of the matrix
    pool_size: int = 16  # prefill/suffix pooling window (paper: 100)

    @property
    def sa_decode_window(self) -> int:
        # sparse-decode ring buffer: sink + local (+1 for current token)
        return self.sink_size + self.local_size + 1


@dataclass(frozen=True)
class RouterConfig:
    """Layer Router: Context Encoder MLP + Router Head MLP."""

    d_hidden: int = 64
    tau_start: float = 2.0  # Gumbel-Softmax temperature annealing
    tau_end: float = 0.3
    # Task-dependent sparsity budgets t (permissible fraction of SA layers).
    # Paper section 4.1: t=1.0 for context-holistic, t=0.45 for retrieval.
    t_retrieval: float = 0.45
    t_holistic: float = 1.0


@dataclass(frozen=True)
class TrainConfig:
    """Optimization settings (paper Table 3, scaled)."""

    seed: int = 0
    # backbone pretraining (substitute for the public pretrained checkpoint)
    pretrain_steps: int = 1100
    pretrain_batch: int = 8
    pretrain_seq: int = 512
    pretrain_lr: float = 2e-3
    # router training (the paper's 300-step, 12h-on-8xA800 run, scaled)
    router_steps: int = 120
    router_batch: int = 8
    router_seq: int = 256
    router_lr: float = 5e-4  # paper: "Mask LR" 5e-4
    lambda_lr: float = 1e-3  # paper: "Reg. LR" 1e-3
    warmup_ratio: float = 0.2
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    weight_decay: float = 0.1
    # continued training with a frozen router (paper section 5.3)
    continued_steps: int = 60
    continued_lr: float = 3e-4


# Executable bucket sizes for the AOT artifacts (powers of two).
PREFILL_BUCKETS = (128, 256, 512, 1024, 2048)
DECODE_KV_BUCKETS = (128, 256, 512, 1024, 2048)

MODEL = ModelConfig()
SPARSITY = SparsityConfig()
ROUTER = RouterConfig()
TRAIN = TrainConfig()


def dump_meta(path: str) -> None:
    """Write the full configuration as JSON for the rust side."""
    meta = {
        "model": asdict(MODEL),
        "sparsity": asdict(SPARSITY),
        "router": asdict(ROUTER),
        "train": asdict(TRAIN),
        "prefill_buckets": list(PREFILL_BUCKETS),
        "decode_kv_buckets": list(DECODE_KV_BUCKETS),
        "sa_decode_window": SPARSITY.sa_decode_window,
    }
    with open(path, "w") as f:
        json.dump(meta, f, indent=2)
