"""L2: the FluxAttention transformer model in JAX.

Defines:
  * the backbone transformer (RMSNorm / RoPE / MHA / SwiGLU-free MLP)
    used both for training (fast jnp refs, vmapped, lax.scan over layers)
    and for AOT export (per-layer step functions calling the L1 Pallas
    kernels so they lower into the same HLO);
  * the Layer Router (Context Encoder MLP + Router Head MLP) with
    Gumbel-Softmax soft routing (paper eq. 4-5) for training and argmax
    hard routing for inference;
  * the flat-signature step functions that aot.py lowers to HLO text for
    the rust runtime (prefill layer step per attention mode, decode qkv /
    attend steps, router, lm head).

Python never runs at serving time: everything here is build-time only.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import MODEL, SPARSITY, ROUTER
from .kernels import ref
from .kernels.full_attn import full_attention_pallas
from .kernels.ssa import ssa_attention_pallas
from .kernels.triangle import triangle_attention_pallas
from .kernels.xattn import (xattn_scores_pallas, select_blocks,
                            block_sparse_attention_pallas)
from .kernels.decode import fa_decode_pallas
from .kernels.router_pool import router_mlp_pallas

MODES = ("fa", "ssa", "ta", "xa")


# ---------------------------------------------------------------------------
# parameter containers
# ---------------------------------------------------------------------------

class LayerParams(NamedTuple):
    """One transformer layer. Arrays may carry a leading (L,) axis when
    stacked for lax.scan."""
    norm1: jnp.ndarray   # (d,)
    wq: jnp.ndarray      # (d, d)
    wk: jnp.ndarray      # (d, d)
    wv: jnp.ndarray      # (d, d)
    wo: jnp.ndarray      # (d, d)
    norm2: jnp.ndarray   # (d,)
    w_ff1: jnp.ndarray   # (d, ff)
    w_ff2: jnp.ndarray   # (ff, d)


class Params(NamedTuple):
    """Backbone parameters. The LM head is weight-tied to the embedding
    (lm_head = embed.T) -- tying makes the copy/retrieval circuits form
    orders of magnitude faster at this scale, and the AOT export
    materializes embed.T as the `lm_head` tensor so the rust runtime is
    agnostic to the tying."""
    embed: jnp.ndarray       # (V, d)
    layers: LayerParams      # stacked (L, ...)
    norm_f: jnp.ndarray      # (d,)


class RouterParams(NamedTuple):
    """Per-layer Layer Router; stacked (L, ...) like the backbone."""
    w1: jnp.ndarray  # (2d, hidden)
    b1: jnp.ndarray  # (hidden,)
    w2: jnp.ndarray  # (hidden, 2)  logits order: [SA, FA]
    b2: jnp.ndarray  # (2,)


def init_params(key, cfg=MODEL) -> Params:
    d, ff, v, nl = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    ks = jax.random.split(key, 8)

    def mat(k, shape, scale=None):
        scale = scale or (1.0 / jnp.sqrt(shape[0]))
        return jax.random.normal(k, shape, jnp.float32) * scale

    layers = LayerParams(
        norm1=jnp.ones((nl, d)),
        wq=mat(ks[0], (nl, d, d), 1.0 / jnp.sqrt(d)),
        wk=mat(ks[1], (nl, d, d), 1.0 / jnp.sqrt(d)),
        wv=mat(ks[2], (nl, d, d), 1.0 / jnp.sqrt(d)),
        wo=mat(ks[3], (nl, d, d), 1.0 / jnp.sqrt(d)),
        norm2=jnp.ones((nl, d)),
        w_ff1=mat(ks[4], (nl, d, ff), 1.0 / jnp.sqrt(d)),
        w_ff2=mat(ks[5], (nl, ff, d), 1.0 / jnp.sqrt(ff)),
    )
    return Params(
        embed=mat(ks[6], (v, d), 1.0 / jnp.sqrt(d)),
        layers=layers,
        norm_f=jnp.ones((d,)),
    )


def init_router(key, cfg=MODEL, rcfg=ROUTER) -> RouterParams:
    d, h, nl = cfg.d_model, rcfg.d_hidden, cfg.n_layers
    k1, k2 = jax.random.split(key)
    return RouterParams(
        w1=jax.random.normal(k1, (nl, 2 * d, h), jnp.float32) / jnp.sqrt(2 * d),
        b1=jnp.zeros((nl, h)),
        w2=jax.random.normal(k2, (nl, h, 2), jnp.float32) / jnp.sqrt(h),
        b2=jnp.zeros((nl, 2)),
    )


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=MODEL.rms_eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope_tables(positions, head_dim=MODEL.head_dim, theta=MODEL.rope_theta):
    """cos/sin tables (S, D/2) for integer positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, D); cos/sin: (S, D/2). Rotates adjacent pairs."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def qkv_proj(lp: LayerParams, x, cos, sin, cfg=MODEL):
    """x: (S, d) -> q, k, v each (H, S, D), RoPE applied to q and k."""
    s = x.shape[0]
    h, dd = cfg.n_heads, cfg.head_dim
    xn = rms_norm(x, lp.norm1)
    q = (xn @ lp.wq).reshape(s, h, dd).transpose(1, 0, 2)
    k = (xn @ lp.wk).reshape(s, h, dd).transpose(1, 0, 2)
    v = (xn @ lp.wv).reshape(s, h, dd).transpose(1, 0, 2)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def attn_out_mlp(lp: LayerParams, x, ctx, cfg=MODEL):
    """Residual add of attention output + MLP block. ctx: (H, S, D)."""
    s = x.shape[0]
    merged = ctx.transpose(1, 0, 2).reshape(s, cfg.d_model)
    x = x + merged @ lp.wo
    xn = rms_norm(x, lp.norm2)
    return x + jax.nn.gelu(xn @ lp.w_ff1) @ lp.w_ff2


def sparse_attention_ref(q, k, v, mode: str, sp=SPARSITY):
    """Training-time (fast jnp) attention for a given mode."""
    if mode == "fa":
        return ref.full_attention(q, k, v)
    if mode == "ssa":
        return ref.ssa_attention(q, k, v, sp.sink_size, sp.local_size)
    if mode == "ta":
        return ref.triangle_attention(q, k, v, sp.sink_size, sp.local_size,
                                      sp.triangle_last_q)
    if mode == "xa":
        return ref.xattn_attention(q, k, v, sp.block_size, sp.xattn_stride,
                                   sp.xattn_keep_ratio, sp.sink_size,
                                   sp.local_size)
    raise ValueError(mode)


def sparse_attention_pallas(q, k, v, mode: str, sp=SPARSITY):
    """AOT-export attention: the L1 Pallas kernels."""
    if mode == "fa":
        return full_attention_pallas(q, k, v)
    if mode == "ssa":
        return ssa_attention_pallas(q, k, v, sp.sink_size, sp.local_size)
    if mode == "ta":
        return triangle_attention_pallas(q, k, v, sp.sink_size, sp.local_size,
                                         sp.triangle_last_q)
    if mode == "xa":
        scores = xattn_scores_pallas(q, k, sp.block_size, sp.xattn_stride)
        mask = select_blocks(scores, sp.block_size, sp.xattn_keep_ratio,
                             sp.sink_size, sp.local_size)
        return block_sparse_attention_pallas(q, k, v, mask, bq=sp.block_size,
                                             bk=sp.block_size)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# AOT step functions (flat signatures; lowered by aot.py)
# ---------------------------------------------------------------------------

def prefill_layer_step(mode: str, x, norm1, wq, wk, wv, wo, norm2, w_ff1,
                       w_ff2):
    """One transformer layer over a full (bucketed) prompt.

    x: (S, d). Returns (x_out (S, d), k (H, S, D), v (H, S, D)).
    Padding contract: rust pads prompts to the bucket at the END; causal
    masking guarantees all valid rows are exact.
    """
    lp = LayerParams(norm1, wq, wk, wv, wo, norm2, w_ff1, w_ff2)
    s = x.shape[0]
    cos, sin = rope_tables(jnp.arange(s))
    q, k, v = qkv_proj(lp, x, cos, sin)
    ctx = sparse_attention_pallas(q, k, v, mode)
    return attn_out_mlp(lp, x, ctx), k, v


def decode_qkv_step(x, pos, norm1, wq, wk, wv):
    """Decode stage 1: project + RoPE the current token.

    x: (d,), pos: (1,) i32. Returns q, k, v each (H, D). Rust appends
    k, v into its KV cache before calling the attend step.
    """
    h, dd = MODEL.n_heads, MODEL.head_dim
    xn = rms_norm(x, norm1)
    q = (xn @ wq).reshape(h, dd)
    k = (xn @ wk).reshape(h, dd)
    v = (xn @ wv).reshape(h, dd)
    cos, sin = rope_tables(pos.astype(jnp.int32))
    q = apply_rope(q[:, None, :], cos, sin)[:, 0]
    k = apply_rope(k[:, None, :], cos, sin)[:, 0]
    return q, k, v


def decode_attend_step(x, q, k_cache, v_cache, valid_len, wo, norm2, w_ff1,
                       w_ff2):
    """Decode stage 2: attend over the cache (which already contains the
    current token) and finish the layer. x: (d,) residual input."""
    ctx = fa_decode_pallas(q, k_cache, v_cache, valid_len)  # (H, D)
    merged = ctx.reshape(MODEL.d_model)
    x = x + merged @ wo
    xn = rms_norm(x, norm2)
    return x + jax.nn.gelu(xn @ w_ff1) @ w_ff2


def router_step(desc, w1, b1, w2, b2):
    """Layer Router logits from a (2d,) pooled descriptor: [SA, FA]."""
    return router_mlp_pallas(desc, w1, b1, w2, b2)


def lm_head_step(x, norm_f, lm_head):
    """Final norm + vocabulary projection for one token. x: (d,)."""
    return rms_norm(x, norm_f) @ lm_head


def lm_head_seq_step(x, norm_f, lm_head):
    """Bucketed scoring path: x (S, d) -> logits (S, V)."""
    return rms_norm(x, norm_f) @ lm_head


# ---------------------------------------------------------------------------
# training-time whole-model forward (fast jnp refs, scan over layers)
# ---------------------------------------------------------------------------

def forward_train(params: Params, tokens, sa_mode: str = "ssa",
                  r_soft=None, cfg=MODEL):
    """Batched forward. tokens: (B, S) i32.

    r_soft: optional (L, B) FA-selection probabilities (paper eq. 5); when
    given, each layer's output is the convex combination
    r * FA(x) + (1 - r) * SA(x). When None, pure full attention.
    Returns logits (B, S, V).
    """
    b, s = tokens.shape
    x = params.embed[tokens]  # (B, S, d)
    cos, sin = rope_tables(jnp.arange(s))

    def scan_body(x, inp):
        lp, r = inp
        y_fa = _layer_fwd_b(lp, x, cos, sin, "fa")
        if r_soft is None:
            return y_fa, None
        y_sa = _layer_fwd_b(lp, x, cos, sin, sa_mode)
        y = r[:, None, None] * y_fa + (1.0 - r[:, None, None]) * y_sa
        return y, None

    rs = r_soft if r_soft is not None else jnp.ones((cfg.n_layers, b))
    x, _ = jax.lax.scan(scan_body, x, (params.layers, rs))
    return rms_norm(x, params.norm_f) @ params.embed.T


def _layer_fwd(lp: LayerParams, x, cos, sin, mode: str):
    q, k, v = qkv_proj(lp, x, cos, sin)
    ctx = sparse_attention_ref(q, k, v, mode)
    return attn_out_mlp(lp, x, ctx)


def _layer_fwd_b(lp: LayerParams, x, cos, sin, mode: str):
    """Batched layer forward; mode stays a static python string."""
    return jax.vmap(
        functools.partial(_layer_fwd, mode=mode),
        in_axes=(None, 0, None, None))(lp, x, cos, sin)


def forward_hard_routed(params: Params, tokens, layer_modes, cfg=MODEL):
    """Inference-style forward with per-layer hard modes (python list of
    mode strings, len L). Used by python-side eval; rust replicates this
    layer dispatch at serving time."""
    b, s = tokens.shape
    x = params.embed[tokens]
    cos, sin = rope_tables(jnp.arange(s))
    layer_list = [jax.tree.map(lambda a: a[i], params.layers)
                  for i in range(cfg.n_layers)]
    for lp, mode in zip(layer_list, layer_modes):
        x = _layer_fwd_b(lp, x, cos, sin, mode)
    return rms_norm(x, params.norm_f) @ params.embed.T


# ---------------------------------------------------------------------------
# Layer Router forward (training + eval)
# ---------------------------------------------------------------------------

def pool_descriptor(x, pool=SPARSITY.pool_size):
    """Prefill-Suffix Pooling of (S, d) hidden states -> (2d,)."""
    s = x.shape[0]
    p = min(pool, s)
    return jnp.concatenate([x[:p].mean(axis=0), x[s - p:].mean(axis=0)])


def router_logits_all_layers(rp: RouterParams, params: Params, tokens,
                             pool=SPARSITY.pool_size, cfg=MODEL,
                             sa_mode: str = "ssa", hard: bool = True):
    """Run the model layer-by-layer, routing each layer from its own
    input descriptor (matching the serving data path). Returns
    (modes (L, B) bool FA?, logits (L, B, 2)). Uses hard routing."""
    b, s = tokens.shape
    x = params.embed[tokens]
    cos, sin = rope_tables(jnp.arange(s))
    modes, logits_all = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params.layers)
        desc = jax.vmap(pool_descriptor, in_axes=(0, None))(x, pool)  # (B, 2d)
        logits = jax.nn.gelu(desc @ rp.w1[i] + rp.b1[i]) @ rp.w2[i] + rp.b2[i]
        is_fa = logits[:, 1] > logits[:, 0]  # (B,)
        logits_all.append(logits)
        y_fa = _layer_fwd_b(lp, x, cos, sin, "fa")
        y_sa = _layer_fwd_b(lp, x, cos, sin, sa_mode)
        x = jnp.where(is_fa[:, None, None], y_fa, y_sa)
        modes.append(is_fa)
    return jnp.stack(modes), jnp.stack(logits_all)


def gumbel_soft_route(key, logits, tau):
    """Paper eq. 4: Gumbel-Softmax relaxation. logits (..., 2) -> r_soft
    = P(FA) in (0, 1)."""
    g = jax.random.gumbel(key, logits.shape)
    z = (logits + g) / tau
    return jax.nn.softmax(z, axis=-1)[..., 1]


def routed_forward_train(params: Params, rp: RouterParams, tokens, key, tau,
                         sa_mode: str = "ssa", pool=SPARSITY.pool_size,
                         cfg=MODEL):
    """Soft-routed forward for router training (paper eq. 4-5).

    Per layer: pool the layer input, compute router logits, sample r_soft
    via Gumbel-Softmax, output the convex combination of FA and SA paths.
    Returns (logits (B, S, V), r_soft (L, B)).
    """
    b, s = tokens.shape
    x = params.embed[tokens]
    cos, sin = rope_tables(jnp.arange(s))
    keys = jax.random.split(key, cfg.n_layers)
    r_all = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params.layers)
        desc = jax.vmap(pool_descriptor, in_axes=(0, None))(x, pool)
        logits = jax.nn.gelu(desc @ rp.w1[i] + rp.b1[i]) @ rp.w2[i] + rp.b2[i]
        r = gumbel_soft_route(keys[i], logits, tau)  # (B,)
        y_fa = _layer_fwd_b(lp, x, cos, sin, "fa")
        y_sa = _layer_fwd_b(lp, x, cos, sin, sa_mode)
        x = r[:, None, None] * y_fa + (1.0 - r[:, None, None]) * y_sa
        r_all.append(r)
    logits_lm = rms_norm(x, params.norm_f) @ params.embed.T
    return logits_lm, jnp.stack(r_all)


def cross_entropy(logits, targets, weights):
    """Token CE with position weights. logits (B,S,V), targets (B,S)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * weights).sum() / jnp.maximum(weights.sum(), 1.0)
