"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Includes hypothesis sweeps over shapes/seeds and semantic property tests
(causality, sink/window locality) that perturb inputs outside the mask
support and assert output invariance.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    full_attention_pallas, ssa_attention_pallas, triangle_attention_pallas,
    xattn_scores_pallas, xattn_attention_pallas, fa_decode_pallas,
    sa_decode_pallas, prefill_suffix_pool_pallas, prefill_suffix_pool_ref,
    router_mlp_pallas, router_mlp_ref, ref,
)

HSETTINGS = dict(deadline=None, max_examples=8, derandomize=True)


def rand_qkv(seed, h, s, d, scale=0.5):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.standard_normal((h, s, d)),
                             jnp.float32) * scale
    return mk(), mk(), mk()


# ---------------------------------------------------------------------------
# parity vs oracle
# ---------------------------------------------------------------------------

@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([64, 128, 256]),
       h=st.sampled_from([1, 2, 4]), d=st.sampled_from([16, 32]))
def test_full_attention_matches_ref(seed, s, h, d):
    q, k, v = rand_qkv(seed, h, s, d)
    out = full_attention_pallas(q, k, v)
    np.testing.assert_allclose(out, ref.full_attention(q, k, v),
                               rtol=2e-5, atol=2e-5)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([128, 256]),
       sink=st.sampled_from([8, 16, 64]), local=st.sampled_from([32, 128]))
def test_ssa_matches_ref(seed, s, sink, local):
    q, k, v = rand_qkv(seed, 2, s, 32)
    out = ssa_attention_pallas(q, k, v, sink, local)
    np.testing.assert_allclose(out, ref.ssa_attention(q, k, v, sink, local),
                               rtol=2e-5, atol=2e-5)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([128, 256]),
       last_q=st.sampled_from([32, 64, 128]))
def test_triangle_matches_ref(seed, s, last_q):
    q, k, v = rand_qkv(seed, 2, s, 32)
    out = triangle_attention_pallas(q, k, v, 16, 64, last_q)
    np.testing.assert_allclose(
        out, ref.triangle_attention(q, k, v, 16, 64, last_q),
        rtol=2e-5, atol=2e-5)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([128, 256]))
def test_xattn_scores_match_ref(seed, s):
    q, k, _ = rand_qkv(seed, 2, s, 32)
    got = xattn_scores_pallas(q, k, 16, 4)
    want = ref.xattn_block_scores(q, k, 16, 4).sum(axis=0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16),
       keep=st.sampled_from([0.125, 0.25, 0.5]))
def test_xattn_pipeline_matches_ref(seed, keep):
    q, k, v = rand_qkv(seed, 2, 128, 32)
    out = xattn_attention_pallas(q, k, v, 16, 4, keep, 16, 64)
    want = ref.xattn_attention(q, k, v, 16, 4, keep, 16, 64)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16), kmax=st.sampled_from([128, 256]),
       valid=st.integers(1, 128))
def test_fa_decode_matches_ref(seed, kmax, valid):
    rng = np.random.default_rng(seed)
    h, d = 4, 32
    q = jnp.asarray(rng.standard_normal((h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((h, kmax, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((h, kmax, d)), jnp.float32)
    out = fa_decode_pallas(q, kc, vc, jnp.asarray([valid], jnp.int32))
    np.testing.assert_allclose(out, ref.fa_decode(q, kc, vc, valid),
                               rtol=2e-5, atol=2e-5)


def test_sa_decode_matches_ref():
    rng = np.random.default_rng(7)
    h, d, buf = 4, 32, 192
    q = jnp.asarray(rng.standard_normal((h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((h, buf, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((h, buf, d)), jnp.float32)
    out = sa_decode_pallas(q, kc, vc, jnp.asarray([145], jnp.int32))
    np.testing.assert_allclose(out, ref.sa_decode(q, kc, vc, 145),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# semantic properties (mask support)
# ---------------------------------------------------------------------------

def test_full_attention_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    q, k, v = rand_qkv(3, 2, 128, 32)
    base = full_attention_pallas(q, k, v)
    k2 = k.at[:, 100:].add(3.0)
    v2 = v.at[:, 100:].add(-5.0)
    pert = full_attention_pallas(q, k2, v2)
    np.testing.assert_allclose(base[:, :100], pert[:, :100],
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[:, 100:], pert[:, 100:])


def test_ssa_ignores_outside_sink_and_window():
    """Rows past sink+local must be blind to the masked middle region."""
    sink, local, s = 16, 32, 256
    q, k, v = rand_qkv(4, 2, s, 32)
    base = ssa_attention_pallas(q, k, v, sink, local)
    # perturb keys in (sink, i-local] for the last row block: indices
    # 32..(192) are invisible to rows >= 224
    k2 = k.at[:, 32:192].add(7.0)
    v2 = v.at[:, 32:192].add(7.0)
    pert = ssa_attention_pallas(q, k2, v2, sink, local)
    np.testing.assert_allclose(base[:, 224:], pert[:, 224:],
                               rtol=1e-6, atol=1e-6)


def test_triangle_last_rows_are_dense():
    """Dense last-q rows must see middle-region perturbations."""
    sink, local, last_q, s = 16, 32, 64, 256
    q, k, v = rand_qkv(5, 2, s, 32)
    base = triangle_attention_pallas(q, k, v, sink, local, last_q)
    k2 = k.at[:, 64:128].add(5.0)
    pert = triangle_attention_pallas(q, k2, v, sink, local, last_q)
    # streaming rows in [160, 192) cannot see cols 64..128
    np.testing.assert_allclose(base[:, 160:192], pert[:, 160:192],
                               rtol=1e-6, atol=1e-6)
    # dense rows (last 64) must change
    assert not np.allclose(base[:, 192:], pert[:, 192:])


def test_decode_valid_len_masks_tail():
    rng = np.random.default_rng(11)
    h, d, kmax = 2, 32, 128
    q = jnp.asarray(rng.standard_normal((h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((h, kmax, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((h, kmax, d)), jnp.float32)
    base = fa_decode_pallas(q, kc, vc, jnp.asarray([50], jnp.int32))
    kc2 = kc.at[:, 50:].set(99.0)
    vc2 = vc.at[:, 50:].set(-99.0)
    pert = fa_decode_pallas(q, kc2, vc2, jnp.asarray([50], jnp.int32))
    np.testing.assert_allclose(base, pert, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# pooling / router MLP
# ---------------------------------------------------------------------------

@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([32, 128, 512]),
       pool=st.sampled_from([8, 16, 64]))
def test_pool_matches_ref(seed, s, pool):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((s, 64)), jnp.float32)
    np.testing.assert_allclose(prefill_suffix_pool_pallas(x, pool),
                               prefill_suffix_pool_ref(x, pool),
                               rtol=1e-6, atol=1e-6)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**16))
def test_router_mlp_matches_ref(seed):
    rng = np.random.default_rng(seed)
    d, h = 256, 64
    desc = jnp.asarray(rng.standard_normal(d), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((d, h)) * 0.1, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal(h) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((h, 2)) * 0.1, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal(2) * 0.1, jnp.float32)
    np.testing.assert_allclose(router_mlp_pallas(desc, w1, b1, w2, b2),
                               router_mlp_ref(desc, w1, b1, w2, b2),
                               rtol=1e-5, atol=1e-5)


def test_pool_length_invariance_of_descriptor_dim():
    """Router input dim is constant across sequence lengths (Fig 9)."""
    for s in (64, 256, 2048):
        x = jnp.ones((s, 128), jnp.float32)
        assert prefill_suffix_pool_pallas(x, 16).shape == (256,)
