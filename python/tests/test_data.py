"""Task-generator invariants: exact lengths, answer placement, solvability
semantics (the retrieval/holistic split the router must learn)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


@settings(deadline=None, max_examples=10, derandomize=True)
@given(seed=st.integers(0, 2**16),
       task=st.sampled_from(sorted(data.GENERATORS)),
       seq_len=st.sampled_from([128, 256, 512, 1024]))
def test_generator_layout(seed, task, seq_len):
    rng = np.random.default_rng(seed)
    s = data.GENERATORS[task](rng, seq_len)
    toks = s["tokens"]
    assert len(toks) == seq_len
    assert toks[0] == data.BOS
    assert toks[-1] == data.EOS
    a0, al = s["ans_start"], s["ans_len"]
    assert al >= 1
    assert toks[a0 - 1] == data.ANSWER
    assert (toks[a0:a0 + al] >= data.CONTENT).all()
    assert (toks < data.VOCAB).all() and (toks >= 0).all()


def test_category_taxonomy_is_total():
    for t in data.TASKS:
        assert t in data.CATEGORY
    cats = set(data.CATEGORY.values())
    assert cats == {"sdocqa", "mdocqa", "summ", "icl", "synthetic", "code"}


def test_retrieval_answers_require_lookup():
    """qasper: the answer token appears in the context exactly where the
    key is (and the key-answer pair is unique)."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        s = data.GENERATORS["qasper"](rng, 256)
        toks = list(s["tokens"])
        q_pos = toks.index(data.QUERY)
        key = toks[q_pos + 1]
        ans = toks[s["ans_start"]]
        # find the fact (SEP key value) in the context
        found = [i for i in range(q_pos)
                 if toks[i] == data.SEP and i + 2 < q_pos
                 and toks[i + 1] == key]
        assert any(toks[i + 2] == ans for i in found)


def test_holistic_answer_in_local_window():
    """trec: a (pattern -> label) example for the queried pattern exists
    within the trailing `local` tokens, so SSA keeps it visible."""
    rng = np.random.default_rng(1)
    local = 128
    hit = 0
    for _ in range(20):
        s = data.GENERATORS["trec"](rng, 512)
        toks = list(s["tokens"])
        q_pos = toks.index(data.QUERY)
        pat = toks[q_pos + 1]
        window = toks[max(0, q_pos - local):q_pos]
        if pat in window:
            hit += 1
    assert hit >= 16  # probabilistic but overwhelmingly likely


def test_pre_needle_depth_varies():
    rng = np.random.default_rng(2)
    depths = []
    for _ in range(50):
        s = data.GENERATORS["pre"](rng, 512)
        toks = list(s["tokens"])
        q_pos = toks.index(data.QUERY)
        key = toks[q_pos + 1]
        depths.append(toks.index(key))
    assert np.std(depths) > 50  # uniformly spread, not clustered


def test_arith_chain_answer_is_correct():
    rng = np.random.default_rng(3)
    for _ in range(10):
        s = data.GENERATORS["gsm"](rng, 256)
        toks = list(s["tokens"])
        # replay the chain: initial value then (+x) ops
        mod = 97
        i = toks.index(data.QUERY)
        val = (toks[i + 1] - data.CONTENT) % data.NCONTENT
        j = i + 2
        add_tag = data.CONTENT + (data.NCONTENT - 1) % data.NCONTENT
        while j + 2 < len(toks):
            if toks[j] == data.SEP and toks[j + 1] == add_tag:
                val = (val + (toks[j + 2] - data.CONTENT)) % mod
                j += 3
            else:
                j += 1
        ans = toks[s["ans_start"]]
        assert ans == data.CONTENT + val % data.NCONTENT


def test_make_batch_shapes_and_weights():
    rng = np.random.default_rng(4)
    toks, w, starts, lens, retr = data.make_batch(
        rng, list(data.TASKS), 16, 256)
    assert toks.shape == (16, 256) and w.shape == (16, 256)
    assert (w.max(axis=1) == 5.0).all()  # every sample has an answer span
    assert retr.dtype == bool


def test_batch_single_task_category_flag():
    rng = np.random.default_rng(5)
    _, _, _, _, retr = data.make_batch(rng, ["pre"], 4, 128)
    assert retr.all()
    _, _, _, _, retr = data.make_batch(rng, ["gov"], 4, 128)
    assert not retr.any()
