"""L2 correctness: model shapes, prefill/decode equivalence, routing.

The decode-consistency test is the core serving-correctness signal: the
per-token decode path (decode_qkv_step -> cache append -> decode_attend
_step) must reproduce the prefill path row-for-row, because the rust
coordinator runs exactly those step functions.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import data
from compile.config import MODEL, SPARSITY


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def rparams():
    return M.init_router(jax.random.PRNGKey(1))


def layer_of(params, i):
    return jax.tree.map(lambda a: a[i], params.layers)


def test_forward_shapes(params):
    toks = jnp.zeros((2, 128), jnp.int32)
    logits = M.forward_train(params, toks)
    assert logits.shape == (2, 128, MODEL.vocab_size)


def test_prefill_layer_step_shapes(params):
    lp = layer_of(params, 0)
    x = jnp.ones((128, MODEL.d_model), jnp.float32)
    for mode in M.MODES:
        y, k, v = M.prefill_layer_step(mode, x, *lp)
        assert y.shape == x.shape
        assert k.shape == (MODEL.n_heads, 128, MODEL.head_dim)
        assert v.shape == k.shape


def test_prefill_padding_contract(params):
    """Valid rows are exact regardless of trailing padding (causality)."""
    lp = layer_of(params, 0)
    rng = np.random.default_rng(0)
    x_short = jnp.asarray(rng.standard_normal((128, MODEL.d_model)),
                          jnp.float32)
    x_padded = jnp.concatenate(
        [x_short, jnp.asarray(rng.standard_normal((128, MODEL.d_model)),
                              jnp.float32) * 50.0])
    y_short, *_ = M.prefill_layer_step("fa", x_short, *lp)
    y_pad, *_ = M.prefill_layer_step("fa", x_padded, *lp)
    np.testing.assert_allclose(y_short, y_pad[:128], rtol=2e-4, atol=2e-4)


def test_decode_consistency_with_prefill(params):
    """Teacher-forcing equivalence: running the decode step over a
    sequence token-by-token must match the prefill layer output."""
    lp = layer_of(params, 0)
    s = 64
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((s, MODEL.d_model)), jnp.float32)
    y_prefill, k_pre, v_pre = M.prefill_layer_step("fa", x, *lp)

    h, dd = MODEL.n_heads, MODEL.head_dim
    k_cache = np.zeros((h, s, dd), np.float32)
    v_cache = np.zeros((h, s, dd), np.float32)
    outs = []
    for t in range(s):
        q, k_new, v_new = M.decode_qkv_step(
            x[t], jnp.asarray([t], jnp.int32), lp.norm1, lp.wq, lp.wk,
            lp.wv)
        k_cache[:, t] = np.asarray(k_new)
        v_cache[:, t] = np.asarray(v_new)
        y = M.decode_attend_step(
            x[t], q, jnp.asarray(k_cache), jnp.asarray(v_cache),
            jnp.asarray([t + 1], jnp.int32), lp.wo, lp.norm2, lp.w_ff1,
            lp.w_ff2)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(outs), y_prefill, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(k_cache, k_pre, rtol=1e-5, atol=1e-5)


def test_decode_kv_cache_roundtrip_rope(params):
    """RoPE at append time: cached keys already carry their position."""
    lp = layer_of(params, 3)
    x = jnp.ones((MODEL.d_model,), jnp.float32)
    q0, k0, _ = M.decode_qkv_step(x, jnp.asarray([0], jnp.int32),
                                  lp.norm1, lp.wq, lp.wk, lp.wv)
    q9, k9, _ = M.decode_qkv_step(x, jnp.asarray([9], jnp.int32),
                                  lp.norm1, lp.wq, lp.wk, lp.wv)
    # same input, different positions -> different rotations
    assert not np.allclose(k0, k9)
    # RoPE preserves norms
    np.testing.assert_allclose(np.linalg.norm(np.asarray(k0), axis=-1),
                               np.linalg.norm(np.asarray(k9), axis=-1),
                               rtol=1e-5)


def test_router_soft_hard_consistency(params, rparams):
    """As tau -> 0, soft routing must converge to the argmax decision."""
    toks = jnp.asarray(np.random.default_rng(2).integers(
        32, 512, (2, 128)), jnp.int32)
    key = jax.random.PRNGKey(0)
    _, r_cold = M.routed_forward_train(params, rparams, toks, key, 1e-4)
    assert np.all((np.asarray(r_cold) < 1e-3) | (np.asarray(r_cold) > 1 - 1e-3))


def test_gumbel_soft_route_range():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((8, 2)),
                         jnp.float32)
    r = M.gumbel_soft_route(key, logits, 1.0)
    assert r.shape == (8,)
    assert np.all((np.asarray(r) > 0) & (np.asarray(r) < 1))


def test_routed_forward_blends(params, rparams):
    toks = jnp.asarray(np.random.default_rng(3).integers(32, 512, (2, 128)),
                       jnp.int32)
    logits, r = M.routed_forward_train(params, rparams, toks,
                                       jax.random.PRNGKey(1), 1.0)
    assert logits.shape == (2, 128, MODEL.vocab_size)
    assert r.shape == (MODEL.n_layers, 2)


def test_hard_routed_modes_change_output(params):
    toks = jnp.asarray(np.random.default_rng(4).integers(32, 512, (1, 256)),
                       jnp.int32)
    fa = M.forward_hard_routed(params, toks, ["fa"] * MODEL.n_layers)
    sa = M.forward_hard_routed(params, toks, ["ssa"] * MODEL.n_layers)
    assert not np.allclose(fa, sa)


def test_lm_head_step(params):
    x = jnp.ones((MODEL.d_model,), jnp.float32)
    logits = M.lm_head_step(x, params.norm_f, params.embed.T)
    assert logits.shape == (MODEL.vocab_size,)


def test_cross_entropy_weighting():
    logits = jnp.zeros((1, 4, 8))
    targets = jnp.zeros((1, 4), jnp.int32)
    w_all = jnp.ones((1, 4))
    w_none = jnp.zeros((1, 4))
    assert float(M.cross_entropy(logits, targets, w_all)) > 0
    assert float(M.cross_entropy(logits, targets, w_none)) == 0


def test_pool_descriptor_matches_kernel():
    from compile.kernels import prefill_suffix_pool_pallas
    x = jnp.asarray(np.random.default_rng(5).standard_normal((256, 128)),
                    jnp.float32)
    np.testing.assert_allclose(M.pool_descriptor(x, 16),
                               prefill_suffix_pool_pallas(x, 16),
                               rtol=1e-6, atol=1e-6)
