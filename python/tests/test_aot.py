"""AOT export pipeline: HLO text lowering sanity + weight blob format."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.config import MODEL, PREFILL_BUCKETS, DECODE_KV_BUCKETS


def test_to_hlo_text_small_exe():
    text = aot.to_hlo_text(M.lm_head_step, aot.f32(MODEL.d_model),
                           aot.f32(MODEL.d_model),
                           aot.f32(MODEL.d_model, MODEL.vocab_size))
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple contract for the rust loader
    assert "ROOT" in text


def test_router_exe_lowers():
    from compile.config import ROUTER
    d, h = MODEL.d_model, ROUTER.d_hidden
    text = aot.to_hlo_text(M.router_step, aot.f32(2 * d), aot.f32(2 * d, h),
                           aot.f32(h), aot.f32(h, 2), aot.f32(2))
    assert "HloModule" in text


def test_prefill_exe_lowers_smallest_bucket():
    import functools
    d, ff = MODEL.d_model, MODEL.d_ff
    lw = [aot.f32(d), aot.f32(d, d), aot.f32(d, d), aot.f32(d, d),
          aot.f32(d, d), aot.f32(d), aot.f32(d, ff), aot.f32(ff, d)]
    text = aot.to_hlo_text(
        functools.partial(M.prefill_layer_step, "ssa"),
        aot.f32(128, d), *lw)
    assert "HloModule" in text


def test_executable_specs_cover_design_inventory():
    names = [n for n, _, _ in aot.executable_specs()]
    for s in PREFILL_BUCKETS:
        for mode in M.MODES:
            assert f"layer_{mode}_prefill_{s}" in names
    for k in DECODE_KV_BUCKETS:
        assert f"decode_attend_fa_{k}" in names
    assert "decode_attend_sa" in names
    assert "decode_qkv" in names
    assert "router" in names
    assert "lm_head" in names


def test_flat_bin_roundtrip(tmp_path):
    from compile.train import export_flat_bin
    d = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
         "b": np.ones(4, np.float32)}
    bin_path = tmp_path / "w.bin"
    man_path = tmp_path / "w.json"
    export_flat_bin(d, str(bin_path), str(man_path))
    man = json.load(open(man_path))
    blob = open(bin_path, "rb").read()
    assert len(blob) == (6 + 4) * 4
    by_name = {e["name"]: e for e in man}
    a = np.frombuffer(blob, np.float32, count=6,
                      offset=by_name["a"]["offset"]).reshape(2, 3)
    np.testing.assert_array_equal(a, d["a"])
    # manifest order is sorted and offsets are contiguous
    offs = [e["offset"] for e in man]
    assert offs == sorted(offs)


def test_sa_buf_covers_window():
    from compile.config import SPARSITY
    assert aot.SA_BUF >= SPARSITY.sa_decode_window
    assert aot.SA_BUF % 64 == 0
