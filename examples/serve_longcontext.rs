//! Long-context serving demo: starts the JSONL TCP server in-process,
//! connects as a client, and streams a set of long-context requests with
//! different policies — the paper's deployment scenario (section 3.3).
//!
//! ```bash
//! cargo run --release --example serve_longcontext
//! ```

use flux_attention::config::{MetaConfig, ServingConfig};
use flux_attention::coordinator::Coordinator;
use flux_attention::engine::EngineHandle;
use flux_attention::server::{client_request, serve, StreamClient, WireRequest};
use flux_attention::util::json::Json;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

fn main() -> anyhow::Result<()> {
    // $FLUX_ARTIFACTS (trained AOT export) or hermetic synthetic artifacts
    let artifacts = flux_attention::runtime::synthetic::ensure_default()?;
    let cfg = MetaConfig::load(&artifacts)?;
    let n_layers = cfg.model.n_layers;
    let engine = EngineHandle::spawn(artifacts)?;
    let addr = "127.0.0.1:7071";

    let coord = Coordinator::start(engine, ServingConfig::default())?;
    let server_coord = coord.clone();
    std::thread::spawn(move || {
        let _ = serve(server_coord, addr, n_layers);
    });
    std::thread::sleep(std::time::Duration::from_millis(300));

    let mut rng = Rng::seed_from_u64(7);
    let scenarios = [
        ("backbone", Task::PRe, 1024, false),
        ("flux-ssa", Task::PRe, 1024, false),
        ("flux-ssa", Task::Gov, 1024, false),
        ("flux-ta", Task::HotQA, 2040, false),
        ("flux-ssa", Task::Trec, 2040, true), // sparse decode
    ];
    println!(
        "{:<10} {:<8} {:>6} {:>4} {:>9} {:>9} {:>7}",
        "policy", "task", "ctx", "sd", "ttft_ms", "e2e_ms", "omsr"
    );
    for (policy, task, ctx, sd) in scenarios {
        let sample = generate(task, &mut rng, ctx);
        let req = WireRequest {
            prompt: sample.prompt.clone(),
            max_new: sample.answer.len() + 1,
            policy: policy.into(),
            sparse_decode: sd,
            ..Default::default()
        };
        let resp = client_request(addr, &req)?;
        if let Some(e) = &resp.error {
            println!("{policy:<10} {:<8} error: {e}", task.name());
            continue;
        }
        println!(
            "{:<10} {:<8} {:>6} {:>4} {:>9.1} {:>9.1} {:>7.2}   -> {}",
            policy,
            task.name(),
            sample.prompt.len(),
            sd as u8,
            resp.ttft_ms,
            resp.e2e_ms,
            resp.omsr,
            resp.text
        );
    }
    // --- wire protocol v2: multiplexed streams with mid-flight
    // cancellation on a single connection ---
    println!("\n-- v2 streaming: two multiplexed streams, one cancelled --");
    let client = StreamClient::connect(addr)?;
    let long = generate(Task::Gov, &mut rng, 1024);
    let short = generate(Task::PRe, &mut rng, 512);
    let victim = client.open(&WireRequest {
        prompt: long.prompt,
        max_new: 256,
        ignore_eos: true,
        ..Default::default()
    })?;
    let survivor = client.open(&WireRequest {
        prompt: short.prompt,
        max_new: short.answer.len() + 1,
        ..Default::default()
    })?;
    // let the victim stream a few tokens, then shed it
    let mut victim_tokens = 0;
    while victim_tokens < 3 {
        match victim.recv() {
            Some(j) if j.get("event").and_then(Json::as_str) == Some("token") => {
                victim_tokens += 1;
            }
            Some(_) => {}
            None => break,
        }
    }
    victim.cancel()?;
    while let Some(j) = victim.recv() {
        if j.get("event").and_then(Json::as_str) == Some("error") {
            println!(
                "victim    : cancelled after {victim_tokens} streamed tokens (kind={})",
                j.get("kind").and_then(Json::as_str).unwrap_or("?")
            );
            break;
        }
    }
    let resp = survivor.wait()?;
    if let Some(e) = &resp.error {
        anyhow::bail!("survivor stream failed: {e}");
    }
    println!(
        "survivor  : {} tokens, ttft {:.1} ms, queue {:.1} ms -> {}",
        resp.tokens.len(),
        resp.ttft_ms,
        resp.queue_ms,
        resp.text
    );

    println!("\nserver metrics: {}", coord.metrics.lock().unwrap().summary());
    Ok(())
}
