//! Routing analysis: visualize the Layer Router's per-task decisions
//! (paper Fig 4) and the router-overhead length-invariance (Fig 9)
//! directly on the serving engine.
//!
//! ```bash
//! cargo run --release --example route_analysis
//! ```

use flux_attention::engine::Engine;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::workload::{generate, Task};
use flux_attention::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // $FLUX_ARTIFACTS (trained AOT export) or hermetic synthetic artifacts
    let artifacts = flux_attention::runtime::synthetic::ensure_default()?;
    let mut engine = Engine::load(&artifacts)?;
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let n_layers = engine.cfg().model.n_layers;
    let n = 8;

    println!("FA-activation frequency per layer (dark = always FA):\n");
    print!("{:<10}", "task");
    for l in 0..n_layers {
        print!(" L{l} ");
    }
    println!("  omsr");
    for task in [Task::Qasper, Task::HotQA, Task::PRe, Task::Gov, Task::Trec, Task::Lcc] {
        let mut counts = vec![0usize; n_layers];
        let mut omsr = 0.0;
        let mut rng = Rng::seed_from_u64(task as u64);
        for _ in 0..n {
            let s = generate(task, &mut rng, 512);
            let (id, report) = engine.prefill(&s.prompt, &policy, "balanced")?;
            engine.release(id);
            omsr += report.omsr / n as f64;
            for (c, m) in counts.iter_mut().zip(&report.modes) {
                *c += (*m == AttnMode::Fa) as usize;
            }
        }
        print!("{:<10}", task.name());
        for &c in &counts {
            let f = c as f64 / n as f64;
            let glyph = match (f * 4.0).round() as usize {
                0 => " . ",
                1 => " - ",
                2 => " + ",
                3 => " * ",
                _ => " # ",
            };
            print!("{glyph} ");
        }
        println!("  {omsr:.2}");
    }

    println!("\nrouter overhead (ms per layer) vs context length:");
    let max_prefill = *engine.cfg().prefill_buckets.last().unwrap();
    for seq in [128usize, 256, 512, 1024, 2040] {
        if seq > max_prefill {
            continue; // synthetic bucket ledger tops out below the AOT export
        }
        let mut rng = Rng::seed_from_u64(99);
        let s = generate(Task::PRe, &mut rng, seq);
        let (id, report) = engine.prefill(&s.prompt, &policy, "balanced")?;
        engine.release(id);
        println!(
            "  ctx {:>5}: {:.4} ms/layer",
            seq,
            report.router_us as f64 / 1e3 / n_layers as f64
        );
    }
    Ok(())
}
