//! End-to-end quickstart: load the trained artifacts, serve a batch of
//! mixed-category requests through the full coordinator (continuous
//! batching + dynamic layer routing), and report accuracy, latency,
//! throughput and routing decisions.
//!
//! This is the repo's end-to-end validation driver (EXPERIMENTS.md §E2E):
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use flux_attention::config::ServingConfig;
use flux_attention::coordinator::{Coordinator, Request};
use flux_attention::engine::EngineHandle;
use flux_attention::eval::exact_match;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::tokenizer::Tokenizer;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

fn main() -> anyhow::Result<()> {
    // $FLUX_ARTIFACTS (trained AOT export) or hermetic synthetic artifacts
    let artifacts = flux_attention::runtime::synthetic::ensure_default()?;
    eprintln!("loading engine from {artifacts:?} ...");
    let engine = EngineHandle::spawn(artifacts)?;
    let tok = Tokenizer::new();
    let coord = Coordinator::start(engine, ServingConfig::default())?;

    // a mixed batch: retrieval-intensive + context-holistic tasks
    let tasks = [
        Task::PRe,
        Task::Qasper,
        Task::HotQA,
        Task::Gov,
        Task::Trec,
        Task::Lcc,
        Task::PRe,
        Task::Gov,
    ];
    let mut rng = Rng::seed_from_u64(2026);
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };

    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for &task in &tasks {
        let sample = generate(task, &mut rng, 512);
        let coord = coord.clone();
        let policy = policy.clone();
        let answer = sample.answer.clone();
        handles.push((
            task,
            answer,
            std::thread::spawn(move || {
                coord.submit(Request {
                    max_new: sample.answer.len() + 1,
                    prompt: sample.prompt,
                    policy,
                    ..Default::default()
                })
            }),
        ));
    }

    println!(
        "{:<8} {:>8} {:>9} {:>9} {:>6}  {:<22} routing",
        "task", "ttft_ms", "e2e_ms", "dec_ms/t", "omsr", "answer"
    );
    let mut correct = 0usize;
    let n = handles.len();
    for (task, answer, h) in handles {
        let r = h.join().expect("thread")?;
        let ok = exact_match(&r.tokens, &answer);
        correct += ok as usize;
        println!(
            "{:<8} {:>8.1} {:>9.1} {:>9.2} {:>6.2}  {:<22} {}",
            task.name(),
            r.ttft_us as f64 / 1e3,
            r.e2e_us as f64 / 1e3,
            r.decode_us_per_token / 1e3,
            r.omsr,
            format!("{} [{}]", tok.decode(&r.tokens), if ok { "OK" } else { "MISS" }),
            r.modes.join(","),
        );
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!("\n{}", coord.metrics.lock().unwrap().summary());
    println!(
        "accuracy {}/{}  wall {:.1}s  ({:.2} req/s)",
        correct,
        n,
        elapsed,
        n as f64 / elapsed
    );
    Ok(())
}
