//! Sparsity sweep: the accuracy/latency trade-off across static Omega
//! levels and the FluxAttention dynamic policy — a runnable version of
//! the paper's motivating experiment (section 2.3 / Fig 1a) on live
//! serving hardware.
//!
//! ```bash
//! cargo run --release --example sparsity_sweep
//! ```

use flux_attention::baselines::entropy_ranked_modes;
use flux_attention::engine::Engine;
use flux_attention::eval::{experiments::entropy_scores, run_task};
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::workload::Task;

fn main() -> anyhow::Result<()> {
    // $FLUX_ARTIFACTS (trained AOT export) or hermetic synthetic artifacts
    let artifacts = flux_attention::runtime::synthetic::ensure_default()?;
    let mut engine = Engine::load(&artifacts)?;
    let seq_len = 512;
    let n = 4;
    let scores = entropy_scores(&mut engine, seq_len)?;
    println!("layer entropy scores: {scores:.3?}\n");

    println!(
        "{:<14} {:>6} {:>9} {:>9} {:>11} {:>11}",
        "policy", "omega", "pre_acc", "gov_acc", "prefill_ms", "kv_bytes"
    );
    for omega in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let modes = entropy_ranked_modes(&scores, omega, AttnMode::Ssa);
        let policy = Policy::Static { modes, decode: DecodeMode::Sparse };
        let r1 = run_task(&mut engine, Task::PRe, &policy, "balanced", n, seq_len, 1)?;
        let r2 = run_task(&mut engine, Task::Gov, &policy, "balanced", n, seq_len, 2)?;
        println!(
            "{:<14} {:>6.2} {:>9.1} {:>9.1} {:>11.1} {:>11.0}",
            "entropy-static", omega, r1.acc, r2.acc, r1.prefill_ms, r1.kv_bytes
        );
    }
    let flux = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };
    let r1 = run_task(&mut engine, Task::PRe, &flux, "balanced", n, seq_len, 1)?;
    let r2 = run_task(&mut engine, Task::Gov, &flux, "balanced", n, seq_len, 2)?;
    println!(
        "{:<14} {:>6.2} {:>9.1} {:>9.1} {:>11.1} {:>11.0}   (dynamic, per-request)",
        "flux-ssa", (r1.omsr + r2.omsr) / 2.0, r1.acc, r2.acc, r1.prefill_ms, r1.kv_bytes
    );
    Ok(())
}
