//! Fig 9 bench: Layer-Router overhead (pooling + router executable) per
//! layer across context lengths — the paper's claim is ~0.2 ms/layer and
//! length-invariant from 512 to 1M tokens; here the descriptor is fixed
//! (2 d_model) so invariance is structural, and we measure it up to 1M
//! rows of synthetic hidden state. The router MLP sits below the
//! reference backend's parallelism threshold, so these numbers are
//! single-threaded regardless of FLUX_THREADS.

use flux_attention::engine::Engine;
use flux_attention::router::pool_descriptor;
use flux_attention::runtime::HostTensor;
use flux_attention::util::bench::Bench;

fn main() {
    // $FLUX_ARTIFACTS when populated, otherwise hermetic synthetic
    // artifacts on the RefBackend — the bench always runs.
    let dir = flux_attention::runtime::synthetic::ensure_default().expect("artifacts");
    let mut engine = Engine::load(&dir).expect("engine load");
    let d = engine.cfg().model.d_model;
    let pool = engine.cfg().sparsity.pool_size;

    // pooling alone (host-side) across sequence lengths: O(pool * d)
    let mut b = Bench::new("router_overhead");
    for s in [512usize, 8_192, 65_536, 1_048_576] {
        let hidden = HostTensor::zeros(vec![s, d]);
        b.run(&format!("pooling/{s}"), 5, 50, || pool_descriptor(&hidden, s, pool));
    }

    // full routing step: pooling + router executable, per layer
    for s in [512usize, 8_192, 65_536, 1_048_576] {
        let hidden = HostTensor::zeros(vec![s, d]);
        b.run(&format!("router_step/{s}"), 3, 30, || {
            let desc = pool_descriptor(&hidden, s, pool);
            let net = engine.routers.get("balanced").expect("router");
            net.route(&mut *engine.rt, 0, &desc).expect("route")
        });
    }
    b.save();
}
