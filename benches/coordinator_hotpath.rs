//! L3 hot-path microbenchmarks (the §Perf profiling targets): everything
//! the coordinator does *around* executable execution — KV re-bucketing,
//! literal conversion, ring-buffer compaction, pooling, argmax. The
//! perf target is that this overhead stays <10% of executable time.
//! Needs no artifacts (pure host-side substrate work).

use flux_attention::kvcache::{FullCache, SparseCache};
use flux_attention::model::argmax;
use flux_attention::router::pool_descriptor;
use flux_attention::runtime::HostTensor;
use flux_attention::util::bench::Bench;

fn main() {
    let (h, d) = (4usize, 32usize);
    let mut b = Bench::new("coordinator_hotpath");

    // full-cache re-bucketing (the legacy cloning path) vs the
    // zero-copy view staging the decode fast path uses
    for len in [256usize, 1024, 2048] {
        let mut cache = FullCache::new(h, d, len);
        for _ in 0..len {
            cache.append(&vec![1.0; h * d], &vec![2.0; h * d]);
        }
        b.run(&format!("kv_as_tensors/full/{len}"), 3, 50, || cache.as_tensors(len));
        b.run(&format!("kv_view/full/{len}"), 3, 200, || {
            let (kt, vt) = cache.view();
            kt.data.len() + vt.data.len()
        });
    }
    let mut sc = SparseCache::new(h, d, 16, 128, 192);
    for _ in 0..500 {
        sc.append(&vec![1.0; h * d], &vec![2.0; h * d]);
    }
    b.run("kv_as_tensors/sparse", 3, 100, || sc.as_tensors());
    b.run("kv_view/sparse", 3, 200, || {
        let (kt, vt, valid) = sc.view();
        kt.data.len() + vt.data.len() + valid
    });

    // host-tensor materialization of decode-sized arguments (the
    // backend-boundary copy that replaced per-call literal conversion)
    for len in [192usize, 2048] {
        let t = HostTensor::zeros(vec![h, len, d]);
        b.run(&format!("tensor_clone/{len}"), 3, 100, || t.clone());
    }

    // pooling + argmax (per-layer / per-token host work)
    let hidden = HostTensor::zeros(vec![2048, 128]);
    b.run("pool_descriptor/2048", 5, 200, || pool_descriptor(&hidden, 2048, 16));
    let logits = vec![0.5f32; 512];
    b.run("argmax/512", 5, 500, || argmax(&logits));

    // cache append (per-layer per-token)
    let mut cache = FullCache::new(h, d, 2048);
    let k = vec![1.0f32; h * d];
    b.run("full_cache_append", 5, 500, || {
        if cache.len() >= 2048 {
            cache = FullCache::new(h, d, 2048);
        }
        cache.append(&k, &k)
    });
    let mut scache = SparseCache::new(h, d, 16, 128, 192);
    b.run("sparse_cache_append", 5, 500, || scache.append(&k, &k));

    b.save();
}
