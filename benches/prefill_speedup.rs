//! Fig 3(a) bench: end-to-end prefill latency per attention mode across
//! context buckets. The dense/FA row is the 1.0x baseline; the mode/FA
//! latency ratios give the speedup series of the paper's figure.
//!
//! Uses `$FLUX_ARTIFACTS` when populated, otherwise hermetic synthetic
//! artifacts on the pure-Rust RefBackend.

use flux_attention::engine::Engine;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::util::bench::Bench;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

fn main() {
    // $FLUX_ARTIFACTS when populated, otherwise hermetic synthetic
    // artifacts on the RefBackend — the bench always runs.
    let dir = flux_attention::runtime::synthetic::ensure_default().expect("artifacts");
    let mut engine = Engine::load(&dir).expect("engine load");
    let n_layers = engine.cfg().model.n_layers;
    let max_prefill = *engine.cfg().prefill_buckets.last().unwrap();
    let mut b = Bench::new("prefill");
    for seq in [128usize, 512, 2040] {
        if seq > max_prefill {
            eprintln!("  (skipping ctx {seq}: exceeds max prefill bucket {max_prefill})");
            continue;
        }
        let mut rng = Rng::seed_from_u64(1);
        let sample = generate(Task::PRe, &mut rng, seq);
        for mode in [AttnMode::Fa, AttnMode::Ssa, AttnMode::Ta, AttnMode::Xa] {
            let policy =
                Policy::Static { modes: vec![mode; n_layers], decode: DecodeMode::Dense };
            let iters = if seq > 1024 { 3 } else { 5 };
            b.run(&format!("prefill/{}/{}", mode.name(), seq), 1, iters, || {
                let (id, _) =
                    engine.prefill(&sample.prompt, &policy, "balanced").expect("prefill");
                engine.release(id);
            });
        }

        // single-worker reference point: the parallel-kernel speedup
        // series (outputs are bit-identical for every worker count)
        engine.set_threads(1);
        let policy = Policy::Static { modes: vec![AttnMode::Fa; n_layers], decode: DecodeMode::Dense };
        let iters = if seq > 1024 { 3 } else { 5 };
        b.run(&format!("prefill/fa_1thread/{seq}"), 1, iters, || {
            let (id, _) = engine.prefill(&sample.prompt, &policy, "balanced").expect("prefill");
            engine.release(id);
        });
        engine.set_threads(flux_attention::runtime::flux_threads_default());
    }
    b.save();
}
