//! Fig 3(b) / Fig 1(b) bench: per-token decode latency, full-KV dense
//! decode vs the sink+local sparse decode, across KV lengths. The
//! dense/sparse ratio is the paper's kernel-level decode speedup series.

use flux_attention::engine::Engine;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::util::bench::Bench;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

fn main() {
    // $FLUX_ARTIFACTS when populated, otherwise hermetic synthetic
    // artifacts on the RefBackend — the bench always runs.
    let dir = flux_attention::runtime::synthetic::ensure_default().expect("artifacts");
    let mut engine = Engine::load(&dir).expect("engine load");
    let n_layers = engine.cfg().model.n_layers;
    // stay inside the artifact bucket ledger (synthetic tops out lower
    // than the full AOT export)
    let max_prefill = *engine.cfg().prefill_buckets.last().unwrap();
    let max_decode = *engine.cfg().decode_kv_buckets.last().unwrap();
    let mut b = Bench::new("decode");
    for seq in [256usize, 512, 1024, 2000] {
        if seq > max_prefill || seq + 16 > max_decode {
            eprintln!(
                "  (skipping kv {seq}: exceeds bucket ledger, prefill max {max_prefill} / decode max {max_decode})"
            );
            continue;
        }
        let mut rng = Rng::seed_from_u64(2);
        let sample = generate(Task::PRe, &mut rng, seq);

        let (id, _) =
            engine.prefill(&sample.prompt, &Policy::Backbone, "balanced").expect("prefill");
        let dense = b.run(&format!("decode/dense/{seq}"), 2, 10, || {
            engine.decode_step(id).expect("decode")
        });
        engine.release(id);

        // legacy cloning path on the same shape: the zero-copy delta
        engine.set_zero_copy(false);
        let (id, _) =
            engine.prefill(&sample.prompt, &Policy::Backbone, "balanced").expect("prefill");
        let cloned = b.run(&format!("decode/dense_clone/{seq}"), 2, 10, || {
            engine.decode_step(id).expect("decode")
        });
        engine.release(id);
        engine.set_zero_copy(true);
        println!(
            "  -> kv {seq}: zero-copy staging speedup {:.2}x",
            cloned.mean_us / dense.mean_us.max(1e-9)
        );

        let sparse_policy = Policy::Static {
            modes: vec![AttnMode::Ssa; n_layers],
            decode: DecodeMode::Sparse,
        };
        let (id, _) =
            engine.prefill(&sample.prompt, &sparse_policy, "balanced").expect("prefill");
        let sparse = b.run(&format!("decode/sparse/{seq}"), 2, 10, || {
            engine.decode_step(id).expect("decode")
        });
        engine.release(id);

        println!(
            "  -> kv {seq}: layer-level sparse decode speedup {:.2}x",
            dense.mean_us / sparse.mean_us.max(1e-9)
        );
    }
    b.save();
}
