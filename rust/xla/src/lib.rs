//! Type-level stub of the `xla` (PJRT C API) crate.
//!
//! This container has no XLA/PJRT native library, so the real `xla`
//! crate cannot be vendored. This stub exposes the exact API surface
//! `flux_attention::runtime::pjrt` uses, letting `--features pjrt`
//! type-check and build everywhere; every fallible entry point returns
//! an error at runtime (`PjRtClient::cpu()` fails first, so the PJRT
//! backend reports a clear message instead of silently "running").
//!
//! To run against real PJRT, point the `xla` path dependency in
//! rust/Cargo.toml at the real crate (plus the xla_extension C library)
//! — the signatures below match its usage in runtime/pjrt.rs.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>() -> Result<T, Error> {
    Err(Error(
        "xla stub: built without a real PJRT library (see DESIGN.md §3: \
         replace the in-tree `xla` path dependency with the real crate)"
            .to_string(),
    ))
}

/// Opaque host literal. Carries no data in the stub.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_vals: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        stub_err()
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        stub_err()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err()
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        Vec::new()
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Error> {
        stub_err()
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Always errors in the stub: the PJRT backend fails fast at
    /// construction rather than pretending to execute.
    pub fn cpu() -> Result<Self, Error> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err()
    }
}
