//! Static sparse-attention baselines (paper Tables 1-2 comparison rows)
//! and the UnComp-style matrix-entropy layer profiler (paper section 2.3
//! / Appendix C) used for Fig 1a's progressive sparsification.
//!
//! Baselines are *layerised* versions of the head-level originals — the
//! substitution the paper itself makes when comparing at matched
//! Omega_MSR (DESIGN.md section 2):
//!   * DuoAttention-like: entropy-profiled retrieval layers keep FA, the
//!     rest stream (SSA), fixed ratio 0.5.
//!   * PruLong-like: same identification, but alternating assignment
//!     bias toward early layers (its learned masks concentrate retrieval
//!     capacity early).
//!   * TriangleMix: dense shallow layers, Triangle attention deep
//!     layers (the paper's static heuristic comparator).

use crate::router::AttnMode;

/// Symmetric Jacobi eigenvalue solver (d x d). The substrate for the
/// matrix-entropy score — no LAPACK in this environment, so we build it.
pub fn jacobi_eigenvalues(mat: &[f64], d: usize, sweeps: usize) -> Vec<f64> {
    assert_eq!(mat.len(), d * d);
    let mut a = mat.to_vec();
    for _ in 0..sweeps {
        let mut off = 0.0;
        for p in 0..d {
            for q in (p + 1)..d {
                off += a[p * d + q] * a[p * d + q];
            }
        }
        if off < 1e-18 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = a[p * d + q];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let app = a[p * d + p];
                let aqq = a[q * d + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..d {
                    let aip = a[i * d + p];
                    let aiq = a[i * d + q];
                    a[i * d + p] = c * aip - s * aiq;
                    a[i * d + q] = s * aip + c * aiq;
                }
                for i in 0..d {
                    let api = a[p * d + i];
                    let aqi = a[q * d + i];
                    a[p * d + i] = c * api - s * aqi;
                    a[q * d + i] = s * api + c * aqi;
                }
            }
        }
    }
    (0..d).map(|i| a[i * d + i]).collect()
}

/// UnComp matrix entropy of hidden states `(s, d)` (paper eq. 7):
/// von Neumann entropy of the trace-normalized covariance, truncated to
/// the top-K eigenvalues.
pub fn matrix_entropy(hidden: &[f32], s: usize, d: usize, top_k: usize) -> f64 {
    assert_eq!(hidden.len(), s * d);
    // covariance (d x d) = X^T X (s >> d here, so d x d is the cheap side)
    let mut cov = vec![0f64; d * d];
    for t in 0..s {
        let row = &hidden[t * d..(t + 1) * d];
        for i in 0..d {
            let xi = row[i] as f64;
            for j in i..d {
                cov[i * d + j] += xi * row[j] as f64;
            }
        }
    }
    for i in 0..d {
        for j in 0..i {
            cov[i * d + j] = cov[j * d + i];
        }
    }
    let trace: f64 = (0..d).map(|i| cov[i * d + i]).sum();
    if trace <= 0.0 {
        return 0.0;
    }
    for x in cov.iter_mut() {
        *x /= trace;
    }
    let mut ev = jacobi_eigenvalues(&cov, d, 12);
    ev.sort_by(|a, b| b.partial_cmp(a).unwrap());
    ev.truncate(top_k);
    -ev.iter().filter(|&&l| l > 1e-12).map(|&l| l * l.ln()).sum::<f64>()
}

/// Progressive entropy-ranked sparsification (paper Appendix C.2):
/// keep the top-`k = floor((1 - omega) * L)` entropy layers as FA,
/// replace the rest with `sa_mode`.
pub fn entropy_ranked_modes(scores: &[f64], omega: f64, sa_mode: AttnMode) -> Vec<AttnMode> {
    let l = scores.len();
    let keep_fa = ((1.0 - omega) * l as f64).floor() as usize;
    let mut idx: Vec<usize> = (0..l).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut modes = vec![sa_mode; l];
    for &i in idx.iter().take(keep_fa) {
        modes[i] = AttnMode::Fa;
    }
    modes
}

/// DuoAttention-like static allocation at Omega = 0.5.
pub fn duo_attention_modes(scores: &[f64]) -> Vec<AttnMode> {
    entropy_ranked_modes(scores, 0.5, AttnMode::Ssa)
}

/// PruLong-like: Omega = 0.5 with an early-layer retrieval bias — the
/// first quarter of layers is always FA, the remaining FA budget goes
/// to the highest-entropy layers.
pub fn prulong_modes(scores: &[f64]) -> Vec<AttnMode> {
    let l = scores.len();
    let keep_fa = l / 2;
    let forced = (l / 4).max(1);
    let mut modes = vec![AttnMode::Ssa; l];
    for m in modes.iter_mut().take(forced) {
        *m = AttnMode::Fa;
    }
    let mut idx: Vec<usize> = (forced..l).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    for &i in idx.iter().take(keep_fa.saturating_sub(forced)) {
        modes[i] = AttnMode::Fa;
    }
    modes
}

/// TriangleMix: dense shallow half, Triangle attention deep half.
pub fn trianglemix_modes(n_layers: usize) -> Vec<AttnMode> {
    (0..n_layers)
        .map(|i| if i < n_layers / 2 { AttnMode::Fa } else { AttnMode::Ta })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_diagonalizes_known_matrix() {
        // [[2, 1], [1, 2]] -> eigenvalues 1, 3
        let mut ev = jacobi_eigenvalues(&[2.0, 1.0, 1.0, 2.0], 2, 10);
        ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((ev[0] - 1.0).abs() < 1e-9);
        assert!((ev[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn jacobi_identity() {
        let d = 4;
        let mut m = vec![0.0; d * d];
        for i in 0..d {
            m[i * d + i] = (i + 1) as f64;
        }
        let mut ev = jacobi_eigenvalues(&m, d, 4);
        ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, e) in ev.iter().enumerate() {
            assert!((e - (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn entropy_rank_ordering() {
        // rank-1 hidden states -> ~zero entropy; iid noise -> high
        let s = 64;
        let d = 8;
        let rank1: Vec<f32> = (0..s * d).map(|i| ((i / d) as f32 + 1.0)).collect();
        let mut noise = vec![0f32; s * d];
        let mut state = 12345u64;
        for x in noise.iter_mut() {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            *x = ((state >> 33) as f32 / 2e9) - 1.0;
        }
        let e_low = matrix_entropy(&rank1, s, d, d);
        let e_high = matrix_entropy(&noise, s, d, d);
        assert!(e_high > e_low + 0.5, "high {e_high} low {e_low}");
    }

    #[test]
    fn entropy_ranked_keeps_top_layers_fa() {
        let scores = vec![0.1, 0.9, 0.5, 0.7];
        let modes = entropy_ranked_modes(&scores, 0.5, AttnMode::Ssa);
        assert_eq!(modes[1], AttnMode::Fa);
        assert_eq!(modes[3], AttnMode::Fa);
        assert_eq!(modes[0], AttnMode::Ssa);
        assert_eq!(modes[2], AttnMode::Ssa);
    }

    #[test]
    fn omega_extremes() {
        let scores = vec![0.5; 8];
        assert!(entropy_ranked_modes(&scores, 0.0, AttnMode::Ssa)
            .iter()
            .all(|m| *m == AttnMode::Fa));
        assert!(entropy_ranked_modes(&scores, 1.0, AttnMode::Ssa)
            .iter()
            .all(|m| *m == AttnMode::Ssa));
    }

    #[test]
    fn prulong_forces_early_layers() {
        let scores = vec![0.0, 0.0, 0.9, 0.9, 0.9, 0.9, 0.1, 0.1];
        let modes = prulong_modes(&scores);
        assert_eq!(modes[0], AttnMode::Fa);
        assert_eq!(modes[1], AttnMode::Fa);
        assert_eq!(modes.iter().filter(|m| **m == AttnMode::Fa).count(), 4);
    }

    #[test]
    fn trianglemix_split() {
        let m = trianglemix_modes(8);
        assert!(m[..4].iter().all(|x| *x == AttnMode::Fa));
        assert!(m[4..].iter().all(|x| *x == AttnMode::Ta));
    }
}
