//! `flux` — the FluxAttention serving CLI (hand-rolled argument parsing;
//! no clap in the offline vendor set).
//!
//! Usage:
//!   flux [--artifacts DIR] serve [--addr HOST:PORT] [--deadline-ms N]
//!                                [--chunk-tokens N] [--chunk-budget N]
//!                                [--round-timeout-ms N] [--restart-max N]
//!                                [--restart-backoff-ms N] [--drain-ms N]
//!                                [--prefix-cache] [--prefix-cache-pages N]
//!                                [--replicas R] [--queue-high-watermark N]
//!                                [--queue-low-watermark N]
//!                                [--admission-mode worst-case|optimistic]
//!                                [--optimistic-percent P] [--max-preemptions N]
//!        (chunk-tokens 0 = monolithic prefill; default 128 interleaves
//!        prefill chunks with batched decode rounds, DESIGN.md §10;
//!        round-timeout-ms arms the engine-round watchdog, restart-*
//!        bound engine respawns after a crash, and SIGINT/SIGTERM
//!        drain in-flight streams for up to drain-ms before exit,
//!        DESIGN.md §12; prefix-cache enables cross-request KV reuse
//!        of shared prompt prefixes, capped at prefix-cache-pages pool
//!        pages — default half the pool — DESIGN.md §13; replicas R
//!        serves R data-parallel engines, each its own failure domain,
//!        dispatched least-loaded with session affinity; the queue
//!        watermarks reject `overloaded (queue_watermark)` when every
//!        replica's queue is above high until it drains to low —
//!        DESIGN.md §14; admission-mode optimistic charges
//!        optimistic-percent% of the worst-case KV pages at admission
//!        and preempts-and-resumes streams when the pool actually runs
//!        dry, max-preemptions bounding starvation — DESIGN.md §15)
//!   flux [--artifacts DIR] generate [--task T] [--seq-len N]
//!                                   [--policy P] [--router R] [--sparse-decode]
//!                                   [--stream] [--deadline-ms N]
//!   flux [--artifacts DIR] experiment <id> [--n N] [--seq-len N]
//!        ids: fig1a fig1b table1 table2 fig3 fig4 fig5 fig8 fig9 cases kvmem curves route_ledger all
//!   flux [--artifacts DIR] bench-serve [--requests N] [--seq-len N]
//!                                      [--rate R] [--policy P]
//!   flux [--artifacts DIR] bench [--smoke] [--seq-len N] [--tokens N]
//!                                [--threads N] [--out DIR]
//!        (includes the batched-decode batch-size sweep; serving honors
//!        FLUX_BATCH_DECODE=0 to force the serial per-request walk)
//!   flux [--artifacts DIR] synth [--seed N]
//!   flux [--artifacts DIR] info
//!
//! `synth` writes a deterministic synthetic artifact set (RefBackend
//! manifest + weights + balanced router) into the artifacts dir, so
//! every other subcommand runs hermetically without `make artifacts`.

#![allow(clippy::needless_range_loop)]

use std::path::PathBuf;

use anyhow::Result;

use flux_attention::config::{MetaConfig, ServingConfig};
use flux_attention::coordinator::{Coordinator, Request, SessionEvent};
use flux_attention::engine::{Engine, EngineHandle};
use flux_attention::eval::experiments;
use flux_attention::server;
use flux_attention::tokenizer::Tokenizer;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{self, Task};

/// Trivial flag parser: --key value / --key (bool) / positionals.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = vec![];
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `Some(parsed)` when the flag is present and parses, else `None`.
    fn get_opt_u64(&self, key: &str) -> Option<u64> {
        self.flags.get(key).and_then(|v| v.parse().ok())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_task(s: &str) -> Result<Task> {
    Ok(match s {
        "qasper" => Task::Qasper,
        "mfen" | "mf-en" => Task::MFen,
        "hotqa" => Task::HotQA,
        "2wiki" | "wiki2" => Task::Wiki2,
        "gov" => Task::Gov,
        "mnews" | "m.news" => Task::MNews,
        "trec" => Task::Trec,
        "tqa" => Task::Tqa,
        "sams" => Task::Sams,
        "pcount" => Task::PCount,
        "pre" => Task::PRe,
        "rbp" | "rb-p" => Task::Rbp,
        "lcc" => Task::Lcc,
        "ruler" => Task::Ruler,
        "lbv2e" => Task::Lbv2Easy,
        "lbv2h" => Task::Lbv2Hard,
        "gsm" | "gsm8k" => Task::Gsm,
        "aime" | "aime24" => Task::Aime,
        other => anyhow::bail!("unknown task {other}"),
    })
}

/// Signal-to-drain bridge for `flux serve`: the handler only flips this
/// flag (async-signal-safe); a watcher thread does the actual drain.
static SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Route SIGINT/SIGTERM to [`on_signal`] via the libc already linked
/// into every binary (no signal crate in the offline vendor set).
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let handler = on_signal as extern "C" fn(i32) as usize;
    unsafe {
        signal(SIGINT, handler);
        signal(SIGTERM, handler);
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("flux: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");

    match cmd {
        "serve" => {
            let cfg = MetaConfig::load(&artifacts)?;
            let defaults = ServingConfig::default();
            let replicas = args.get_usize("replicas", defaults.replicas).max(1);
            let scfg = ServingConfig {
                default_deadline_ms: args.get_opt_u64("deadline-ms"),
                prefill_chunk_tokens: args
                    .get_usize("chunk-tokens", defaults.prefill_chunk_tokens),
                prefill_chunk_budget: args
                    .get_usize("chunk-budget", defaults.prefill_chunk_budget),
                engine_round_timeout_ms: args
                    .get_opt_u64("round-timeout-ms")
                    .or(defaults.engine_round_timeout_ms),
                engine_restart_max: args.get_usize("restart-max", defaults.engine_restart_max),
                engine_restart_backoff_ms: args
                    .get_opt_u64("restart-backoff-ms")
                    .unwrap_or(defaults.engine_restart_backoff_ms),
                prefix_cache: args.has("prefix-cache"),
                prefix_cache_pages: args
                    .get_opt_u64("prefix-cache-pages")
                    .map(|v| v as usize),
                replicas,
                queue_high_watermark: args
                    .get_opt_u64("queue-high-watermark")
                    .map(|v| v as usize),
                queue_low_watermark: args
                    .get_opt_u64("queue-low-watermark")
                    .map(|v| v as usize),
                // route-aware optimistic admission + preemption
                // (DESIGN.md §15): worst-case unless opted in
                admission_mode: match args.get("admission-mode", "worst-case").as_str() {
                    "worst-case" => flux_attention::config::AdmissionMode::WorstCase,
                    "optimistic" => flux_attention::config::AdmissionMode::Optimistic {
                        factor: args
                            .get_opt_u64("optimistic-percent")
                            .map_or(0.5, |p| p as f64 / 100.0),
                    },
                    other => anyhow::bail!(
                        "unknown --admission-mode '{other}' (worst-case | optimistic)"
                    ),
                },
                max_preemptions: args
                    .get_opt_u64("max-preemptions")
                    .map_or(defaults.max_preemptions, |v| v as u32),
                ..Default::default()
            };
            // R data-parallel engine replicas, each its own failure
            // domain (backend + KV pool + optional prefix cache)
            let engines = (0..replicas)
                .map(|i| EngineHandle::spawn_from_env_replica(artifacts.clone(), i))
                .collect::<Result<Vec<_>>>()?;
            let coord = Coordinator::start_replicas(engines, scfg)?;
            let drain_ms = args.get_opt_u64("drain-ms").unwrap_or(30_000);
            install_signal_handlers();
            {
                let coord = coord.clone();
                std::thread::spawn(move || {
                    use std::sync::atomic::Ordering;
                    while !SHUTDOWN.load(Ordering::SeqCst) {
                        std::thread::sleep(std::time::Duration::from_millis(100));
                    }
                    eprintln!("flux: signal received, draining in-flight streams (up to {drain_ms} ms)");
                    let clean = coord.drain(std::time::Duration::from_millis(drain_ms));
                    if clean {
                        eprintln!("flux: drain complete");
                    } else {
                        eprintln!("flux: drain deadline exceeded, exiting with streams in flight");
                    }
                    // give session pump threads a beat to flush their
                    // terminal frames onto the sockets
                    std::thread::sleep(std::time::Duration::from_millis(200));
                    std::process::exit(if clean { 0 } else { 1 });
                });
            }
            server::serve(coord, &args.get("addr", "127.0.0.1:7070"), cfg.model.n_layers)
        }
        "generate" => {
            let tok = Tokenizer::new();
            let mut rng = Rng::seed_from_u64(args.get_usize("seed", 0) as u64);
            let task = parse_task(&args.get("task", "pre"))?;
            let sample = workload::generate(task, &mut rng, args.get_usize("seq-len", 256));
            if args.has("stream") {
                return generate_streaming(&args, artifacts, task, &sample, &tok);
            }
            let mut engine = Engine::load(&artifacts)?;
            let pol = server::parse_policy(
                &args.get("policy", "flux-ssa"),
                args.has("sparse-decode"),
                engine.cfg().model.n_layers,
            )?;
            let (gen, report) =
                engine.generate(&sample.prompt, &pol, &args.get("router", "balanced"),
                                sample.answer.len() + 1)?;
            println!("task      : {}", task.name());
            println!("prompt    : {} tokens (bucket {})", report.prompt_len, report.bucket);
            println!(
                "routing   : {:?}",
                report.modes.iter().map(|m| m.name()).collect::<Vec<_>>()
            );
            println!("omsr      : {:.2}", report.omsr);
            println!(
                "prefill   : {:.1} ms (router {:.2} ms)",
                report.total_us as f64 / 1e3,
                report.router_us as f64 / 1e3
            );
            println!("generated : {}", tok.decode(&gen));
            println!("expected  : {}", tok.decode(&sample.answer));
            println!("correct   : {}", flux_attention::eval::exact_match(&gen, &sample.answer));
            Ok(())
        }
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .map(String::as_str)
                .ok_or_else(|| anyhow::anyhow!("experiment id required"))?;
            let mut engine = Engine::load(&artifacts)?;
            run_experiment(
                &mut engine,
                id,
                args.get_usize("n", 6),
                args.get_usize("seq-len", 256),
            )
        }
        "bench-serve" => {
            let cfg = MetaConfig::load(&artifacts)?;
            let n_layers = cfg.model.n_layers;
            let engine = EngineHandle::spawn(artifacts.clone())?;
            let coord = Coordinator::start(engine, ServingConfig::default())?;
            let tasks = [Task::PRe, Task::Gov, Task::HotQA, Task::Trec];
            let trace = workload::poisson_trace(
                3,
                &tasks,
                args.get_usize("requests", 16),
                args.get_usize("seq-len", 256),
                args.get_f64("rate", 20.0),
            );
            let n_requests = trace.len();
            let policy_str = args.get("policy", "flux-ssa");
            let t0 = std::time::Instant::now();
            let mut handles = vec![];
            for entry in trace {
                let coord = coord.clone();
                let pol = server::parse_policy(&policy_str, false, n_layers)?;
                handles.push(std::thread::spawn(move || {
                    let wait = entry.arrival_ms.saturating_sub(t0.elapsed().as_millis() as u64);
                    std::thread::sleep(std::time::Duration::from_millis(wait));
                    coord.submit(Request {
                        max_new: entry.sample.answer.len() + 1,
                        prompt: entry.sample.prompt,
                        policy: pol,
                        ..Default::default()
                    })
                }));
            }
            let mut ok = 0usize;
            for h in handles {
                if h.join().map(|r| r.is_ok()).unwrap_or(false) {
                    ok += 1;
                }
            }
            let elapsed = t0.elapsed().as_secs_f64();
            println!("{}", coord.metrics.lock().unwrap().summary());
            println!(
                "completed {ok}/{n_requests} in {elapsed:.1}s ({:.2} req/s)",
                ok as f64 / elapsed
            );
            Ok(())
        }
        "bench" => {
            // hermetic: fall back to synthetic artifacts when the
            // requested directory has no manifest (CI smoke path)
            let dir = if artifacts.join("manifest.json").exists() {
                artifacts
            } else {
                flux_attention::runtime::synthetic::ensure_default()?
            };
            let defaults = flux_attention::util::bench::ServingBenchOpts::default();
            let opts = flux_attention::util::bench::ServingBenchOpts {
                seq_len: args.get_usize("seq-len", defaults.seq_len),
                decode_tokens: args.get_usize("tokens", defaults.decode_tokens),
                threads: args.get_usize("threads", defaults.threads),
                out_dir: PathBuf::from(args.get("out", ".")),
                smoke: args.has("smoke"),
            };
            let (p, d) = flux_attention::util::bench::run_serving_bench(&dir, &opts)?;
            let s = flux_attention::util::bench::run_streaming_bench(&dir, &opts)?;
            if opts.smoke {
                println!("SMOKE OK: {p:?}, {d:?} and {s:?} validated");
            }
            Ok(())
        }
        "synth" => {
            let seed = args.get_usize("seed", 0) as u64;
            let dir = flux_attention::runtime::synthetic::write_artifacts(
                &artifacts,
                flux_attention::runtime::synthetic::DEFAULT_META,
                seed,
            )?;
            println!("synthetic artifacts (backend=ref, seed {seed}) written to {dir:?}");
            Ok(())
        }
        "info" => {
            let cfg = MetaConfig::load(&artifacts)?;
            println!("{cfg:#?}");
            Ok(())
        }
        _ => {
            eprintln!("usage: flux [--artifacts DIR] <serve|generate|experiment|bench-serve|bench|synth|info> [flags]");
            eprintln!("  generate --stream streams tokens through the session API as they decode");
            eprintln!("  bench sweeps batched decode at batch sizes 1/2/4/8 (FLUX_BATCH_DECODE=0 forces serial)");
            eprintln!("  serve --chunk-tokens N sizes prefill chunks (0 = monolithic), --chunk-budget N caps chunks per decode round");
            eprintln!("  serve --round-timeout-ms N arms the engine watchdog; --restart-max/--restart-backoff-ms bound respawns; --drain-ms N caps SIGINT/SIGTERM drain (default 30000)");
            eprintln!("  serve --replicas R runs R data-parallel engine replicas (least-loaded dispatch, per-replica supervision)");
            eprintln!("  serve --queue-high-watermark/--queue-low-watermark N bound queue depth with typed overloaded backpressure");
            eprintln!("  serve --admission-mode worst-case|optimistic [--optimistic-percent P] charges P% of the worst-case KV pages at admission (default 50); a dry pool preempts-and-resumes instead of rejecting");
            eprintln!("  serve --max-preemptions N caps preemptions per request before typed retryable preemption_exhausted (default 4)");
            eprintln!("  serve reads FLUX_FAULT_SEED / FLUX_FAULT_PLAN for deterministic fault injection (chaos testing)");
            eprintln!("experiment ids: fig1a fig1b table1 table2 fig3 fig4 fig5 fig8 fig9 cases kvmem curves route_ledger all");
            Ok(())
        }
    }
}

/// `flux generate --stream`: drive one request through the event-driven
/// session API, printing tokens as they decode (the TTFT the paper's
/// speedups buy is visible instead of hidden behind a blocking call).
fn generate_streaming(
    args: &Args,
    artifacts: PathBuf,
    task: Task,
    sample: &flux_attention::workload::Sample,
    tok: &Tokenizer,
) -> Result<()> {
    use std::io::Write as _;
    let n_layers = MetaConfig::load(&artifacts)?.model.n_layers;
    let policy = server::parse_policy(
        &args.get("policy", "flux-ssa"),
        args.has("sparse-decode"),
        n_layers,
    )?;
    let engine = EngineHandle::spawn(artifacts)?;
    let coord = Coordinator::start(engine, ServingConfig::default())?;
    let handle = coord.open(Request {
        prompt: sample.prompt.clone(),
        max_new: sample.answer.len() + 1,
        policy,
        router: args.get("router", "balanced"),
        deadline_ms: args.get_opt_u64("deadline-ms"),
        ..Default::default()
    })?;
    println!("task      : {}", task.name());
    while let Some(ev) = handle.recv() {
        match ev {
            SessionEvent::Queued => {}
            SessionEvent::Prefilled { first_token, omsr, ttft_us, .. } => {
                println!("prefilled : omsr {omsr:.2}, ttft {:.1} ms", ttft_us as f64 / 1e3);
                print!("generated : {}", tok.decode_token(first_token));
                std::io::stdout().flush()?;
            }
            SessionEvent::Token { tok: t, .. } => {
                print!(" {}", tok.decode_token(t));
                std::io::stdout().flush()?;
            }
            SessionEvent::Preempted { preemptions, .. } => {
                println!();
                println!("preempted : KV pages reclaimed (preemption #{preemptions}), parked");
            }
            SessionEvent::Resumed { resume_us, .. } => {
                print!("resumed   : after {:.1} ms; stream continues:", resume_us as f64 / 1e3);
                std::io::stdout().flush()?;
            }
            SessionEvent::Done { stats } => {
                println!();
                println!(
                    "done      : {} tokens, e2e {:.1} ms, {:.2} ms/token",
                    stats.tokens.len(),
                    stats.e2e_us as f64 / 1e3,
                    stats.decode_us_per_token / 1e3
                );
                break;
            }
            SessionEvent::Error { error } => {
                println!();
                anyhow::bail!("stream failed: {error}");
            }
        }
    }
    println!("expected  : {}", tok.decode(&sample.answer));
    Ok(())
}

fn run_experiment(engine: &mut Engine, id: &str, n: usize, seq_len: usize) -> Result<()> {
    let t_sweep: Vec<String> =
        ["t25", "t35", "balanced", "t55"].iter().map(|s| s.to_string()).collect();
    let pool_sweep: Vec<String> = ["pool8", "balanced", "pool64", "pool128", "poolfull"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    match id {
        "fig1a" => experiments::fig1a(engine, n, seq_len),
        "fig1b" => experiments::fig1b(engine),
        "table1" => experiments::table1(engine, n, seq_len),
        "table2" => experiments::table2(engine, n),
        "fig3" => experiments::fig3(engine),
        "fig4" => experiments::fig4(engine, n, seq_len),
        "fig5" => experiments::sweep(engine, &t_sweep, n, seq_len, "fig5"),
        "fig8" => experiments::sweep(engine, &pool_sweep, n, seq_len, "fig8"),
        "fig9" => experiments::fig9(engine),
        "cases" => experiments::cases(engine),
        "kvmem" => experiments::kv_memory(engine, seq_len),
        "route_ledger" => experiments::route_ledger(engine, n, seq_len),
        "curves" => {
            let dir = engine.cfg().artifacts_dir.clone();
            experiments::curves(&dir)
        }
        "all" => {
            for e in [
                "fig1a", "fig1b", "table1", "table2", "fig3", "fig4", "fig5", "fig8", "fig9",
                "cases", "kvmem", "curves", "route_ledger",
            ] {
                println!("\n################ {e} ################");
                run_experiment(engine, e, n, seq_len)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other}"),
    }
}
