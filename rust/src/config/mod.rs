//! Typed configuration: mirrors `python/compile/config.py` (paper Table 3,
//! scaled) and is loaded from `artifacts/model_meta.json` so the two sides
//! can never drift.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq_len: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

#[derive(Debug, Clone)]
pub struct SparsityConfig {
    pub sink_size: usize,
    pub local_size: usize,
    pub block_size: usize,
    pub xattn_stride: usize,
    pub xattn_keep_ratio: f64,
    pub triangle_last_q: usize,
    pub pool_size: usize,
}

#[derive(Debug, Clone)]
pub struct RouterCfg {
    pub d_hidden: usize,
    pub tau_start: f64,
    pub tau_end: f64,
    pub t_retrieval: f64,
    pub t_holistic: f64,
}

/// Full build-time metadata written by `python -m compile.aot`.
#[derive(Debug, Clone)]
pub struct MetaConfig {
    pub model: ModelConfig,
    pub sparsity: SparsityConfig,
    pub router: RouterCfg,
    pub prefill_buckets: Vec<usize>,
    pub decode_kv_buckets: Vec<usize>,
    pub sa_decode_window: usize,
    pub sa_buf: usize,
    pub artifacts_dir: PathBuf,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("missing numeric field '{key}'"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing numeric field '{key}'"))
}

fn usize_arr(j: &Json, key: &str) -> Result<Vec<usize>> {
    Ok(j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array '{key}'"))?
        .iter()
        .filter_map(Json::as_usize)
        .collect())
}

impl MetaConfig {
    /// Load from `<artifacts>/model_meta.json`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let path = dir.join("model_meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let cfg = Self::from_json_str(&text, dir)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(text: &str, dir: PathBuf) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("model_meta.json: {e}"))?;
        let m = j.get("model").context("missing 'model'")?;
        let s = j.get("sparsity").context("missing 'sparsity'")?;
        let r = j.get("router").context("missing 'router'")?;
        Ok(MetaConfig {
            model: ModelConfig {
                vocab_size: req_usize(m, "vocab_size")?,
                d_model: req_usize(m, "d_model")?,
                n_layers: req_usize(m, "n_layers")?,
                n_heads: req_usize(m, "n_heads")?,
                head_dim: req_usize(m, "head_dim")?,
                d_ff: req_usize(m, "d_ff")?,
                max_seq_len: req_usize(m, "max_seq_len")?,
                rope_theta: req_f64(m, "rope_theta")?,
                rms_eps: req_f64(m, "rms_eps")?,
            },
            sparsity: SparsityConfig {
                sink_size: req_usize(s, "sink_size")?,
                local_size: req_usize(s, "local_size")?,
                block_size: req_usize(s, "block_size")?,
                xattn_stride: req_usize(s, "xattn_stride")?,
                xattn_keep_ratio: req_f64(s, "xattn_keep_ratio")?,
                triangle_last_q: req_usize(s, "triangle_last_q")?,
                pool_size: req_usize(s, "pool_size")?,
            },
            router: RouterCfg {
                d_hidden: req_usize(r, "d_hidden")?,
                tau_start: req_f64(r, "tau_start")?,
                tau_end: req_f64(r, "tau_end")?,
                t_retrieval: req_f64(r, "t_retrieval")?,
                t_holistic: req_f64(r, "t_holistic")?,
            },
            prefill_buckets: usize_arr(&j, "prefill_buckets")?,
            decode_kv_buckets: usize_arr(&j, "decode_kv_buckets")?,
            sa_decode_window: req_usize(&j, "sa_decode_window")?,
            sa_buf: req_usize(&j, "sa_buf")?,
            artifacts_dir: dir,
        })
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.model.n_heads * self.model.head_dim == self.model.d_model,
            "n_heads * head_dim must equal d_model"
        );
        anyhow::ensure!(
            self.sa_buf >= self.sa_decode_window,
            "sparse decode buffer smaller than sink+local window"
        );
        anyhow::ensure!(
            self.prefill_buckets.windows(2).all(|w| w[0] < w[1]),
            "prefill buckets must be strictly increasing"
        );
        anyhow::ensure!(
            self.decode_kv_buckets.windows(2).all(|w| w[0] < w[1]),
            "decode buckets must be strictly increasing"
        );
        Ok(())
    }

    /// Smallest prefill bucket that fits `len` tokens.
    pub fn prefill_bucket(&self, len: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().find(|&b| b >= len)
    }

    /// Smallest decode KV bucket that fits `len` cached tokens.
    pub fn decode_bucket(&self, len: usize) -> Option<usize> {
        self.decode_kv_buckets.iter().copied().find(|&b| b >= len)
    }

    /// Bucket for a dense decode-attend over a cache holding `len`
    /// tokens in a backing store of `capacity` slots.
    ///
    /// Prefers `capacity` when it is itself a published decode bucket —
    /// the cache's internal buffer is then already in executable layout
    /// and the engine stages it zero-copy through `FullCache::view`
    /// (no KV bytes cloned; see DESIGN.md §7).
    /// Otherwise (prefill buckets misaligned with decode buckets, or a
    /// capacity grown past the largest bucket) falls back to the
    /// smallest published bucket that fits `len`. The old
    /// `decode_bucket(len).max(capacity.min(last))` expression instead
    /// selected non-existent executables whenever a grown capacity was
    /// not a published bucket (regression-tested in
    /// `tests/integration.rs::decode_bucket_selection_across_boundaries`).
    pub fn decode_attend_bucket(&self, len: usize, capacity: usize) -> Option<usize> {
        if capacity >= len && self.decode_kv_buckets.contains(&capacity) {
            return Some(capacity);
        }
        self.decode_bucket(len)
    }

    /// Default artifacts location (env override for tests/benches).
    pub fn default_dir() -> PathBuf {
        std::env::var("FLUX_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// How admission charges a request's KV-page footprint before the
/// router has fired (DESIGN.md §15). The true footprint is only known
/// once the first prefill chunk pins the per-layer route: SA layers
/// draw a small fixed `sa_buf` ring while FA layers grow to the
/// covering bucket for `prompt + max_new`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionMode {
    /// Charge the all-FA worst case (every layer grown to the covering
    /// bucket). Structurally under-admits hybrid routes but can never
    /// run the pool dry at runtime — exactly the pre-§15 behavior.
    WorstCase,
    /// Charge `ceil(worst_case * factor)` at admission and correct the
    /// ledger to the routed footprint once the route is pinned at the
    /// prefill→decode promotion. `factor < 1.0` over-admits on purpose;
    /// a genuinely exhausted pool is handled by preempt-and-resume
    /// instead of rejection (DESIGN.md §15).
    Optimistic {
        /// Fraction of the worst-case page footprint charged at
        /// admission (clamped to a minimum of one page).
        factor: f64,
    },
}

impl AdmissionMode {
    /// Pages to charge at admission for a request whose worst-case
    /// footprint is `worst` pages.
    pub fn admission_pages(&self, worst: usize) -> usize {
        match *self {
            AdmissionMode::WorstCase => worst,
            AdmissionMode::Optimistic { factor } => {
                let f = factor.clamp(0.0, 1.0);
                ((worst as f64 * f).ceil() as usize).clamp(1, worst)
            }
        }
    }
}

/// Serving-side knobs (the paper's deployment configuration, section 3.3).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// max new tokens per request unless the request overrides
    pub max_new_tokens: usize,
    /// admission-queue capacity before back-pressure rejects
    pub queue_capacity: usize,
    /// chunked prefill (DESIGN.md §10): tokens per prefill chunk. Each
    /// chunk runs as one engine call at its smallest covering bucket,
    /// interleaved with decode rounds so a long prompt never stalls
    /// running streams for its whole prefill. `0` = monolithic (one
    /// whole-prompt chunk). Rounded up to a block-size multiple.
    pub prefill_chunk_tokens: usize,
    /// how many prefill chunks the scheduler may run between two decode
    /// rounds — the inter-token-latency vs prefill-throughput knob
    /// (decode streams wait at most this many chunk calls per round)
    pub prefill_chunk_budget: usize,
    /// maximum concurrently active (prefilling or decoding) requests
    pub max_active_requests: usize,
    /// hard per-request cap on `max_new` at admission — oversized
    /// requests are rejected with a typed error instead of pinning an
    /// engine slot for an unbounded generation
    pub max_new_cap: usize,
    /// default wall-clock deadline applied when a request carries no
    /// `deadline_ms` of its own; `None` = no deadline. Expired requests
    /// are evicted between decode steps (their engine slot and KV cache
    /// are reclaimed) with `RequestError::DeadlineExceeded`.
    pub default_deadline_ms: Option<u64>,
    /// token-budget admission (DESIGN.md §11): cap on the sum of prompt
    /// tokens across requests simultaneously in prefill. A single
    /// prompt longer than this is rejected `Overloaded` at enqueue.
    pub max_batch_prefill_tokens: usize,
    /// token-budget admission: cap on the sum of worst-case total
    /// tokens (`prompt + max_new`) across every running request. The
    /// scheduler admits a request only while its worst case fits; a
    /// single request whose worst case exceeds the whole budget is
    /// rejected `Overloaded` at enqueue.
    pub max_batch_total_tokens: usize,
    /// round watchdog (DESIGN.md §12): wall-clock deadline on one
    /// engine round-trip (`decode_batch` / `prefill_chunk`). A round
    /// exceeding it classifies the engine as stalled and routes into
    /// the supervision/restart path instead of hanging the scheduler
    /// forever. `None` = no watchdog (trusted local backends).
    pub engine_round_timeout_ms: Option<u64>,
    /// supervision (DESIGN.md §12): how many times the scheduler may
    /// restart a dead/stalled engine before giving up and failing all
    /// in-flight and queued requests with `RequestError::EngineFailed`.
    pub engine_restart_max: usize,
    /// base backoff before the first restart attempt, doubled per
    /// subsequent attempt.
    pub engine_restart_backoff_ms: u64,
    /// cross-request prefix cache (DESIGN.md §13): reuse the KV of
    /// shared prompt prefixes (system prompts, few-shot preambles)
    /// across requests, pinning the cached per-layer route. Off by
    /// default — a cache hit pins the stored route instead of
    /// re-running the router on the full prompt.
    pub prefix_cache: bool,
    /// cap on KV-pool pages the prefix index may retain; `None` =
    /// half the pool. LRU eviction reclaims unreferenced entries under
    /// pool pressure either way.
    pub prefix_cache_pages: Option<usize>,
    /// data-parallel engine replicas behind the coordinator
    /// (DESIGN.md §14). Each replica owns its own backend, KV pool and
    /// (optional) prefix cache, and runs its own scheduler loop;
    /// dispatch picks the replica least loaded by committed tokens,
    /// with session affinity toward warm prefix caches. `1` (the
    /// default) is the single-engine layout of PRs 3–8.
    pub replicas: usize,
    /// queue-depth high watermark (DESIGN.md §14): when a replica's
    /// admission queue reaches this depth it stops accepting dispatch
    /// (new requests go to other replicas, or are rejected with a
    /// typed retryable `Overloaded { detail: "queue_watermark" }` when
    /// every replica is saturated) until the queue drains back to the
    /// low watermark. `None` disables watermark backpressure — only
    /// the hard `queue_capacity` bound (`QueueFull`) applies.
    pub queue_high_watermark: Option<usize>,
    /// queue-depth low watermark: a saturated replica resumes
    /// accepting dispatch once its queue depth has drained to this.
    /// `None` defaults to half the high watermark. The hysteresis gap
    /// keeps admission from flapping at the boundary.
    pub queue_low_watermark: Option<usize>,
    /// route-aware optimistic admission (DESIGN.md §15): how the page
    /// ledger charges a request before its route is known. `WorstCase`
    /// reproduces the pre-§15 admission decisions exactly.
    pub admission_mode: AdmissionMode,
    /// preempt-and-resume (DESIGN.md §15): how many times one request
    /// may be preempted (or re-parked after a failed resume) before it
    /// fails with typed retryable
    /// `RequestError::PreemptionExhausted` — the starvation bound that
    /// keeps every admitted stream terminating.
    pub max_preemptions: u32,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            max_new_tokens: 8,
            queue_capacity: 256,
            prefill_chunk_tokens: 128,
            prefill_chunk_budget: 1,
            max_active_requests: 32,
            max_new_cap: 4096,
            default_deadline_ms: None,
            max_batch_prefill_tokens: 4096,
            max_batch_total_tokens: 131072,
            engine_round_timeout_ms: None,
            engine_restart_max: 2,
            engine_restart_backoff_ms: 50,
            prefix_cache: false,
            prefix_cache_pages: None,
            replicas: 1,
            queue_high_watermark: None,
            queue_low_watermark: None,
            admission_mode: AdmissionMode::WorstCase,
            max_preemptions: 4,
        }
    }
}

#[cfg(test)]
pub(crate) const TEST_META_JSON: &str = r#"{
    "model": {"vocab_size":512,"d_model":128,"n_layers":8,
              "n_heads":4,"head_dim":32,"d_ff":512,
              "max_seq_len":2048,"rope_theta":10000.0,
              "rms_eps":1e-5},
    "sparsity": {"sink_size":16,"local_size":128,"block_size":16,
                 "xattn_stride":4,"xattn_keep_ratio":0.25,
                 "triangle_last_q":64,"pool_size":16},
    "router": {"d_hidden":64,"tau_start":2.0,"tau_end":0.3,
               "t_retrieval":0.45,"t_holistic":1.0},
    "prefill_buckets": [128,256,512,1024,2048],
    "decode_kv_buckets": [128,256,512,1024,2048],
    "sa_decode_window": 145,
    "sa_buf": 192
}"#;

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_for_test() -> MetaConfig {
        MetaConfig::from_json_str(TEST_META_JSON, PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn bucket_selection() {
        let m = meta_for_test();
        assert_eq!(m.prefill_bucket(1), Some(128));
        assert_eq!(m.prefill_bucket(128), Some(128));
        assert_eq!(m.prefill_bucket(129), Some(256));
        assert_eq!(m.prefill_bucket(2048), Some(2048));
        assert_eq!(m.prefill_bucket(2049), None);
        assert_eq!(m.decode_bucket(500), Some(512));
    }

    #[test]
    fn decode_attend_bucket_prefers_aligned_capacity() {
        let m = meta_for_test(); // decode buckets [128, 256, 512, 1024, 2048]
        // capacity is a published bucket -> reuse it (fast path), even
        // when a smaller bucket would fit
        assert_eq!(m.decode_attend_bucket(130, 256), Some(256));
        assert_eq!(m.decode_attend_bucket(10, 2048), Some(2048));
        // capacity NOT a published bucket (e.g. grown from a 96-slot
        // prefill bucket): fall back to the smallest bucket >= len
        assert_eq!(m.decode_attend_bucket(97, 192), Some(128));
        assert_eq!(m.decode_attend_bucket(129, 192), Some(256));
        // boundary: exactly at a bucket edge
        assert_eq!(m.decode_attend_bucket(128, 128), Some(128));
        assert_eq!(m.decode_attend_bucket(129, 4096), Some(256));
        // overflow past the largest bucket is a hard None
        assert_eq!(m.decode_attend_bucket(2049, 4096), None);
    }

    #[test]
    fn validation_accepts_good_config() {
        meta_for_test().validate().unwrap();
    }

    #[test]
    fn validation_rejects_bad_buffer() {
        let mut m = meta_for_test();
        m.sa_buf = 10;
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_field_is_an_error() {
        assert!(MetaConfig::from_json_str("{}", PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn admission_pages_charging() {
        // worst case charges the full footprint
        assert_eq!(AdmissionMode::WorstCase.admission_pages(100), 100);
        assert_eq!(AdmissionMode::WorstCase.admission_pages(1), 1);
        // optimistic rounds up and never charges below one page or
        // above the worst case
        let half = AdmissionMode::Optimistic { factor: 0.5 };
        assert_eq!(half.admission_pages(100), 50);
        assert_eq!(half.admission_pages(101), 51);
        assert_eq!(half.admission_pages(1), 1);
        assert_eq!(AdmissionMode::Optimistic { factor: 0.0 }.admission_pages(100), 1);
        assert_eq!(AdmissionMode::Optimistic { factor: 2.0 }.admission_pages(100), 100);
        // factor 1.0 is exactly worst case
        assert_eq!(AdmissionMode::Optimistic { factor: 1.0 }.admission_pages(37), 37);
    }
}
