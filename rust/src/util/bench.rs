//! Micro-benchmark substrate (no criterion in the vendor set).
//!
//! Warmup + timed iterations with mean / p50 / p95 reporting and a
//! machine-readable JSON dump per group, so `cargo bench` output can be
//! diffed across the §Perf optimization iterations.

use std::time::Instant;

use super::json::Json;

pub struct Bench {
    group: String,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        println!("\n== bench group: {group} ==");
        Self { group: group.to_string(), results: vec![] }
    }

    /// Run `f` repeatedly: `warmup` unmeasured + `iters` measured calls.
    /// Returns the stats so callers can derive ratios (speedup series).
    pub fn run<R>(&mut self, name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> Stats {
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64 / 1e3);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            iters,
            mean_us: samples.iter().sum::<f64>() / iters as f64,
            p50_us: samples[iters / 2],
            p95_us: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        };
        println!(
            "{:<40} mean {:>10.2} us   p50 {:>10.2} us   p95 {:>10.2} us   ({} iters)",
            name, stats.mean_us, stats.p50_us, stats.p95_us, iters
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    /// Write results to `results/bench_<group>.json`.
    pub fn save(&self) {
        let _ = std::fs::create_dir_all("results");
        let mut arr = Json::Arr(vec![]);
        for (name, s) in &self.results {
            let mut o = Json::obj();
            o.set("name", Json::from(name.clone()));
            o.set("mean_us", Json::from(s.mean_us));
            o.set("p50_us", Json::from(s.p50_us));
            o.set("p95_us", Json::from(s.p95_us));
            arr.push(o);
        }
        let path = format!("results/bench_{}.json", self.group);
        let _ = std::fs::write(&path, arr.to_string());
        println!("(saved {path})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        b.run("noop", 2, 16, || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].1.mean_us >= 0.0);
        assert!(b.results[0].1.p95_us >= b.results[0].1.p50_us);
    }
}
