//! Micro-benchmark substrate (no criterion in the vendor set).
//!
//! Warmup + timed iterations with mean / p50 / p95 reporting and a
//! machine-readable JSON dump per group, so `cargo bench` output can be
//! diffed across the §Perf optimization iterations.
//!
//! Also hosts the `flux bench` serving harness
//! ([`run_serving_bench`]): prefill + decode step latency across the
//! three staging configurations (clone+serial baseline, zero-copy
//! serial, zero-copy parallel), the batched-decode batch-size sweep
//! (serial vs (layer, mode)-bucketed rounds, DESIGN.md §9), the
//! bucket-padding utilization ledger and the chunked-prefill
//! interference scenario (decode gap p95 under a concurrent long-prompt
//! arrival, monolithic vs chunked — DESIGN.md §10), emitted as
//! `BENCH_prefill.json` (schema `flux-bench-prefill/v2`) /
//! `BENCH_decode.json` (schema `flux-bench-decode/v2`) — the repo-root
//! perf trajectory every future PR measures against (DESIGN.md §7).

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use super::json::Json;

pub struct Bench {
    group: String,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        println!("\n== bench group: {group} ==");
        Self { group: group.to_string(), results: vec![] }
    }

    /// Run `f` repeatedly: `warmup` unmeasured + `iters` measured calls.
    /// Returns the stats so callers can derive ratios (speedup series).
    pub fn run<R>(&mut self, name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> Stats {
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64 / 1e3);
        }
        let stats = stats_of(&mut samples);
        println!(
            "{:<40} mean {:>10.2} us   p50 {:>10.2} us   p95 {:>10.2} us   ({} iters)",
            name, stats.mean_us, stats.p50_us, stats.p95_us, iters
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    /// Write results to `results/bench_<group>.json`.
    pub fn save(&self) {
        let _ = std::fs::create_dir_all("results");
        let mut arr = Json::Arr(vec![]);
        for (name, s) in &self.results {
            let mut o = Json::obj();
            o.set("name", Json::from(name.clone()));
            o.set("mean_us", Json::from(s.mean_us));
            o.set("p50_us", Json::from(s.p50_us));
            o.set("p95_us", Json::from(s.p95_us));
            arr.push(o);
        }
        let path = format!("results/bench_{}.json", self.group);
        let _ = std::fs::write(&path, arr.to_string());
        println!("(saved {path})");
    }
}

// ---------------------------------------------------------------------------
// `flux bench`: the serving-path benchmark behind BENCH_prefill.json /
// BENCH_decode.json
// ---------------------------------------------------------------------------

/// Options for the `flux bench` serving benchmark.
#[derive(Debug, Clone)]
pub struct ServingBenchOpts {
    /// prompt length (clamped to the artifact's largest prefill bucket)
    pub seq_len: usize,
    /// timed decode steps per configuration
    pub decode_tokens: usize,
    /// worker count for the parallel configuration
    pub threads: usize,
    /// where BENCH_prefill.json / BENCH_decode.json land
    pub out_dir: PathBuf,
    /// tiny CI run: fewer iterations, validation only
    pub smoke: bool,
}

impl Default for ServingBenchOpts {
    fn default() -> Self {
        Self {
            seq_len: 256,
            decode_tokens: 32,
            threads: crate::runtime::flux_threads_default(),
            out_dir: PathBuf::from("."),
            smoke: false,
        }
    }
}

fn stats_of(samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let iters = samples.len();
    Stats {
        iters,
        mean_us: samples.iter().sum::<f64>() / iters as f64,
        p50_us: samples[iters / 2],
        p95_us: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
    }
}

fn stats_json(label: &str, st: &Stats, tokens_per_s: f64) -> Json {
    let mut o = Json::obj();
    o.set("label", Json::from(label));
    o.set("iters", Json::from(st.iters));
    o.set("mean_us", Json::from(st.mean_us));
    o.set("p50_us", Json::from(st.p50_us));
    o.set("p95_us", Json::from(st.p95_us));
    o.set("tokens_per_s", Json::from(tokens_per_s));
    o
}

/// Assert a written bench file parses and reports positive throughput —
/// the `flux bench --smoke` CI gate.
fn validate_bench_file(path: &Path) -> Result<()> {
    let j = Json::parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    let ok = j
        .get("configs")
        .and_then(Json::as_arr)
        .map(|arr| {
            !arr.is_empty()
                && arr.iter().all(|c| {
                    c.get("tokens_per_s")
                        .and_then(Json::as_f64)
                        .map(|v| v > 0.0)
                        .unwrap_or(false)
                })
        })
        .unwrap_or(false);
    anyhow::ensure!(ok, "bench output {path:?} failed validation (missing/zero tokens_per_s)");
    Ok(())
}

/// The `flux bench --smoke` CI gate for the prefill file's v2 schema
/// (DESIGN.md §10): the chunked-vs-monolithic interference scenario
/// must be present with verified bit-identical token streams and the
/// decode-gap speedup fields, and the bucket-padding utilization ledger
/// must be recorded for both configurations.
fn validate_prefill_v2(path: &Path) -> Result<()> {
    let j = Json::parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    anyhow::ensure!(
        j.get("schema").and_then(Json::as_str) == Some("flux-bench-prefill/v2"),
        "{path:?}: schema must be flux-bench-prefill/v2"
    );
    let inter = j
        .get("interference")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing interference scenario"))?;
    anyhow::ensure!(
        inter.get("bit_identical").and_then(Json::as_bool) == Some(true),
        "{path:?}: interference token streams not verified bit-identical"
    );
    anyhow::ensure!(
        inter.get("speedup_decode_p95").and_then(Json::as_f64).is_some(),
        "{path:?}: missing interference.speedup_decode_p95"
    );
    for cfg in ["monolithic", "chunked"] {
        let c = inter
            .get(cfg)
            .ok_or_else(|| anyhow::anyhow!("{path:?}: missing interference.{cfg}"))?;
        anyhow::ensure!(
            c.get("decode_gap_p95_us").and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
            "{path:?}: interference.{cfg} reports no decode-gap p95"
        );
        anyhow::ensure!(
            c.get("long_ttft_us").and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
            "{path:?}: interference.{cfg} reports no long-prompt TTFT"
        );
    }
    let pad = j
        .get("padding")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing padding utilization ledger"))?;
    for cfg in ["monolithic", "chunked"] {
        let u = pad
            .get(cfg)
            .and_then(|c| c.get("utilization"))
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("{path:?}: missing padding.{cfg}.utilization"))?;
        anyhow::ensure!(
            u > 0.0 && u <= 1.0,
            "{path:?}: padding.{cfg}.utilization {u} out of (0, 1]"
        );
    }
    Ok(())
}

/// The `flux bench --smoke` CI gate for the decode file's v2 schema:
/// the batched scenario must be present, every scenario's token streams
/// must have verified bit-identical, and `speedup_batched_over_serial`
/// must be reported.
fn validate_decode_v2(path: &Path) -> Result<()> {
    let j = Json::parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    anyhow::ensure!(
        j.get("schema").and_then(Json::as_str) == Some("flux-bench-decode/v2"),
        "{path:?}: schema must be flux-bench-decode/v2"
    );
    anyhow::ensure!(
        j.get("speedup_batched_over_serial").and_then(Json::as_f64).is_some(),
        "{path:?}: missing speedup_batched_over_serial"
    );
    let scenarios = j
        .get("batched")
        .and_then(|b| b.get("scenarios"))
        .and_then(Json::as_arr)
        .filter(|s| !s.is_empty())
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing batched.scenarios"))?;
    for s in scenarios {
        anyhow::ensure!(
            s.get("bit_identical").and_then(Json::as_bool) == Some(true),
            "{path:?}: batched scenario not verified bit-identical"
        );
        anyhow::ensure!(
            s.get("batched_tokens_per_s").and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
            "{path:?}: batched scenario reports no throughput"
        );
    }
    Ok(())
}

/// The `flux bench --smoke` CI gate for the serving file's v6 schema
/// (DESIGN.md §11–15): throughput must be positive, the pool-pressure
/// scenario must be present with a nonzero page high-water mark, at
/// least one typed overloaded rejection, and verified bit-identical
/// token streams across page sizes, the fault-recovery scenario must
/// show a mid-stream engine kill that was supervised back to life
/// (≥1 restart, recovered, post-restart bit-identity), the
/// prefix-reuse scenario must record a nonzero hit rate with tokens
/// actually reused and warm streams verified bit-identical to the
/// cold run, and the saturation scenario must sweep offered load over
/// a multi-replica set (positive goodput at every level) with a
/// replica-kill ledger showing ≥1 failover completion bit-identical to
/// the unfaulted reference, and the preemption scenario must show an
/// undersized pool actually preempting AND resuming (≥1 each) with
/// every stream completing bit-identical to the worst-case serial
/// reference and goodput recorded for both admission modes — CI fails
/// if the paged pool, the failure domain, the prefix cache, the
/// replica set, or the preemption path silently stops being measured.
fn validate_serving(path: &Path) -> Result<()> {
    let j = Json::parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    anyhow::ensure!(
        j.get("schema").and_then(Json::as_str) == Some("flux-bench-serving/v6"),
        "{path:?}: schema must be flux-bench-serving/v6"
    );
    anyhow::ensure!(
        j.get("tokens_per_s").and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
        "{path:?}: missing/zero tokens_per_s"
    );
    let p = j
        .get("pool_pressure")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing pool_pressure scenario"))?;
    anyhow::ensure!(
        p.get("pages_peak").and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
        "{path:?}: pool_pressure reports no page occupancy (pages_peak)"
    );
    anyhow::ensure!(
        p.get("overloaded_rejections").and_then(Json::as_f64).map(|v| v >= 1.0).unwrap_or(false),
        "{path:?}: pool_pressure recorded no typed overloaded rejection"
    );
    anyhow::ensure!(
        p.get("bit_identical").and_then(Json::as_bool) == Some(true),
        "{path:?}: page-size sweep token streams not verified bit-identical"
    );
    let f = j
        .get("fault_recovery")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing fault_recovery scenario"))?;
    anyhow::ensure!(
        f.get("recovered").and_then(Json::as_bool) == Some(true),
        "{path:?}: fault_recovery scenario did not recover"
    );
    anyhow::ensure!(
        f.get("engine_restarts").and_then(Json::as_f64).map(|v| v >= 1.0).unwrap_or(false),
        "{path:?}: fault_recovery recorded no engine restart"
    );
    anyhow::ensure!(
        f.get("bit_identical").and_then(Json::as_bool) == Some(true),
        "{path:?}: post-restart stream not verified bit-identical"
    );
    let r = j
        .get("prefix_reuse")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing prefix_reuse scenario"))?;
    anyhow::ensure!(
        r.get("hit_rate").and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
        "{path:?}: prefix_reuse recorded a zero hit rate"
    );
    anyhow::ensure!(
        r.get("tokens_reused").and_then(Json::as_f64).map(|v| v >= 1.0).unwrap_or(false),
        "{path:?}: prefix_reuse reused no tokens"
    );
    for k in ["ttft_cold_us", "ttft_warm_p50_us"] {
        anyhow::ensure!(
            r.get(k).and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
            "{path:?}: prefix_reuse missing {k}"
        );
    }
    anyhow::ensure!(
        r.get("bit_identical").and_then(Json::as_bool) == Some(true),
        "{path:?}: warm prefix-hit stream not verified bit-identical to the cold run"
    );
    let s = j
        .get("saturation")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing saturation scenario"))?;
    let runs = s
        .get("runs")
        .and_then(Json::as_arr)
        .filter(|r| !r.is_empty())
        .ok_or_else(|| anyhow::anyhow!("{path:?}: saturation recorded no replica runs"))?;
    let mut max_replicas = 0usize;
    for run in runs {
        max_replicas = max_replicas.max(run.get("replicas").and_then(Json::as_usize).unwrap_or(0));
        let sweep = run
            .get("sweep")
            .and_then(Json::as_arr)
            .filter(|s| !s.is_empty())
            .ok_or_else(|| anyhow::anyhow!("{path:?}: saturation run has an empty load sweep"))?;
        for lv in sweep {
            anyhow::ensure!(
                lv.get("goodput_tokens_per_s")
                    .and_then(Json::as_f64)
                    .map(|v| v > 0.0)
                    .unwrap_or(false),
                "{path:?}: saturation level reports no goodput"
            );
        }
    }
    anyhow::ensure!(
        max_replicas >= 2,
        "{path:?}: saturation never measured a multi-replica set"
    );
    let k = s
        .get("replica_kill")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing replica_kill ledger"))?;
    anyhow::ensure!(
        k.get("recovered").and_then(Json::as_bool) == Some(true),
        "{path:?}: replica kill did not recover"
    );
    anyhow::ensure!(
        k.get("failover_completions").and_then(Json::as_f64).map(|v| v >= 1.0).unwrap_or(false),
        "{path:?}: replica kill recorded no failover completion"
    );
    anyhow::ensure!(
        k.get("bit_identical").and_then(Json::as_bool) == Some(true),
        "{path:?}: failover streams not verified bit-identical"
    );
    let pe = j
        .get("preemption")
        .ok_or_else(|| anyhow::anyhow!("{path:?}: missing preemption scenario"))?;
    anyhow::ensure!(
        pe.get("preemptions").and_then(Json::as_f64).map(|v| v >= 1.0).unwrap_or(false),
        "{path:?}: preemption scenario recorded no preemption"
    );
    anyhow::ensure!(
        pe.get("resumes").and_then(Json::as_f64).map(|v| v >= 1.0).unwrap_or(false),
        "{path:?}: preemption scenario recorded no resume"
    );
    anyhow::ensure!(
        pe.get("all_streams_completed").and_then(Json::as_bool) == Some(true),
        "{path:?}: preemption scenario left streams incomplete"
    );
    anyhow::ensure!(
        pe.get("bit_identical").and_then(Json::as_bool) == Some(true),
        "{path:?}: resumed streams not verified bit-identical to the worst-case reference"
    );
    for key in ["goodput_optimistic_tokens_per_s", "goodput_worst_case_tokens_per_s"] {
        anyhow::ensure!(
            pe.get(key).and_then(Json::as_f64).map(|v| v > 0.0).unwrap_or(false),
            "{path:?}: preemption scenario missing {key}"
        );
    }
    Ok(())
}

/// One configuration's numbers from the prefill-interference scenario.
struct InterferenceRun {
    long_prompt_tokens: usize,
    gap_p50_us: f64,
    gap_p95_us: f64,
    gap_max_us: f64,
    long_ttft_us: u64,
    short_streams: Vec<Vec<u32>>,
    long_tokens: Vec<u32>,
    prefill_chunks: u64,
    decode_stall_us: u64,
}

/// The prefill-interference scenario (DESIGN.md §10): N short streams
/// decode steadily; a long prompt arrives mid-flight; we measure the
/// short streams' inter-token gaps over the long prefill window and the
/// long request's TTFT. `chunk_tokens == 0` is the monolithic baseline
/// (the long prefill stalls every stream for its whole duration);
/// chunked runs interleave decode rounds between chunks. Token streams
/// are greedy and per-request deterministic, so the two configurations
/// must produce bit-identical streams — the caller asserts it.
fn run_interference(
    artifacts: &Path,
    opts: &ServingBenchOpts,
    chunk_tokens: usize,
) -> Result<InterferenceRun> {
    use crate::config::{MetaConfig, ServingConfig};
    use crate::coordinator::{Coordinator, Request, SessionEvent};
    use crate::engine::EngineHandle;
    use crate::router::{AttnMode, DecodeMode, Policy};
    use crate::util::rng::Rng;
    use crate::workload::{generate, Task};

    let meta = MetaConfig::load(artifacts)?;
    let n_layers = meta.model.n_layers;
    let max_prefill = *meta.prefill_buckets.last().unwrap();
    let (n_short, short_max_new, long_len) = if opts.smoke {
        (2usize, 64usize, 384usize.min(max_prefill))
    } else {
        (3, 128, 768usize.min(max_prefill))
    };
    let engine = EngineHandle::spawn(artifacts.to_path_buf())?;
    let coord = Coordinator::start(
        engine,
        ServingConfig {
            prefill_chunk_tokens: chunk_tokens,
            prefill_chunk_budget: 1,
            ..Default::default()
        },
    )?;
    // mixed static routing (alternate FA / SSA, sparse decode) pins the
    // per-layer modes so the monolithic and chunked runs are comparable
    // bit-for-bit AND every chunk exercises both cache layouts,
    // including the sparse-ring priming path
    let modes: Vec<AttnMode> = (0..n_layers)
        .map(|l| if l % 2 == 0 { AttnMode::Fa } else { AttnMode::Ssa })
        .collect();
    let policy = Policy::Static { modes, decode: DecodeMode::Sparse };

    let mut rng = Rng::seed_from_u64(31);
    let timeout = std::time::Duration::from_secs(120);
    let (first_tx, first_rx) = std::sync::mpsc::channel::<()>();
    let mut workers = vec![];
    for i in 0..n_short {
        let s = generate(Task::PRe, &mut rng, 96);
        let h = coord
            .open(Request {
                prompt: s.prompt,
                max_new: short_max_new,
                ignore_eos: true,
                policy: policy.clone(),
                ..Default::default()
            })
            .map_err(|e| anyhow::anyhow!("short stream {i} rejected: {e}"))?;
        let tx = first_tx.clone();
        workers.push(std::thread::spawn(move || -> (Vec<(Instant, u32)>, bool) {
            let mut toks: Vec<(Instant, u32)> = vec![];
            let mut ok = false;
            while let Some(ev) = h.recv_timeout(timeout) {
                match ev {
                    SessionEvent::Prefilled { first_token, .. } => {
                        toks.push((Instant::now(), first_token));
                        let _ = tx.send(());
                    }
                    SessionEvent::Token { tok, .. } => toks.push((Instant::now(), tok)),
                    SessionEvent::Done { .. } => {
                        ok = true;
                        break;
                    }
                    SessionEvent::Error { .. } => break,
                    _ => {}
                }
            }
            (toks, ok)
        }));
    }
    drop(first_tx);
    for _ in 0..n_short {
        first_rx
            .recv_timeout(timeout)
            .map_err(|_| anyhow::anyhow!("short stream died before its first token"))?;
    }

    // the long-prompt arrival
    let long_prompt: Vec<u32> = (0..long_len).map(|i| (i as u32) % 250 + 1).collect();
    let t_submit = Instant::now();
    let hl = coord
        .open(Request {
            prompt: long_prompt,
            max_new: 4,
            ignore_eos: true,
            policy: policy.clone(),
            ..Default::default()
        })
        .map_err(|e| anyhow::anyhow!("long request rejected: {e}"))?;
    let mut long_tokens = vec![];
    let mut t_prefilled: Option<Instant> = None;
    while let Some(ev) = hl.recv_timeout(timeout) {
        match ev {
            SessionEvent::Prefilled { first_token, .. } => {
                long_tokens.push(first_token);
                t_prefilled = Some(Instant::now());
            }
            SessionEvent::Token { tok, .. } => long_tokens.push(tok),
            SessionEvent::Done { .. } => break,
            SessionEvent::Error { error } => anyhow::bail!("long request failed: {error}"),
            _ => {}
        }
    }
    let t_prefilled =
        t_prefilled.ok_or_else(|| anyhow::anyhow!("long request never prefilled"))?;
    let long_ttft_us = t_prefilled.duration_since(t_submit).as_micros() as u64;

    let mut short_streams = vec![];
    let mut window_gaps: Vec<f64> = vec![];
    let mut all_gaps: Vec<f64> = vec![];
    for w in workers {
        let (toks, ok) = w.join().map_err(|_| anyhow::anyhow!("short stream panicked"))?;
        anyhow::ensure!(
            ok && toks.len() == short_max_new,
            "short stream truncated at {} of {short_max_new} tokens",
            toks.len()
        );
        for pair in toks.windows(2) {
            let gap = pair[1].0.duration_since(pair[0].0).as_nanos() as f64 / 1e3;
            all_gaps.push(gap);
            // gaps overlapping the long prefill window measure the stall
            if pair[1].0 >= t_submit && pair[0].0 <= t_prefilled {
                window_gaps.push(gap);
            }
        }
        short_streams.push(toks.into_iter().map(|(_, t)| t).collect());
    }
    // fallback for races where every short stream finished before the
    // long prompt arrived (tiny models decode fast): report the overall
    // gap distribution instead of an empty window
    let mut gaps = if window_gaps.is_empty() { all_gaps } else { window_gaps };
    anyhow::ensure!(!gaps.is_empty(), "no inter-token gaps recorded");
    let st = stats_of(&mut gaps);
    let m = coord.metrics.lock().unwrap().clone();
    Ok(InterferenceRun {
        long_prompt_tokens: long_len,
        gap_p50_us: st.p50_us,
        gap_p95_us: st.p95_us,
        gap_max_us: *gaps.last().unwrap(),
        long_ttft_us,
        short_streams,
        long_tokens,
        prefill_chunks: m.prefill_chunks,
        decode_stall_us: m.decode_stall_us,
    })
}

/// Run the serving benchmark against an artifact directory and write
/// `BENCH_prefill.json` / `BENCH_decode.json` into `opts.out_dir`.
/// Returns the two paths. Three staging configurations are compared
/// in-process so the clone-vs-view and serial-vs-parallel deltas come
/// from the same binary and artifacts:
///   * `baseline_clone_serial` — pre-optimization behavior (KV cloned
///     per layer per token, single-threaded kernels);
///   * `view_serial` — zero-copy KV staging, single-threaded;
///   * `view_parallel` — zero-copy + `opts.threads` kernel workers.
pub fn run_serving_bench(artifacts: &Path, opts: &ServingBenchOpts) -> Result<(PathBuf, PathBuf)> {
    use crate::engine::Engine;
    use crate::router::{AttnMode, DecodeMode, Policy};
    use crate::runtime::Backend;
    use crate::util::rng::Rng;
    use crate::workload::{generate, Task};

    let mut engine = Engine::load(artifacts)?;
    let n_layers = engine.cfg().model.n_layers;
    let max_prefill = *engine.cfg().prefill_buckets.last().unwrap();
    let (seq, steps, prefill_iters) = if opts.smoke {
        (opts.seq_len.min(128).min(max_prefill), opts.decode_tokens.clamp(2, 4), 2)
    } else {
        (opts.seq_len.min(max_prefill), opts.decode_tokens.max(2), 5)
    };
    let mut rng = Rng::seed_from_u64(7);
    let sample = generate(Task::PRe, &mut rng, seq);
    let prompt_len = sample.prompt.len();

    struct RunCfg {
        label: &'static str,
        zero_copy: bool,
        threads: usize,
    }
    let configs = [
        RunCfg { label: "baseline_clone_serial", zero_copy: false, threads: 1 },
        RunCfg { label: "view_serial", zero_copy: true, threads: 1 },
        RunCfg { label: "view_parallel", zero_copy: true, threads: opts.threads },
    ];

    println!("== flux bench (seq {seq}, {steps} decode steps, {} threads) ==", opts.threads);

    // ---- prefill: serial vs parallel kernels (zero-copy staging only
    // affects decode KV, so a clone-vs-view prefill row would measure
    // the same configuration twice) ----
    let mut prefill_results: Vec<(String, Stats, f64)> = Vec::new();
    for (label, threads) in [("baseline_serial", 1usize), ("parallel", opts.threads)] {
        engine.set_zero_copy(true);
        engine.set_threads(threads);
        let mut samples = Vec::with_capacity(prefill_iters);
        for _ in 0..prefill_iters {
            let t0 = Instant::now();
            let (id, _) = engine.prefill(&sample.prompt, &Policy::Backbone, "balanced")?;
            samples.push(t0.elapsed().as_nanos() as f64 / 1e3);
            engine.release(id);
        }
        let st = stats_of(&mut samples);
        let tok_s = prompt_len as f64 / (st.mean_us / 1e6).max(1e-12);
        println!(
            "prefill/fa/{:<22} mean {:>10.1} us   p50 {:>10.1}   p95 {:>10.1}   {:>10.0} tok/s",
            label, st.mean_us, st.p50_us, st.p95_us, tok_s
        );
        prefill_results.push((label.to_string(), st, tok_s));
    }
    // SSA prefill under the optimized configuration (FA-vs-SA ratio)
    let ssa_policy =
        Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Dense };
    let mut ssa_samples = Vec::with_capacity(prefill_iters);
    for _ in 0..prefill_iters {
        let t0 = Instant::now();
        let (id, _) = engine.prefill(&sample.prompt, &ssa_policy, "balanced")?;
        ssa_samples.push(t0.elapsed().as_nanos() as f64 / 1e3);
        engine.release(id);
    }
    let ssa_st = stats_of(&mut ssa_samples);
    let ssa_tok_s = prompt_len as f64 / (ssa_st.mean_us / 1e6).max(1e-12);

    // ---- decode: per configuration ----
    let mut decode_results: Vec<(String, Stats, f64)> = Vec::new();
    let mut kv_fast_path = (0u64, 0u64);
    for c in &configs {
        engine.set_zero_copy(c.zero_copy);
        engine.set_threads(c.threads);
        if c.label == "view_parallel" {
            engine.rt.reset_stats(); // capture fast-path KV accounting
        }
        let (id, _) = engine.prefill(&sample.prompt, &Policy::Backbone, "balanced")?;
        for _ in 0..2 {
            engine.decode_step(id)?; // warmup
        }
        let mut samples = Vec::with_capacity(steps);
        for _ in 0..steps {
            let t0 = Instant::now();
            engine.decode_step(id)?;
            samples.push(t0.elapsed().as_nanos() as f64 / 1e3);
        }
        engine.release(id);
        let st = stats_of(&mut samples);
        let tok_s = 1e6 / st.mean_us.max(1e-9);
        println!(
            "decode/fa/{:<23} mean {:>10.1} us   p50 {:>10.1}   p95 {:>10.1}   {:>10.1} tok/s",
            c.label, st.mean_us, st.p50_us, st.p95_us, tok_s
        );
        decode_results.push((c.label.to_string(), st, tok_s));
        if c.label == "view_parallel" {
            kv_fast_path = engine.kv_transfer_totals();
        }
    }

    // sparse decode under the optimized configuration (FA-vs-SA ratio)
    let sparse_policy =
        Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Sparse };
    let (id, _) = engine.prefill(&sample.prompt, &sparse_policy, "balanced")?;
    for _ in 0..2 {
        engine.decode_step(id)?;
    }
    let mut sparse_samples = Vec::with_capacity(steps);
    for _ in 0..steps {
        let t0 = Instant::now();
        engine.decode_step(id)?;
        sparse_samples.push(t0.elapsed().as_nanos() as f64 / 1e3);
    }
    engine.release(id);
    let sparse_st = stats_of(&mut sparse_samples);

    // ---- batched decode (DESIGN.md §9): one engine round per token
    // across B active requests, (layer, mode)-bucketed, vs B serial
    // per-request walks — the batch-size sweep behind
    // `speedup_batched_over_serial`. The mixed Flux policy routes the
    // balanced router's even layers FA / odd layers SA with sparse
    // decode, so every round exercises both kernel groups. ----
    let batch_sizes: &[usize] = if opts.smoke { &[2] } else { &[1, 2, 4, 8] };
    let batch_rounds = if opts.smoke { 3 } else { steps.max(8) };
    engine.set_zero_copy(true);
    engine.set_threads(opts.threads);
    let mixed_policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };
    let mut batched_scenarios = Json::Arr(vec![]);
    let mut speedup_batched = 0.0f64;
    for &bsz in batch_sizes {
        // fresh prefills per configuration so serial and batched start
        // from identical state; greedy determinism makes the token
        // streams comparable bit-for-bit
        let mut run = |batched: bool| -> Result<(Vec<Vec<u32>>, f64, f64, f64)> {
            engine.set_batch_decode(batched);
            let mut ids = Vec::with_capacity(bsz);
            for r in 0..bsz {
                let mut rng = Rng::seed_from_u64(100 + r as u64);
                let s = generate(Task::PRe, &mut rng, seq);
                let (id, _) = engine.prefill(&s.prompt, &mixed_policy, "balanced")?;
                ids.push(id);
            }
            for res in engine.decode_batch(&ids) {
                res?; // warmup round
            }
            let mut streams: Vec<Vec<u32>> = vec![Vec::new(); bsz];
            let (mut fa, mut sa) = (0u64, 0u64);
            let t0 = Instant::now();
            for _ in 0..batch_rounds {
                let rep = engine.decode_batch_report(&ids);
                for (stream, tok) in streams.iter_mut().zip(rep.tokens) {
                    stream.push(tok?);
                }
                fa += rep.fa_group_slots;
                sa += rep.sa_group_slots;
            }
            let elapsed_us = t0.elapsed().as_nanos() as f64 / 1e3;
            for id in ids {
                engine.release(id);
            }
            let per_round = batch_rounds.max(1) as f64;
            Ok((streams, elapsed_us, fa as f64 / per_round, sa as f64 / per_round))
        };
        let (serial_streams, serial_us, _, _) = run(false)?;
        let (batched_streams, batched_us, fa_per_round, sa_per_round) = run(true)?;
        let bit_identical = serial_streams == batched_streams;
        anyhow::ensure!(
            bit_identical,
            "batched decode diverged from the serial token streams at batch size {bsz}"
        );
        let tokens = (bsz * batch_rounds) as f64;
        let speedup = serial_us / batched_us.max(1e-9);
        println!(
            "decode/batched b={bsz:<2} serial {:>10.1} us/round  batched {:>10.1} us/round  \
             speedup {speedup:.2}x  groups fa {fa_per_round:.1} sa {sa_per_round:.1} /round",
            serial_us / batch_rounds.max(1) as f64,
            batched_us / batch_rounds.max(1) as f64,
        );
        let mut o = Json::obj();
        o.set("batch", Json::from(bsz));
        o.set("rounds", Json::from(batch_rounds));
        o.set("serial_tokens_per_s", Json::from(tokens / (serial_us / 1e6).max(1e-12)));
        o.set("batched_tokens_per_s", Json::from(tokens / (batched_us / 1e6).max(1e-12)));
        o.set("speedup_batched_over_serial", Json::from(speedup));
        o.set("bit_identical", Json::from(bit_identical));
        o.set("fa_group_slots_per_round", Json::from(fa_per_round));
        o.set("sa_group_slots_per_round", Json::from(sa_per_round));
        batched_scenarios.push(o);
        speedup_batched = speedup; // the sweep's largest batch size wins
    }

    // ---- bucket-padding utilization (DESIGN.md §10): monolithic pads
    // every prompt to its request-level bucket; chunked prefill pads
    // only the last chunk to its smallest covering bucket ----
    let pad_prompt = if opts.smoke { 300.min(max_prefill) } else { 600.min(max_prefill) };
    let pad_tokens: Vec<u32> = (0..pad_prompt).map(|i| (i as u32) % 250 + 1).collect();
    engine.rt.reset_stats();
    let (id, _) = engine.prefill(&pad_tokens, &Policy::Backbone, "balanced")?;
    engine.release(id);
    let (mono_rows_valid, mono_rows_padded) = engine.prefill_row_totals();
    engine.rt.reset_stats();
    let job = engine.prefill_open(&pad_tokens, &Policy::Backbone, "balanced", 128)?;
    loop {
        match engine.prefill_chunk(job)? {
            crate::engine::ChunkOutcome::More { .. } => {}
            crate::engine::ChunkOutcome::Done { id, .. } => {
                engine.release(id);
                break;
            }
        }
    }
    let (chunk_rows_valid, chunk_rows_padded) = engine.prefill_row_totals();
    let util = |v: u64, p: u64| v as f64 / ((v + p) as f64).max(1.0);
    let mono_util = util(mono_rows_valid, mono_rows_padded);
    let chunk_util = util(chunk_rows_valid, chunk_rows_padded);
    println!(
        "prefill padding ({pad_prompt} tokens): monolithic {:.1}% vs chunked {:.1}% row utilization",
        mono_util * 100.0,
        chunk_util * 100.0
    );

    // ---- chunked-prefill interference scenario (DESIGN.md §10):
    // decode gap p95 under a concurrent long-prompt arrival, monolithic
    // vs chunked, with the token streams compared bit-for-bit ----
    let inter_chunk_tokens = 128usize;
    let mono = run_interference(artifacts, opts, 0)?;
    let chunked = run_interference(artifacts, opts, inter_chunk_tokens)?;
    let bit_identical = mono.short_streams == chunked.short_streams
        && mono.long_tokens == chunked.long_tokens;
    anyhow::ensure!(
        bit_identical,
        "chunked prefill diverged from the monolithic token streams in the interference scenario"
    );
    let speedup_decode_p95 = mono.gap_p95_us / chunked.gap_p95_us.max(1e-9);
    println!(
        "prefill interference: decode gap p95 {:.1} us (monolithic) vs {:.1} us (chunked) \
         = {speedup_decode_p95:.2}x; long TTFT {:.1} ms vs {:.1} ms; chunks {} vs {}",
        mono.gap_p95_us,
        chunked.gap_p95_us,
        mono.long_ttft_us as f64 / 1e3,
        chunked.long_ttft_us as f64 / 1e3,
        mono.prefill_chunks,
        chunked.prefill_chunks
    );

    // ---- emit BENCH_prefill.json ----
    let fa_base = prefill_results[0].1.mean_us;
    let fa_par = prefill_results[1].1.mean_us;
    let mut jp = Json::obj();
    jp.set("schema", Json::from("flux-bench-prefill/v2"));
    jp.set("measured", Json::from(true));
    jp.set("seq_len", Json::from(seq));
    jp.set("prompt_len", Json::from(prompt_len));
    jp.set("threads", Json::from(opts.threads));
    let mut arr = Json::Arr(vec![]);
    for (label, st, tok) in &prefill_results {
        arr.push(stats_json(label, st, *tok));
    }
    jp.set("configs", arr);
    jp.set("ssa_optimized", stats_json("ssa_view_parallel", &ssa_st, ssa_tok_s));
    jp.set("fa_over_ssa_latency_ratio", Json::from(fa_par / ssa_st.mean_us.max(1e-9)));
    jp.set("speedup_parallel_over_baseline", Json::from(fa_base / fa_par.max(1e-9)));
    let mut jpad = Json::obj();
    jpad.set("prompt_tokens", Json::from(pad_prompt));
    jpad.set("chunk_tokens", Json::from(128usize));
    let pad_obj = |v: u64, p: u64, u: f64| {
        let mut o = Json::obj();
        o.set("rows_valid", Json::from(v as usize));
        o.set("rows_padded", Json::from(p as usize));
        o.set("utilization", Json::from(u));
        o
    };
    jpad.set("monolithic", pad_obj(mono_rows_valid, mono_rows_padded, mono_util));
    jpad.set("chunked", pad_obj(chunk_rows_valid, chunk_rows_padded, chunk_util));
    jp.set("padding", jpad);
    let mut ji = Json::obj();
    ji.set("long_prompt_tokens", Json::from(mono.long_prompt_tokens));
    ji.set("chunk_tokens", Json::from(inter_chunk_tokens));
    let inter_obj = |r: &InterferenceRun| {
        let mut o = Json::obj();
        o.set("decode_gap_p50_us", Json::from(r.gap_p50_us));
        o.set("decode_gap_p95_us", Json::from(r.gap_p95_us));
        o.set("decode_gap_max_us", Json::from(r.gap_max_us));
        o.set("long_ttft_us", Json::from(r.long_ttft_us as f64));
        o.set("prefill_chunks", Json::from(r.prefill_chunks as usize));
        o.set("decode_stall_us", Json::from(r.decode_stall_us as usize));
        o
    };
    ji.set("monolithic", inter_obj(&mono));
    ji.set("chunked", inter_obj(&chunked));
    ji.set("speedup_decode_p95", Json::from(speedup_decode_p95));
    ji.set(
        "speedup_decode_max_gap",
        Json::from(mono.gap_max_us / chunked.gap_max_us.max(1e-9)),
    );
    ji.set("bit_identical", Json::from(bit_identical));
    jp.set("interference", ji);
    let prefill_path = opts.out_dir.join("BENCH_prefill.json");
    std::fs::write(&prefill_path, jp.to_string())?;

    // ---- emit BENCH_decode.json ----
    let d_base = decode_results[0].1.mean_us;
    let d_view = decode_results[1].1.mean_us;
    let d_par = decode_results[2].1.mean_us;
    let mut jd = Json::obj();
    jd.set("schema", Json::from("flux-bench-decode/v2"));
    jd.set("measured", Json::from(true));
    jd.set("seq_len", Json::from(seq));
    jd.set("decode_tokens", Json::from(steps));
    jd.set("threads", Json::from(opts.threads));
    let mut arr = Json::Arr(vec![]);
    for (label, st, tok) in &decode_results {
        arr.push(stats_json(label, st, *tok));
    }
    jd.set("configs", arr);
    jd.set("sparse_optimized", stats_json("sa_view_parallel", &sparse_st, 1e6 / sparse_st.mean_us.max(1e-9)));
    jd.set("fa_over_sa_step_ratio", Json::from(d_par / sparse_st.mean_us.max(1e-9)));
    jd.set("speedup_view_over_clone", Json::from(d_base / d_view.max(1e-9)));
    jd.set("speedup_parallel_over_view_serial", Json::from(d_view / d_par.max(1e-9)));
    jd.set("speedup_total_over_baseline", Json::from(d_base / d_par.max(1e-9)));
    jd.set("kv_bytes_moved_fast_path", Json::from(kv_fast_path.0 as f64));
    jd.set("kv_bytes_borrowed_fast_path", Json::from(kv_fast_path.1 as f64));
    let mut jb = Json::obj();
    jb.set("batch_sizes", Json::from(batch_sizes.to_vec()));
    jb.set("scenarios", batched_scenarios);
    jd.set("batched", jb);
    jd.set("speedup_batched_over_serial", Json::from(speedup_batched));
    let decode_path = opts.out_dir.join("BENCH_decode.json");
    std::fs::write(&decode_path, jd.to_string())?;

    validate_bench_file(&prefill_path)?;
    validate_bench_file(&decode_path)?;
    validate_prefill_v2(&prefill_path)?;
    validate_decode_v2(&decode_path)?;
    println!(
        "decode speedup: view/clone {:.2}x, parallel/serial {:.2}x, total {:.2}x \
         (kv moved {} B, borrowed {} B on fast path)",
        d_base / d_view.max(1e-9),
        d_view / d_par.max(1e-9),
        d_base / d_par.max(1e-9),
        kv_fast_path.0,
        kv_fast_path.1
    );
    println!("(saved {prefill_path:?} and {decode_path:?})");
    Ok((prefill_path, decode_path))
}

// ---------------------------------------------------------------------------
// `flux bench` streaming scenario: BENCH_serving.json
// ---------------------------------------------------------------------------

/// Concurrent-streaming serving scenario over the real TCP wire: N
/// connections × M in-flight v2 streams each, with one stream per
/// connection cancelled mid-flight. Emits `BENCH_serving.json`
/// (schema `flux-bench-serving/v6`) recording aggregate streamed-token
/// throughput and cancelled-request cleanup: after the cancellations a
/// probe request must admit and complete (proving the scheduler
/// reclaimed the engine slots), and the coordinator's cancelled counter
/// must match what the clients aborted. The v2 schema adds the
/// pool-pressure scenario (DESIGN.md §11): a deliberately tiny page
/// pool serves one modest request while a long-prompt arrival is
/// rejected with a typed `overloaded` error, and the same prompts are
/// verified to decode bit-identically under 16- and 64-token pages.
/// The v3 schema adds the fault-recovery scenario (DESIGN.md §12): an
/// injected kernel panic kills the engine mid-decode, the victim must
/// fail with a typed error, and the supervisor must respawn the engine
/// fast enough that a re-submission of a known prompt completes with a
/// bit-identical stream; the ledger records the observed
/// time-to-readmit alongside the supervision counters. The v4 schema
/// adds the prefix-reuse scenario (DESIGN.md §13): sessions sharing a
/// long system prompt must hit the radix prefix cache, reuse the
/// shared run's KV, and stream bit-identically to a cold run of the
/// same prompt, with cold-vs-warm TTFT recorded. The v5 schema adds
/// the saturation scenario (DESIGN.md §14): an offered-load sweep over
/// 1-, 2- and 4-replica sets records per-level goodput and the TTFT
/// tail (the knee moves right as replicas are added, and load past the
/// queue watermark degrades into typed retryable rejections), plus a
/// replica-kill ledger — one replica of two dies mid-load, its queued
/// work fails over and completes on the survivor bit-identical to the
/// single-replica reference. The v6 schema adds the preemption
/// scenario (DESIGN.md §15): three dense streams co-admit under
/// route-aware optimistic admission on a pool sized below their
/// aggregate worst case, mid-decode capacity growth runs the pool dry,
/// a victim is preempted (pages freed, state snapshotted) and resumed
/// through recompute, and every stream still completes bit-identical
/// to a worst-case serial run of the same pool; the ledger records
/// preemption/resume counts, resume-latency percentiles, and goodput
/// under both admission modes.
pub fn run_streaming_bench(artifacts: &Path, opts: &ServingBenchOpts) -> Result<PathBuf> {
    use crate::config::{MetaConfig, ServingConfig};
    use crate::coordinator::{Coordinator, Request, RequestError};
    use crate::engine::{Engine, EngineHandle};
    use crate::router::{AttnMode, DecodeMode, Policy};
    use crate::runtime::chaos::{FaultKind, FaultPlan};
    use crate::server::{serve_listener, StreamClient, WireRequest};
    use crate::util::rng::Rng;
    use crate::workload::{generate, Task};

    let (n_conns, n_streams, max_new) = if opts.smoke { (2usize, 2usize, 4usize) } else { (4, 4, 16) };
    let meta = MetaConfig::load(artifacts)?;
    let n_layers = meta.model.n_layers;
    let engine = EngineHandle::spawn(artifacts.to_path_buf())?;
    let coord = Coordinator::start(engine, ServingConfig::default())?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            let _ = serve_listener(coord, listener, n_layers);
        });
    }

    let mut rng = Rng::seed_from_u64(21);
    let seq = opts.seq_len.min(128);
    let timeout = std::time::Duration::from_secs(120);
    let t0 = Instant::now();
    let mut workers = vec![];
    for _ in 0..n_conns {
        let prompts: Vec<Vec<u32>> =
            (0..n_streams).map(|_| generate(Task::PRe, &mut rng, seq).prompt).collect();
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || -> Result<(u64, u64)> {
            let client = StreamClient::connect(&addr)?;
            let mut streams = vec![];
            for (i, prompt) in prompts.into_iter().enumerate() {
                // stream 0 is the cancellation victim: give it a long
                // budget so the cancel always lands mid-generation
                let (mn, ie) = if i == 0 { (1024, true) } else { (max_new, false) };
                streams.push(client.open(&WireRequest {
                    prompt,
                    max_new: mn,
                    ignore_eos: ie,
                    ..Default::default()
                })?);
            }
            let victim = streams.remove(0);
            // cancel only once the victim is demonstrably mid-generation
            // (holding an engine slot): wait for a token frame, not just
            // the queued/prefilled admission events
            while let Some(j) = victim.recv_timeout(timeout) {
                if j.get("event").and_then(crate::util::json::Json::as_str) == Some("token") {
                    break;
                }
            }
            victim.cancel()?;
            let mut cancelled = 0u64;
            while let Some(j) = victim.recv_timeout(timeout) {
                if j.get("event").and_then(crate::util::json::Json::as_str) == Some("error") {
                    cancelled += 1;
                    break;
                }
            }
            let mut tokens = 0u64;
            for s in streams {
                let r = s.wait()?;
                anyhow::ensure!(r.error.is_none(), "stream failed: {:?}", r.error);
                tokens += r.tokens.len() as u64;
            }
            Ok((tokens, cancelled))
        }));
    }
    let mut tokens_streamed = 0u64;
    let mut cancelled = 0u64;
    for w in workers {
        let (t, c) = w.join().map_err(|_| anyhow::anyhow!("stream worker panicked"))??;
        tokens_streamed += t;
        cancelled += c;
    }
    let elapsed_s = t0.elapsed().as_secs_f64().max(1e-9);

    // cancelled-request cleanup: a fresh request must admit and complete
    // after the cancellations released their engine slots
    let probe = {
        let mut rng = Rng::seed_from_u64(22);
        let s = generate(Task::PRe, &mut rng, seq);
        coord.submit(Request { prompt: s.prompt, max_new: 2, ..Default::default() })
    };
    let cleanup_ok = probe.is_ok();
    anyhow::ensure!(cleanup_ok, "post-cancel probe request failed: {}", probe.err().unwrap());

    // ---- page-size bit-identity sweep (DESIGN.md §11): the pool's
    // page geometry is invisible to the math — the same mixed FA/SA
    // batch, including a mid-sweep retirement that frees and recycles
    // pages, must decode bit-identical token streams under 16- and
    // 64-token pages ----
    let sweep_page_tokens: [usize; 2] = [16, 64];
    let sweep_rounds = if opts.smoke { 6 } else { 40 };
    let sweep_budget = (*meta.prefill_buckets.last().unwrap() + meta.sa_buf) * n_layers * 8;
    let mixed_policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };
    let mut sweep_streams: Vec<Vec<Vec<u32>>> = Vec::new();
    for &pt in &sweep_page_tokens {
        let mut e = Engine::load_with_pool(artifacts, Some((pt, sweep_budget)))?;
        let mut rng = Rng::seed_from_u64(23);
        let mut ids = Vec::new();
        let mut order: Vec<usize> = (0..3).collect();
        let mut streams: Vec<Vec<u32>> = vec![Vec::new(); 3];
        for slot in 0..3 {
            let s = generate(Task::PRe, &mut rng, seq);
            let (id, r) = e.prefill(&s.prompt, &mixed_policy, "balanced")?;
            ids.push(id);
            streams[slot].push(r.first_token);
        }
        for round in 0..sweep_rounds {
            if round == sweep_rounds / 2 {
                // mid-sweep retirement: the middle request's pages go
                // back to the pool while its batchmates keep decoding
                let pos = 1.min(ids.len() - 1);
                e.release(ids.remove(pos));
                order.remove(pos);
            }
            for (slot, tok) in order.iter().zip(e.decode_batch(&ids)) {
                streams[*slot].push(tok?);
            }
        }
        for id in ids {
            e.release(id);
        }
        sweep_streams.push(streams);
    }
    let bit_identical = sweep_streams.windows(2).all(|w| w[0] == w[1]);
    anyhow::ensure!(
        bit_identical,
        "token streams diverged across page sizes {sweep_page_tokens:?}"
    );

    // ---- pool-pressure scenario (DESIGN.md §11): size the pool to
    // exactly one modest request's worst case; a long-prompt arrival
    // can then never fit and must be rejected with a typed
    // `overloaded` error at enqueue, while the modest request streams
    // to completion and its page occupancy lands in the metrics ----
    let pressure_page_tokens = 32usize;
    let pressure_budget = (meta.prefill_buckets[0] + meta.sa_buf) * n_layers;
    let pressure_engine =
        EngineHandle::spawn_with_pool(artifacts.to_path_buf(), pressure_page_tokens, pressure_budget)?;
    let total_pages = pressure_engine.pool_profile()?.total_pages;
    let pressure_coord = Coordinator::start(pressure_engine, ServingConfig::default())?;
    let modest = {
        let mut rng = Rng::seed_from_u64(24);
        generate(Task::PRe, &mut rng, seq.min(meta.prefill_buckets[0] - 8))
    };
    let resp = pressure_coord
        .submit(Request { prompt: modest.prompt, max_new: 4, ignore_eos: true, ..Default::default() })
        .map_err(|e| anyhow::anyhow!("modest request must fit the pressure pool: {e}"))?;
    anyhow::ensure!(resp.tokens.len() == 4, "pressure-pool request truncated");
    let long_prompt: Vec<u32> = (0..4 * meta.prefill_buckets[0]).map(|i| (i as u32) % 250 + 1).collect();
    let overload =
        pressure_coord.open(Request { prompt: long_prompt, max_new: 4, ..Default::default() });
    match overload {
        Err(RequestError::Overloaded { .. }) => {}
        Err(e) => anyhow::bail!("expected a typed Overloaded rejection, got {e:?}"),
        Ok(_) => anyhow::bail!("long prompt over the page budget must be rejected at enqueue"),
    }
    let mp = pressure_coord.metrics.lock().unwrap().clone();
    anyhow::ensure!(mp.pages_peak > 0, "pressure scenario recorded no page occupancy");
    anyhow::ensure!(mp.requests_overloaded >= 1, "typed overload was not counted");
    println!(
        "pool pressure: {} of {} pages peak under {}-token pages, {} overloaded rejection(s); \
         page-size sweep {:?} bit-identical",
        mp.pages_peak, total_pages, pressure_page_tokens, mp.requests_overloaded, sweep_page_tokens
    );

    // ---- fault-recovery scenario (DESIGN.md §12): inject a kernel
    // panic mid-decode, let the supervisor retire the victim with a
    // typed error and respawn the engine, then measure how long until
    // a re-submission is admitted and completes — and require its
    // token stream to be bit-identical to the pre-fault reference ----
    let fr_reference = {
        let mut rng = Rng::seed_from_u64(25);
        generate(Task::PRe, &mut rng, seq)
    };
    let fr_request = Request {
        prompt: fr_reference.prompt.clone(),
        max_new: 6,
        ignore_eos: true,
        ..Default::default()
    };
    let fr_expected = coord
        .submit(fr_request.clone())
        .map_err(|e| anyhow::anyhow!("fault-recovery reference request failed: {e}"))?
        .tokens;
    let fr_plan = FaultPlan::new().with(40, FaultKind::Panic);
    let fr_plan_spec = fr_plan.to_string();
    let fr_engine = EngineHandle::spawn_with_faults(artifacts.to_path_buf(), None, fr_plan)?;
    let fr_coord = Coordinator::start(
        fr_engine,
        ServingConfig { engine_restart_backoff_ms: 10, ..ServingConfig::default() },
    )?;
    let victim = fr_coord.submit(Request {
        prompt: fr_reference.prompt.clone(),
        max_new: 64,
        ignore_eos: true,
        ..Default::default()
    });
    anyhow::ensure!(
        victim.is_err(),
        "injected panic at call 40 should have killed the victim stream"
    );
    let t_dead = Instant::now();
    // the respawned engine is fault-free, so a retried submission must
    // eventually admit and complete; retry briefly to ride out the
    // restart backoff window
    let mut fr_tokens: Option<Vec<u32>> = None;
    for _ in 0..10 {
        match fr_coord.submit(fr_request.clone()) {
            Ok(r) => {
                fr_tokens = Some(r.tokens);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let fr_tokens = fr_tokens
        .ok_or_else(|| anyhow::anyhow!("engine never readmitted work after injected panic"))?;
    let time_to_readmit_ms = t_dead.elapsed().as_secs_f64() * 1e3;
    let fr_bit_identical = fr_tokens == fr_expected;
    anyhow::ensure!(
        fr_bit_identical,
        "post-restart stream diverged from pre-fault reference: {fr_tokens:?} vs {fr_expected:?}"
    );
    let fr_m = fr_coord.metrics.lock().unwrap().clone();
    anyhow::ensure!(
        fr_m.engine_restarts >= 1,
        "supervisor recorded no engine restart after injected panic"
    );
    println!(
        "fault recovery: plan [{fr_plan_spec}] killed the victim, engine respawned \
         ({} restart(s), {} failed), readmitted in {:.1}ms, post-restart stream bit-identical",
        fr_m.engine_restarts, fr_m.requests_failed, time_to_readmit_ms
    );

    // ---- prefix-reuse scenario (DESIGN.md §13): N sessions share a
    // long system prompt. The first (cold) session seeds the radix
    // prefix cache; every later session must hit it, skip the shared
    // run's prefill chunks, and still stream bit-identically to a cold
    // run of the same prompt. The mixed static FA/SSA sparse-decode
    // route exercises both the full-cache priming and the ring-snapshot
    // restore paths. ----
    let (pr_sessions, pr_prefix_len, pr_decode) =
        if opts.smoke { (2usize, 192usize, 3usize) } else { (4, 1024, 6) };
    let pr_page = crate::engine::Engine::DEFAULT_PAGE_TOKENS;
    let pr_prefix_len =
        pr_prefix_len.min(*meta.prefill_buckets.last().unwrap() - 64) / pr_page * pr_page;
    let mut pe = Engine::load(artifacts)?;
    pe.set_prefix_cache(true, None);
    let pr_modes: Vec<AttnMode> = (0..n_layers)
        .map(|l| if l % 2 == 0 { AttnMode::Fa } else { AttnMode::Ssa })
        .collect();
    let pr_policy = Policy::Static { modes: pr_modes, decode: DecodeMode::Sparse };
    let shared: Vec<u32> = (0..pr_prefix_len).map(|i| (i as u32) % 250 + 1).collect();
    let pr_run = |e: &mut Engine, prompt: &[u32]| -> Result<(Vec<u32>, f64, usize)> {
        let t_open = Instant::now();
        let job = e.prefill_open(prompt, &pr_policy, "balanced", 64)?;
        let (id, report) = loop {
            if let crate::engine::ChunkOutcome::Done { id, report } = e.prefill_chunk(job)? {
                break (id, report);
            }
        };
        let ttft_us = t_open.elapsed().as_nanos() as f64 / 1e3;
        let mut stream = vec![report.first_token];
        for _ in 0..pr_decode {
            stream.push(e.decode_step(id)?);
        }
        e.release(id);
        Ok((stream, ttft_us, report.cached_prefix_tokens))
    };
    let ref_prompt: Vec<u32> = {
        let mut p = shared.clone();
        p.extend((0..8u32).map(|k| (k * 37) % 250 + 1));
        p
    };
    let (cold_stream, ttft_cold_us, cold_cached) = pr_run(&mut pe, &ref_prompt)?;
    anyhow::ensure!(cold_cached == 0, "the first prefix-reuse session must run cold");
    let (warm_stream, warm_ttft_ref, warm_cached) = pr_run(&mut pe, &ref_prompt)?;
    let pr_bit_identical = warm_stream == cold_stream;
    anyhow::ensure!(
        pr_bit_identical,
        "warm prefix-hit stream diverged from the cold run: {warm_stream:?} vs {cold_stream:?}"
    );
    anyhow::ensure!(
        warm_cached == pr_prefix_len,
        "warm session reused {warm_cached} tokens, expected the {pr_prefix_len}-token shared run"
    );
    let mut ttft_warm: Vec<f64> = vec![warm_ttft_ref];
    for s in 1..pr_sessions {
        let mut p = shared.clone();
        p.extend((0..8u32).map(|k| ((s as u32 * 53 + k) * 37) % 250 + 1));
        let (_, t, cached) = pr_run(&mut pe, &p)?;
        anyhow::ensure!(
            cached == pr_prefix_len,
            "session {s} reused {cached} tokens, expected {pr_prefix_len}"
        );
        ttft_warm.push(t);
    }
    let st_warm = stats_of(&mut ttft_warm);
    let pstats = pe.prefix_stats();
    let hit_rate = pstats.hits as f64 / (pstats.hits + pstats.misses).max(1) as f64;
    let speedup_ttft = ttft_cold_us / st_warm.p50_us.max(1e-9);
    pe.prefix_clear();
    pe.pool().drained().map_err(|e| anyhow::anyhow!("prefix pool not drained: {e}"))?;
    println!(
        "prefix reuse: {pr_sessions} warm sessions over a {pr_prefix_len}-token shared prefix, \
         hit rate {hit_rate:.2}, {} tokens reused, TTFT {:.1} ms cold vs {:.1} ms warm p50 \
         ({speedup_ttft:.2}x), streams bit-identical",
        pstats.tokens_reused,
        ttft_cold_us / 1e3,
        st_warm.p50_us / 1e3
    );

    // ---- saturation scenario (DESIGN.md §14): data-parallel replica
    // scale-out. For each replica count, sweep offered load (sessions
    // opened back-to-back) against small per-replica active slots and
    // a queue watermark, recording goodput (tokens of COMPLETED streams
    // per second) and the TTFT tail. Load beyond the watermark degrades
    // into typed retryable rejections, never collapse. ----
    use crate::coordinator::{Response, SessionEvent, SessionHandle};
    let drain_one = |h: &SessionHandle| -> (Option<Response>, Option<RequestError>) {
        let (mut done, mut error) = (None, None);
        while let Some(ev) = h.recv_timeout(timeout) {
            match ev {
                SessionEvent::Done { stats } => done = Some(stats),
                SessionEvent::Error { error: e } => error = Some(e),
                _ => {}
            }
        }
        (done, error)
    };
    let sat_replica_counts: Vec<usize> = if opts.smoke { vec![1, 2] } else { vec![1, 2, 4] };
    let sat_levels: Vec<usize> = if opts.smoke { vec![2, 6] } else { vec![4, 12, 24] };
    let sat_max_new = if opts.smoke { 4usize } else { 8 };
    let sat_seq = seq.min(64);
    let mut sat_runs: Vec<Json> = Vec::new();
    for &nrep in &sat_replica_counts {
        let engines = (0..nrep)
            .map(|i| EngineHandle::spawn_replica(artifacts.to_path_buf(), i))
            .collect::<Result<Vec<_>>>()?;
        let sat_coord = Coordinator::start_replicas(
            engines,
            ServingConfig {
                max_active_requests: 2,
                queue_high_watermark: Some(4),
                ..ServingConfig::default()
            },
        )?;
        let mut sweep: Vec<Json> = Vec::new();
        for &offered in &sat_levels {
            let mut rng = Rng::seed_from_u64(26);
            let t_level = Instant::now();
            let opened: Vec<_> = (0..offered)
                .map(|_| {
                    let s = generate(Task::PRe, &mut rng, sat_seq);
                    sat_coord.open(Request {
                        prompt: s.prompt,
                        max_new: sat_max_new,
                        ignore_eos: true,
                        ..Default::default()
                    })
                })
                .collect();
            let (mut completed, mut rejected, mut tokens) = (0usize, 0usize, 0usize);
            let mut ttfts: Vec<f64> = Vec::new();
            for o in opened {
                match o {
                    Ok(h) => match drain_one(&h) {
                        (Some(done), None) => {
                            completed += 1;
                            tokens += done.tokens.len();
                            ttfts.push(done.ttft_us as f64);
                        }
                        (_, err) => {
                            anyhow::bail!("saturation stream failed without a fault: {err:?}")
                        }
                    },
                    Err(e) => {
                        anyhow::ensure!(
                            e.retryable(),
                            "saturation overload must reject retryable, got {e:?}"
                        );
                        rejected += 1;
                    }
                }
            }
            anyhow::ensure!(completed >= 1, "offered load {offered} completed nothing");
            let level_s = t_level.elapsed().as_secs_f64().max(1e-9);
            let st = stats_of(&mut ttfts);
            let mut lv = Json::obj();
            lv.set("offered_sessions", Json::from(offered));
            lv.set("completed", Json::from(completed));
            lv.set("rejected", Json::from(rejected));
            lv.set("goodput_tokens_per_s", Json::from(tokens as f64 / level_s));
            lv.set("ttft_p50_us", Json::from(st.p50_us));
            lv.set("ttft_p95_us", Json::from(st.p95_us));
            sweep.push(lv);
        }
        let sm = sat_coord.metrics.lock().unwrap().clone();
        let mut run = Json::obj();
        run.set("replicas", Json::from(nrep));
        run.set("sweep", Json::from(sweep));
        run.set("watermark_rejections", Json::from(sm.watermark_rejections as usize));
        println!(
            "saturation: {nrep} replica(s) over offered loads {sat_levels:?}, \
             {} watermark rejection(s)",
            sm.watermark_rejections
        );
        sat_runs.push(run);
    }

    // ---- replica-kill recovery at load: identical prompts alternate
    // deterministically across two replicas (r0, r1, r0, r1), so when
    // replica 1 dies at backend call 30 it holds one in-flight victim
    // (fails typed) and one queued request, which must fail over and
    // complete on the survivor bit-identical to the single-replica
    // reference. `time_to_failover_ms` is measured from the first
    // observed failure to the last completion — an upper bound, since
    // streams are drained sequentially. ----
    let sk_req = {
        let mut rng = Rng::seed_from_u64(27);
        Request {
            prompt: generate(Task::PRe, &mut rng, sat_seq).prompt,
            max_new: sat_max_new,
            ignore_eos: true,
            ..Default::default()
        }
    };
    let sk_expected = coord
        .submit(sk_req.clone())
        .map_err(|e| anyhow::anyhow!("replica-kill reference request failed: {e}"))?
        .tokens;
    let sk_plan = FaultPlan::new().with(30, FaultKind::Panic);
    let sk_plan_spec = sk_plan.to_string();
    let sk_e0 = EngineHandle::spawn_replica(artifacts.to_path_buf(), 0)?;
    let sk_e1 =
        EngineHandle::spawn_replica_with(artifacts.to_path_buf(), None, Some(sk_plan), 1)?;
    let sk_coord = Coordinator::start_replicas(
        vec![sk_e0, sk_e1],
        ServingConfig {
            max_active_requests: 1,
            engine_restart_max: 0,
            ..ServingConfig::default()
        },
    )?;
    let sk_handles: Vec<SessionHandle> = (0..4)
        .map(|_| sk_coord.open(sk_req.clone()))
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("replica-kill pass admission failed: {e:?}"))?;
    let (mut sk_completed, mut sk_failed) = (0usize, 0usize);
    let mut sk_bit_identical = true;
    let mut t_first_failure: Option<Instant> = None;
    let mut failover_ms = 0.0f64;
    for h in &sk_handles {
        match drain_one(h) {
            (Some(done), None) => {
                sk_completed += 1;
                sk_bit_identical &= done.tokens == sk_expected;
                if let Some(t) = t_first_failure {
                    failover_ms = t.elapsed().as_secs_f64() * 1e3;
                }
            }
            (None, Some(RequestError::EngineFailed { replica, .. })) => {
                anyhow::ensure!(replica == 1, "only replica 1 was faulted, got replica {replica}");
                sk_failed += 1;
                t_first_failure.get_or_insert_with(Instant::now);
            }
            other => anyhow::bail!("replica-kill pass: unexpected terminal {other:?}"),
        }
    }
    anyhow::ensure!(
        sk_failed == 1 && sk_completed == 3,
        "replica kill must fail exactly the in-flight victim ({sk_failed} failed, \
         {sk_completed} completed)"
    );
    anyhow::ensure!(sk_bit_identical, "failover streams diverged from the reference");
    let sk_m = sk_coord.metrics.lock().unwrap().clone();
    anyhow::ensure!(
        sk_m.dispatch_failovers >= 1,
        "replica kill recorded no dispatch failover"
    );
    println!(
        "replica kill: plan [{sk_plan_spec}] on replica 1 of 2 — victim failed typed, \
         {} failover(s) completed on the survivor in ≤{failover_ms:.1}ms, bit-identical",
        sk_m.dispatch_failovers
    );

    // ---- preemption scenario (DESIGN.md §15): route-aware optimistic
    // admission on a pool sized BELOW the aggregate worst case. Three
    // concurrent dense streams co-admit under `Optimistic { 0.5 }`; a
    // younger stream's capacity growth at the bucket edge runs the
    // pool dry, the elder is preempted (pages freed, state
    // snapshotted) and later resumed through recompute — and ALL
    // streams complete with token streams bit-identical to the same
    // pool under `WorstCase` serial admission, whose goodput is the
    // comparison baseline. ----
    use crate::config::AdmissionMode;
    use crate::engine::PoolProfile;
    let pm_page_tokens = 32usize;
    let pm_bucket = *meta.prefill_buckets.first().unwrap();
    // prompt and budget at 3/4 of the first bucket: the stream starts
    // in bucket b0 and must double to 2*b0 mid-decode
    let (pm_prompt, pm_max_new) = (pm_bucket * 3 / 4, pm_bucket * 3 / 4);
    let pm_profile = PoolProfile {
        page_tokens: pm_page_tokens,
        total_pages: 0,
        n_layers,
        sa_buf: meta.sa_buf,
        prefill_buckets: meta.prefill_buckets.clone(),
    };
    let pm_worst = pm_profile.worst_case_pages(pm_prompt, pm_max_new);
    let pm_routed = pm_profile.routed_pages(
        pm_prompt,
        pm_max_new,
        &vec![AttnMode::Fa; n_layers],
        DecodeMode::Dense,
    );
    // one fully-grown stream plus half a worst case: two optimistic
    // charges fit, two grown streams do not — growth must preempt
    let pm_pages = pm_routed + pm_worst.div_ceil(2);
    let pm_reqs: Vec<Request> = {
        let mut rng = Rng::seed_from_u64(28);
        (0..3)
            .map(|_| Request {
                prompt: generate(Task::PRe, &mut rng, pm_prompt).prompt,
                max_new: pm_max_new,
                ignore_eos: true,
                ..Default::default()
            })
            .collect()
    };
    // worst-case reference on the SAME pool: serial admission — the
    // goodput baseline and the bit-identity oracle
    let pm_ref_engine = EngineHandle::spawn_with_pool(
        artifacts.to_path_buf(),
        pm_page_tokens,
        pm_pages * pm_page_tokens,
    )?;
    let pm_ref_coord = Coordinator::start(pm_ref_engine, ServingConfig::default())?;
    let t_ref = Instant::now();
    let pm_expected: Vec<Vec<u32>> = pm_reqs
        .iter()
        .map(|r| pm_ref_coord.submit(r.clone()).map(|resp| resp.tokens))
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("worst-case reference stream failed: {e:?}"))?;
    let pm_ref_s = t_ref.elapsed().as_secs_f64().max(1e-9);
    let pm_ref_tokens: usize = pm_expected.iter().map(Vec::len).sum();
    anyhow::ensure!(
        pm_ref_coord.metrics.lock().unwrap().preemptions == 0,
        "WorstCase admission must reproduce serial decisions exactly (no preemption)"
    );

    let pm_engine = EngineHandle::spawn_with_pool(
        artifacts.to_path_buf(),
        pm_page_tokens,
        pm_pages * pm_page_tokens,
    )?;
    let pm_coord = Coordinator::start(
        pm_engine,
        ServingConfig {
            admission_mode: AdmissionMode::Optimistic { factor: 0.5 },
            ..ServingConfig::default()
        },
    )?;
    let t_opt = Instant::now();
    let pm_handles: Vec<SessionHandle> = pm_reqs
        .iter()
        .map(|r| pm_coord.open(r.clone()))
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("optimistic admission rejected a stream: {e:?}"))?;
    let (mut pm_tokens, mut pm_completed) = (0usize, 0usize);
    let mut pm_bit_identical = true;
    for (h, expected) in pm_handles.iter().zip(&pm_expected) {
        match drain_one(h) {
            (Some(done), None) => {
                pm_completed += 1;
                pm_tokens += done.tokens.len();
                pm_bit_identical &= &done.tokens == expected;
            }
            other => anyhow::bail!("preemption scenario stream failed: {other:?}"),
        }
    }
    let pm_opt_s = t_opt.elapsed().as_secs_f64().max(1e-9);
    let pm_m = pm_coord.metrics.lock().unwrap().clone();
    anyhow::ensure!(
        pm_m.preemptions >= 1 && pm_m.resumes >= 1,
        "undersized pool never preempted (pool {pm_pages} pages, worst case {pm_worst} x 3)"
    );
    anyhow::ensure!(
        pm_bit_identical,
        "resumed streams diverged from the worst-case serial reference"
    );
    println!(
        "preemption: {} preemption(s), {} resume(s), {} page(s) freed over a {pm_pages}-page \
         pool (worst case {pm_worst} x 3 streams), resume p50 {}us p95 {}us, goodput \
         {:.1} tok/s optimistic vs {:.1} tok/s worst-case",
        pm_m.preemptions,
        pm_m.resumes,
        pm_m.preempted_pages_freed,
        pm_m.resume_latency.p50_us(),
        pm_m.resume_latency.p95_us(),
        pm_tokens as f64 / pm_opt_s,
        pm_ref_tokens as f64 / pm_ref_s,
    );

    let m = coord.metrics.lock().unwrap().clone();
    let mut j = Json::obj();
    j.set("schema", Json::from("flux-bench-serving/v6"));
    j.set("measured", Json::from(true));
    j.set("connections", Json::from(n_conns));
    j.set("streams_per_connection", Json::from(n_streams));
    j.set("tokens_streamed", Json::from(tokens_streamed as usize));
    j.set("tokens_per_s", Json::from(tokens_streamed as f64 / elapsed_s));
    j.set("cancelled_requests", Json::from(cancelled as usize));
    j.set("coordinator_cancelled", Json::from(m.requests_cancelled as usize));
    j.set("requests_expired", Json::from(m.requests_expired as usize));
    j.set("cancelled_cleanup_ok", Json::from(cleanup_ok));
    j.set("stream_tokens_p50", Json::from(m.stream_tokens.p50_us() as usize));
    j.set("metrics_summary", Json::from(m.summary()));
    let mut jp = Json::obj();
    jp.set("page_tokens", Json::from(pressure_page_tokens));
    jp.set("total_pages", Json::from(total_pages));
    jp.set("pages_peak", Json::from(mp.pages_peak as usize));
    jp.set("overloaded_rejections", Json::from(mp.requests_overloaded as usize));
    jp.set("page_size_sweep", Json::from(sweep_page_tokens.to_vec()));
    jp.set("bit_identical", Json::from(bit_identical));
    jp.set("pressure_metrics_summary", Json::from(mp.summary()));
    j.set("pool_pressure", jp);
    j.set("requests_failed", Json::from(m.requests_failed as usize));
    j.set("engine_restarts", Json::from(m.engine_restarts as usize));
    j.set("watchdog_trips", Json::from(m.watchdog_trips as usize));
    let mut jf = Json::obj();
    jf.set("fault_plan", Json::from(fr_plan_spec));
    jf.set("engine_restarts", Json::from(fr_m.engine_restarts as usize));
    jf.set("watchdog_trips", Json::from(fr_m.watchdog_trips as usize));
    jf.set("requests_failed", Json::from(fr_m.requests_failed as usize));
    jf.set("time_to_readmit_ms", Json::from(time_to_readmit_ms));
    jf.set("recovered", Json::from(true));
    jf.set("bit_identical", Json::from(fr_bit_identical));
    j.set("fault_recovery", jf);
    let mut jr = Json::obj();
    jr.set("sessions", Json::from(pr_sessions + 1));
    jr.set("prefix_tokens", Json::from(pr_prefix_len));
    jr.set("hits", Json::from(pstats.hits as usize));
    jr.set("misses", Json::from(pstats.misses as usize));
    jr.set("hit_rate", Json::from(hit_rate));
    jr.set("tokens_reused", Json::from(pstats.tokens_reused as usize));
    jr.set("evictions", Json::from(pstats.evictions as usize));
    jr.set("ttft_cold_us", Json::from(ttft_cold_us));
    jr.set("ttft_warm_p50_us", Json::from(st_warm.p50_us));
    jr.set("speedup_ttft", Json::from(speedup_ttft));
    jr.set("bit_identical", Json::from(pr_bit_identical));
    j.set("prefix_reuse", jr);
    let mut jsat = Json::obj();
    jsat.set("replica_counts", Json::from(sat_replica_counts.clone()));
    jsat.set("offered_levels", Json::from(sat_levels.clone()));
    jsat.set("max_new", Json::from(sat_max_new));
    jsat.set("runs", Json::from(sat_runs));
    let mut jk = Json::obj();
    jk.set("replicas", Json::from(2usize));
    jk.set("fault_plan", Json::from(sk_plan_spec));
    jk.set("failed_streams", Json::from(sk_failed));
    jk.set("failover_completions", Json::from(sk_m.dispatch_failovers as usize));
    jk.set("replica_deaths", Json::from(sk_m.replicas[1].deaths as usize));
    jk.set("time_to_failover_ms", Json::from(failover_ms));
    jk.set("recovered", Json::from(true));
    jk.set("bit_identical", Json::from(sk_bit_identical));
    jsat.set("replica_kill", jk);
    j.set("saturation", jsat);
    let mut jpe = Json::obj();
    jpe.set("pool_pages", Json::from(pm_pages));
    jpe.set("page_tokens", Json::from(pm_page_tokens));
    jpe.set("streams", Json::from(3usize));
    jpe.set("worst_case_pages", Json::from(pm_worst));
    jpe.set("routed_pages", Json::from(pm_routed));
    jpe.set("admission_factor", Json::from(0.5));
    jpe.set("preemptions", Json::from(pm_m.preemptions as usize));
    jpe.set("resumes", Json::from(pm_m.resumes as usize));
    jpe.set("preempted_pages_freed", Json::from(pm_m.preempted_pages_freed as usize));
    jpe.set("resume_p50_us", Json::from(pm_m.resume_latency.p50_us() as usize));
    jpe.set("resume_p95_us", Json::from(pm_m.resume_latency.p95_us() as usize));
    jpe.set(
        "goodput_optimistic_tokens_per_s",
        Json::from(pm_tokens as f64 / pm_opt_s),
    );
    jpe.set(
        "goodput_worst_case_tokens_per_s",
        Json::from(pm_ref_tokens as f64 / pm_ref_s),
    );
    jpe.set("all_streams_completed", Json::from(pm_completed == 3));
    jpe.set("bit_identical", Json::from(pm_bit_identical));
    j.set("preemption", jpe);
    let path = opts.out_dir.join("BENCH_serving.json");
    std::fs::write(&path, j.to_string())?;
    validate_serving(&path)?;

    anyhow::ensure!(
        tokens_streamed > 0 && cancelled >= 1 && m.requests_cancelled >= cancelled,
        "streaming bench failed validation: {} tokens, {} cancelled (coordinator saw {})",
        tokens_streamed,
        cancelled,
        m.requests_cancelled
    );
    println!(
        "streaming bench: {tokens_streamed} tokens over {n_conns} conns x {n_streams} streams \
         ({:.1} tok/s), {cancelled} cancelled, cleanup ok",
        tokens_streamed as f64 / elapsed_s
    );
    println!("(saved {path:?})");
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        b.run("noop", 2, 16, || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].1.mean_us >= 0.0);
        assert!(b.results[0].1.p95_us >= b.results[0].1.p50_us);
    }

    #[test]
    fn serving_bench_validation_gates_on_throughput() {
        let dir = std::env::temp_dir().join(format!("flux-bench-validate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.json");
        std::fs::write(&bad, r#"{"configs": []}"#).unwrap();
        assert!(validate_bench_file(&bad).is_err(), "empty configs must fail validation");
        let zero = dir.join("zero.json");
        std::fs::write(&zero, r#"{"configs": [{"tokens_per_s": 0.0}]}"#).unwrap();
        assert!(validate_bench_file(&zero).is_err(), "zero tokens/s must fail validation");
        let good = dir.join("good.json");
        std::fs::write(&good, r#"{"configs": [{"tokens_per_s": 12.5}]}"#).unwrap();
        validate_bench_file(&good).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefill_v2_validation_gates_on_interference_fields() {
        let dir = std::env::temp_dir().join(format!("flux-bench-pv2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("v1.json");
        std::fs::write(&old, r#"{"schema": "flux-bench-prefill/v1"}"#).unwrap();
        assert!(validate_prefill_v2(&old).is_err(), "v1 schema must fail the v2 gate");
        let diverged = dir.join("diverged.json");
        std::fs::write(
            &diverged,
            r#"{"schema": "flux-bench-prefill/v2",
                "interference": {"bit_identical": false, "speedup_decode_p95": 2.0,
                    "monolithic": {"decode_gap_p95_us": 900.0, "long_ttft_us": 5000.0},
                    "chunked": {"decode_gap_p95_us": 450.0, "long_ttft_us": 6000.0}},
                "padding": {"monolithic": {"utilization": 0.5},
                            "chunked": {"utilization": 0.9}}}"#,
        )
        .unwrap();
        assert!(validate_prefill_v2(&diverged).is_err(), "non-bit-identical streams must fail");
        let no_pad = dir.join("no_pad.json");
        std::fs::write(
            &no_pad,
            r#"{"schema": "flux-bench-prefill/v2",
                "interference": {"bit_identical": true, "speedup_decode_p95": 2.0,
                    "monolithic": {"decode_gap_p95_us": 900.0, "long_ttft_us": 5000.0},
                    "chunked": {"decode_gap_p95_us": 450.0, "long_ttft_us": 6000.0}}}"#,
        )
        .unwrap();
        assert!(validate_prefill_v2(&no_pad).is_err(), "missing padding ledger must fail");
        let good = dir.join("good.json");
        std::fs::write(
            &good,
            r#"{"schema": "flux-bench-prefill/v2",
                "interference": {"bit_identical": true, "speedup_decode_p95": 2.0,
                    "monolithic": {"decode_gap_p95_us": 900.0, "long_ttft_us": 5000.0},
                    "chunked": {"decode_gap_p95_us": 450.0, "long_ttft_us": 6000.0}},
                "padding": {"monolithic": {"utilization": 0.5},
                            "chunked": {"utilization": 0.9}}}"#,
        )
        .unwrap();
        validate_prefill_v2(&good).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serving_v6_validation_gates_on_pool_fault_prefix_saturation_and_preemption() {
        let dir = std::env::temp_dir().join(format!("flux-bench-sv6-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("v5.json");
        std::fs::write(&old, r#"{"schema": "flux-bench-serving/v5", "tokens_per_s": 10.0}"#)
            .unwrap();
        assert!(validate_serving(&old).is_err(), "v5 schema must fail the v6 gate");
        let no_pool = dir.join("no_pool.json");
        std::fs::write(&no_pool, r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0}"#)
            .unwrap();
        assert!(validate_serving(&no_pool).is_err(), "missing pool_pressure must fail");
        let idle = dir.join("idle.json");
        std::fs::write(
            &idle,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 0, "overloaded_rejections": 1,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "bit_identical": true}}"#,
        )
        .unwrap();
        assert!(validate_serving(&idle).is_err(), "zero pages_peak must fail");
        let unrejected = dir.join("unrejected.json");
        std::fs::write(
            &unrejected,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 0,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "bit_identical": true}}"#,
        )
        .unwrap();
        assert!(validate_serving(&unrejected).is_err(), "no overloaded rejection must fail");
        let diverged = dir.join("diverged.json");
        std::fs::write(
            &diverged,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": false},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "bit_identical": true}}"#,
        )
        .unwrap();
        assert!(validate_serving(&diverged).is_err(), "diverged page-size sweep must fail");
        let no_fault = dir.join("no_fault.json");
        std::fs::write(
            &no_fault,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": true}}"#,
        )
        .unwrap();
        assert!(validate_serving(&no_fault).is_err(), "missing fault_recovery must fail");
        let unrecovered = dir.join("unrecovered.json");
        std::fs::write(
            &unrecovered,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": false, "engine_restarts": 0,
                                   "bit_identical": false}}"#,
        )
        .unwrap();
        assert!(validate_serving(&unrecovered).is_err(), "unrecovered engine must fail");
        let no_prefix = dir.join("no_prefix.json");
        std::fs::write(
            &no_prefix,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "bit_identical": true}}"#,
        )
        .unwrap();
        assert!(validate_serving(&no_prefix).is_err(), "missing prefix_reuse must fail");
        let cold_prefix = dir.join("cold_prefix.json");
        std::fs::write(
            &cold_prefix,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "bit_identical": true},
                "prefix_reuse": {"hit_rate": 0.0, "tokens_reused": 0,
                                 "ttft_cold_us": 900.0, "ttft_warm_p50_us": 300.0,
                                 "bit_identical": true}}"#,
        )
        .unwrap();
        assert!(validate_serving(&cold_prefix).is_err(), "zero hit rate must fail");
        let warm_diverged = dir.join("warm_diverged.json");
        std::fs::write(
            &warm_diverged,
            r#"{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0,
                "pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "bit_identical": true},
                "prefix_reuse": {"hit_rate": 0.8, "tokens_reused": 4096,
                                 "ttft_cold_us": 900.0, "ttft_warm_p50_us": 300.0,
                                 "bit_identical": false}}"#,
        )
        .unwrap();
        assert!(validate_serving(&warm_diverged).is_err(), "diverged warm stream must fail");
        let complete_scenarios = r#""pool_pressure": {"pages_peak": 40, "overloaded_rejections": 1,
                                  "bit_identical": true},
                "fault_recovery": {"recovered": true, "engine_restarts": 1,
                                   "time_to_readmit_ms": 30.5, "bit_identical": true},
                "prefix_reuse": {"hit_rate": 0.8, "tokens_reused": 4096,
                                 "ttft_cold_us": 900.0, "ttft_warm_p50_us": 300.0,
                                 "bit_identical": true}"#;
        let no_sat = dir.join("no_sat.json");
        std::fs::write(
            &no_sat,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios}}}"#
            ),
        )
        .unwrap();
        assert!(validate_serving(&no_sat).is_err(), "missing saturation must fail");
        let solo = dir.join("solo.json");
        std::fs::write(
            &solo,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios},
                "saturation": {{"runs": [{{"replicas": 1,
                        "sweep": [{{"goodput_tokens_per_s": 50.0}}]}}],
                    "replica_kill": {{"recovered": true, "failover_completions": 1,
                                      "bit_identical": true}}}}}}"#
            ),
        )
        .unwrap();
        assert!(validate_serving(&solo).is_err(), "single-replica-only saturation must fail");
        let no_failover = dir.join("no_failover.json");
        std::fs::write(
            &no_failover,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios},
                "saturation": {{"runs": [
                        {{"replicas": 1, "sweep": [{{"goodput_tokens_per_s": 50.0}}]}},
                        {{"replicas": 2, "sweep": [{{"goodput_tokens_per_s": 90.0}}]}}],
                    "replica_kill": {{"recovered": true, "failover_completions": 0,
                                      "bit_identical": true}}}}}}"#
            ),
        )
        .unwrap();
        assert!(validate_serving(&no_failover).is_err(), "zero failovers must fail");
        let full_saturation = r#""saturation": {"replica_counts": [1, 2], "runs": [
                        {"replicas": 1, "sweep": [{"offered_sessions": 4,
                            "goodput_tokens_per_s": 50.0, "ttft_p95_us": 900.0}]},
                        {"replicas": 2, "sweep": [{"offered_sessions": 4,
                            "goodput_tokens_per_s": 90.0, "ttft_p95_us": 500.0}]}],
                    "replica_kill": {"replicas": 2, "recovered": true,
                                      "failover_completions": 2,
                                      "time_to_failover_ms": 120.5,
                                      "bit_identical": true}}"#;
        let no_preempt = dir.join("no_preempt.json");
        std::fs::write(
            &no_preempt,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios},
                {full_saturation}}}"#
            ),
        )
        .unwrap();
        assert!(validate_serving(&no_preempt).is_err(), "missing preemption ledger must fail");
        let never_preempted = dir.join("never_preempted.json");
        std::fs::write(
            &never_preempted,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios},
                {full_saturation},
                "preemption": {{"preemptions": 0, "resumes": 0,
                    "all_streams_completed": true, "bit_identical": true,
                    "goodput_optimistic_tokens_per_s": 60.0,
                    "goodput_worst_case_tokens_per_s": 40.0}}}}"#
            ),
        )
        .unwrap();
        assert!(
            validate_serving(&never_preempted).is_err(),
            "a pool that never preempted must fail (the scenario proved nothing)"
        );
        let preempt_diverged = dir.join("preempt_diverged.json");
        std::fs::write(
            &preempt_diverged,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios},
                {full_saturation},
                "preemption": {{"preemptions": 2, "resumes": 2,
                    "all_streams_completed": true, "bit_identical": false,
                    "goodput_optimistic_tokens_per_s": 60.0,
                    "goodput_worst_case_tokens_per_s": 40.0}}}}"#
            ),
        )
        .unwrap();
        assert!(
            validate_serving(&preempt_diverged).is_err(),
            "resumed streams diverging from the serial reference must fail"
        );
        let good = dir.join("good.json");
        std::fs::write(
            &good,
            format!(
                r#"{{"schema": "flux-bench-serving/v6", "tokens_per_s": 10.0, {complete_scenarios},
                {full_saturation},
                "preemption": {{"preemptions": 2, "resumes": 2,
                    "preempted_pages_freed": 32,
                    "resume_p50_us": 1800, "resume_p95_us": 2400,
                    "all_streams_completed": true, "bit_identical": true,
                    "goodput_optimistic_tokens_per_s": 60.0,
                    "goodput_worst_case_tokens_per_s": 40.0}}}}"#
            ),
        )
        .unwrap();
        validate_serving(&good).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decode_v2_validation_gates_on_batched_fields() {
        let dir = std::env::temp_dir().join(format!("flux-bench-v2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let old = dir.join("v1.json");
        std::fs::write(&old, r#"{"schema": "flux-bench-decode/v1"}"#).unwrap();
        assert!(validate_decode_v2(&old).is_err(), "v1 schema must fail the v2 gate");
        let missing = dir.join("missing.json");
        std::fs::write(
            &missing,
            r#"{"schema": "flux-bench-decode/v2", "speedup_batched_over_serial": 1.5,
                "batched": {"scenarios": []}}"#,
        )
        .unwrap();
        assert!(validate_decode_v2(&missing).is_err(), "empty scenarios must fail");
        let diverged = dir.join("diverged.json");
        std::fs::write(
            &diverged,
            r#"{"schema": "flux-bench-decode/v2", "speedup_batched_over_serial": 1.5,
                "batched": {"scenarios": [{"bit_identical": false,
                                           "batched_tokens_per_s": 10.0}]}}"#,
        )
        .unwrap();
        assert!(validate_decode_v2(&diverged).is_err(), "non-bit-identical streams must fail");
        let good = dir.join("good.json");
        std::fs::write(
            &good,
            r#"{"schema": "flux-bench-decode/v2", "speedup_batched_over_serial": 1.5,
                "batched": {"scenarios": [{"bit_identical": true,
                                           "batched_tokens_per_s": 10.0}]}}"#,
        )
        .unwrap();
        validate_decode_v2(&good).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
