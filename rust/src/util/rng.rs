//! Deterministic RNG substrate (no `rand` crate in the vendor set):
//! SplitMix64 core with the sampling helpers the workload generators
//! need (ranges, floats, shuffles, multinomial-ish scatter gaps).

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// uniform in [0, n) — n must be > 0
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift rejection-free (bias < 2^-64 * n, negligible)
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// uniform in [lo, hi)
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.gen_range(hi - lo)
    }

    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.gen_range((hi - lo) as usize) as u32
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.gen_range((hi - lo) as usize) as i64
    }

    /// uniform f64 in [0, 1)
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }

    /// pick k distinct values from [lo, hi)
    pub fn choose_distinct_u32(&mut self, lo: u32, hi: u32, k: usize) -> Vec<u32> {
        let mut pool: Vec<u32> = (lo..hi).collect();
        self.shuffle(&mut pool);
        pool.truncate(k);
        pool
    }

    /// categorical sample over unnormalized weights
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let total: f64 = probs.iter().sum();
        let mut r = self.f64() * total;
        for (i, &p) in probs.iter().enumerate() {
            r -= p;
            if r < 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// exponential inter-arrival (Poisson process), rate per second
    pub fn exp_ms(&mut self, rate_per_s: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate_per_s * 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::seed_from_u64(2);
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn distinct_choice() {
        let mut r = Rng::seed_from_u64(4);
        let v = r.choose_distinct_u32(10, 30, 5);
        assert_eq!(v.len(), 5);
        let mut s = v.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
        assert!(v.iter().all(|&x| (10..30).contains(&x)));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seed_from_u64(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[0.6, 0.25, 0.15])] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
        assert!((counts[0] as f64 / 30_000.0 - 0.6).abs() < 0.03);
    }
}
