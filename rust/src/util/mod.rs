//! In-crate substrates for the offline build environment (DESIGN.md §4):
//! JSON, deterministic RNG, bench harness and property-test runner.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
