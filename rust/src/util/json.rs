//! Minimal JSON substrate (no serde in the offline vendor set).
//!
//! Supports the full JSON value model with a recursive-descent parser
//! and a writer; enough for the artifact manifests, configs and the
//! experiment result files. Numbers are f64 (the artifact files only
//! carry integers well inside the 2^53 exact range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, v: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
        self
    }

    pub fn push(&mut self, v: Json) -> &mut Json {
        if let Json::Arr(a) = self {
            a.push(v);
        }
        self
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u".to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => esc(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

/// Tiny builder macro for result files.
#[macro_export]
macro_rules! jobj {
    ($($k:expr => $v:expr),* $(,)?) => {{
        #[allow(unused_mut)]
        let mut o = $crate::util::json::Json::obj();
        $( o.set($k, $crate::util::json::Json::from($v)); )*
        o
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": "x\"y", "c": true, "d": null, "e": {"f": 0}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\"y"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str(), Some("Ab"));
    }

    #[test]
    fn integers_print_exact() {
        assert_eq!(Json::Num(1024.0).to_string(), "1024");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn builder_macro() {
        let o = jobj! {"x" => 1usize, "y" => "s"};
        assert_eq!(o.get("x").unwrap().as_usize(), Some(1));
    }
}
