//! Property-testing substrate (no proptest in the vendor set): a small
//! seeded case-runner. Each property runs N random cases; on failure it
//! reports the seed so the case replays deterministically.

use super::rng::Rng;

/// Run `cases` random trials of `prop`. `prop` gets a seeded [`Rng`]
/// and returns `Err(msg)` on violation.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xF1u64 << 32 | case as u64;
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// assertion helpers returning Result for use inside properties
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "assertion failed: {} == {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("adds", 32, |rng| {
            let a = rng.gen_range(100);
            let b = rng.gen_range(100);
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 8, |rng| {
            let x = rng.gen_range(10);
            prop_assert!(x < 5, "x was {x}");
            Ok(())
        });
    }
}
