//! Serving metrics: latency histograms, throughput counters, Omega_MSR
//! accounting per task category.

use std::collections::HashMap;
use std::time::Duration;

/// Simple fixed-bucket latency histogram with exact percentile support
/// (stores all samples; serving runs here are small enough).
#[derive(Debug, Default, Clone)]
pub struct LatencyHistogram {
    samples_us: Vec<u64>,
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn record_us(&mut self, us: u64) {
        self.record_value(us);
    }

    /// Record a raw sample — the histogram is unit-agnostic; e.g.
    /// `stream_tokens` stores per-session token counts, not latencies.
    pub fn record_value(&mut self, v: u64) {
        self.samples_us.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn p50_us(&self) -> u64 {
        self.percentile_us(50.0)
    }

    pub fn p95_us(&self) -> u64 {
        self.percentile_us(95.0)
    }

    pub fn p99_us(&self) -> u64 {
        self.percentile_us(99.0)
    }
}

/// Aggregated serving metrics, exported by the coordinator.
#[derive(Debug, Default, Clone)]
pub struct ServingMetrics {
    pub prefill: LatencyHistogram,
    pub decode: LatencyHistogram,
    pub ttft: LatencyHistogram,
    pub e2e: LatencyHistogram,
    pub router_overhead: LatencyHistogram,
    pub requests_completed: u64,
    pub requests_rejected: u64,
    /// Sessions cancelled mid-flight (explicit cancel, cancel-on-drop,
    /// or a wire `cancel` frame) — their engine slots were reclaimed.
    pub requests_cancelled: u64,
    /// Sessions evicted between decode steps because their deadline
    /// elapsed.
    pub requests_expired: u64,
    /// Sessions that ended in a typed failure terminal: a per-request
    /// engine error mid-flight, or engine death
    /// (`RequestError::EngineFailed`) — admission failures count as
    /// `requests_rejected` instead.
    pub requests_failed: u64,
    /// Successful engine restarts by the supervision path (DESIGN.md
    /// §12) — each one is a whole engine lifetime lost to a panic or
    /// stall and recovered.
    pub engine_restarts: u64,
    /// Engine rounds that exceeded `engine_round_timeout_ms` and were
    /// classified as stalled by the round watchdog.
    pub watchdog_trips: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    /// Tokens streamed per retired session (completed, cancelled or
    /// expired) — the wire-level work distribution, including partial
    /// streams shed by cancellation.
    pub stream_tokens: LatencyHistogram,
    /// Requests per batched decode round (value histogram, not µs) —
    /// how full the one-round-per-token batches actually run.
    pub decode_batch_size: LatencyHistogram,
    /// Batched decode rounds executed (each is exactly one engine
    /// round-trip in the scheduler loop).
    pub decode_rounds: u64,
    /// Sum over decode rounds and layers of the FA-group sizes — with
    /// `sa_group_slots`, the per-mode occupancy of the contiguous
    /// (layer, mode) kernel groups, i.e. the FA/SA mix of live traffic.
    pub fa_group_slots: u64,
    /// Same for the SA (sparse-ring) groups.
    pub sa_group_slots: u64,
    /// Prefill chunk calls executed (DESIGN.md §10) — a monolithic
    /// prefill counts as one chunk, so chunks per completed request
    /// shows how finely long prompts are being interleaved.
    pub prefill_chunks: u64,
    /// Cumulative time decode rounds spent waiting on prefill chunk
    /// work between rounds — the interference the chunked scheduler
    /// bounds at `prefill_chunk_budget` chunks per round.
    pub decode_stall_us: u64,
    /// KV-cache bytes physically copied while staging decode arguments
    /// (absolute engine totals; ~0 on the zero-copy fast path)
    pub kv_bytes_moved: u64,
    /// KV-cache bytes staged as borrowed views — the copies the
    /// zero-copy interchange avoided
    pub kv_bytes_borrowed: u64,
    /// Requests rejected `Overloaded` at admission because their worst
    /// case could never fit the token/page budgets (also counted in
    /// `requests_rejected`).
    pub requests_overloaded: u64,
    /// KV pool occupancy gauges (DESIGN.md §11), snapshotted from the
    /// latest decode-round reply: pages currently allocated / still
    /// free in the shared FA+SA page pool.
    pub pages_allocated: u64,
    pub pages_free: u64,
    /// High-water mark of `pages_allocated` over the engine's lifetime.
    pub pages_peak: u64,
    /// Prefill admissions that matched a cached prefix in the radix
    /// prefix cache (DESIGN.md §13) and skipped prefill for the shared
    /// run; only counted while the cache is enabled.
    pub prefix_hits: u64,
    /// Prefill admissions that ran cold with the prefix cache enabled.
    pub prefix_misses: u64,
    /// Total prompt tokens whose KV was reused from the prefix cache
    /// instead of being recomputed.
    pub prefix_tokens_reused: u64,
    /// Cumulative prefix-cache nodes evicted under index-capacity or
    /// pool pressure (engine-absolute, snapshotted from decode rounds).
    pub prefix_evictions: u64,
    /// Pool pages currently retained by the prefix-cache index — pages
    /// `drained()` would otherwise report as leaked (gauge).
    pub prefix_retained_pages: u64,
    /// Requests preempted under KV-pool pressure (DESIGN.md §15): their
    /// pages were reclaimed and they parked for a transparent resume.
    /// Counts preemption EVENTS — one request preempted twice counts 2.
    pub preemptions: u64,
    /// Parked victims successfully resumed (route-pinned replay +
    /// teacher-forced catch-up completed, stream continuing).
    pub resumes: u64,
    /// Pool pages reclaimed by preemptions (the supply side of
    /// optimistic admission's graceful degradation).
    pub preempted_pages_freed: u64,
    /// Requests that exceeded `max_preemptions` and failed typed
    /// retryable `preemption_exhausted` (also in `requests_failed`).
    pub preemption_exhausted: u64,
    /// Park → catch-up-complete latency per successful resume — the
    /// stall a preempted stream's client actually observed.
    pub resume_latency: LatencyHistogram,
    /// Per-replica dispatch and supervision counters (DESIGN.md §14),
    /// indexed by replica id; grown on first touch so a single-replica
    /// coordinator pays nothing. Empty means "never dispatched".
    pub replicas: Vec<ReplicaMetrics>,
    /// Dispatches routed by session affinity to the replica owning the
    /// warm prefix-cache pages (instead of the least-loaded pick).
    pub dispatch_affinity_hits: u64,
    /// Queued-but-undispatched requests transparently re-dispatched
    /// from a dead or draining replica to a healthy one.
    pub dispatch_failovers: u64,
    /// Admissions rejected `Overloaded { detail: "queue_watermark" }`
    /// because every serving replica's queue was above its high
    /// watermark (also counted in `requests_overloaded`).
    pub watermark_rejections: u64,
    /// Omega_MSR sum + count per policy label
    omsr: HashMap<String, (f64, u64)>,
}

/// One replica's dispatch/supervision counters (DESIGN.md §14).
#[derive(Debug, Default, Clone)]
pub struct ReplicaMetrics {
    /// Requests dispatched to this replica's admission queue.
    pub dispatched: u64,
    /// Engine respawns on this replica (also summed into the global
    /// `engine_restarts`).
    pub restarts: u64,
    /// Permanent failures: the replica exhausted its restart budget
    /// and left the serving set.
    pub deaths: u64,
    /// Completed `drain_replica` rolling-restart cycles.
    pub drains: u64,
    /// Gauge: committed tokens (`prompt + max_new` of dispatched,
    /// not-yet-retired work) as of the latest dispatch decision.
    pub committed_tokens: u64,
    /// Gauge: admission-queue depth as of the latest dispatch decision.
    pub queue_depth: u64,
}

impl ServingMetrics {
    /// Fold one decode-round reply's engine-absolute KV-transfer totals
    /// into the gauges. The engine reports CUMULATIVE counters, so the
    /// published totals must be monotonic non-decreasing across rounds —
    /// `max` pins that semantic even if a reply arrives stale or a
    /// restarted engine briefly reports from zero (plain assignment was
    /// last-writer-wins and silently under-reported in those cases).
    pub fn note_kv_transfer_totals(&mut self, moved: u64, borrowed: u64) {
        self.kv_bytes_moved = self.kv_bytes_moved.max(moved);
        self.kv_bytes_borrowed = self.kv_bytes_borrowed.max(borrowed);
    }

    /// Fold one decode-round reply's pool gauges: occupancy snapshots
    /// overwrite (they are point-in-time), the peak only ratchets up.
    pub fn note_pool_pages(&mut self, allocated: u64, free: u64, peak: u64) {
        self.pages_allocated = allocated;
        self.pages_free = free;
        self.pages_peak = self.pages_peak.max(peak);
    }

    /// Per-replica counters for replica `i`, growing the vector on
    /// first touch (replica ids are dense, assigned at startup).
    pub fn replica_mut(&mut self, i: usize) -> &mut ReplicaMetrics {
        if self.replicas.len() <= i {
            self.replicas.resize_with(i + 1, ReplicaMetrics::default);
        }
        &mut self.replicas[i]
    }

    pub fn record_omsr(&mut self, label: &str, omsr: f64) {
        let e = self.omsr.entry(label.to_string()).or_insert((0.0, 0));
        e.0 += omsr;
        e.1 += 1;
    }

    pub fn mean_omsr(&self, label: &str) -> Option<f64> {
        self.omsr.get(label).map(|(s, n)| s / *n as f64)
    }

    pub fn decode_throughput_tok_s(&self) -> f64 {
        let total_us: u64 = self.decode.samples_us.iter().sum();
        if total_us == 0 {
            return 0.0;
        }
        self.decode.count() as f64 / (total_us as f64 / 1e6)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} rejected={} cancelled={} expired={} failed={} tokens={} \
             stream_p50={}tok ttft_p50={:.1}ms ttft_p95={:.1}ms \
             decode_p50={:.2}ms decode_tput={:.1}tok/s rounds={} batch_p50={}req \
             prefill_chunks={} decode_stall={:.1}ms \
             fa_slots={} sa_slots={} kv_moved={}B kv_borrowed={}B \
             pages={}/{} pages_peak={} overloaded={} restarts={} watchdog_trips={} \
             prefix_hits={} prefix_misses={} prefix_reused={}tok \
             prefix_evictions={} prefix_retained={}pages \
             preemptions={} resumes={} preempted_pages_freed={} \
             preemption_exhausted={} resume_p50={:.1}ms resume_p95={:.1}ms",
            self.requests_completed,
            self.requests_rejected,
            self.requests_cancelled,
            self.requests_expired,
            self.requests_failed,
            self.tokens_generated,
            self.stream_tokens.p50_us(),
            self.ttft.p50_us() as f64 / 1e3,
            self.ttft.p95_us() as f64 / 1e3,
            self.decode.p50_us() as f64 / 1e3,
            self.decode_throughput_tok_s(),
            self.decode_rounds,
            self.decode_batch_size.p50_us(),
            self.prefill_chunks,
            self.decode_stall_us as f64 / 1e3,
            self.fa_group_slots,
            self.sa_group_slots,
            self.kv_bytes_moved,
            self.kv_bytes_borrowed,
            self.pages_allocated,
            self.pages_allocated + self.pages_free,
            self.pages_peak,
            self.requests_overloaded,
            self.engine_restarts,
            self.watchdog_trips,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_tokens_reused,
            self.prefix_evictions,
            self.prefix_retained_pages,
            self.preemptions,
            self.resumes,
            self.preempted_pages_freed,
            self.preemption_exhausted,
            self.resume_latency.p50_us() as f64 / 1e3,
            self.resume_latency.p95_us() as f64 / 1e3,
        );
        // the replica-set section only appears once dispatch has run
        // (single-replica coordinators still emit it, with one entry)
        if !self.replicas.is_empty() {
            let dispatched: Vec<String> =
                self.replicas.iter().map(|r| r.dispatched.to_string()).collect();
            let committed: Vec<String> =
                self.replicas.iter().map(|r| r.committed_tokens.to_string()).collect();
            s.push_str(&format!(
                " replicas={} dispatched=[{}] committed=[{}]tok affinity_hits={} \
                 failovers={} watermark_rejections={} replica_deaths={} replica_drains={}",
                self.replicas.len(),
                dispatched.join(","),
                committed.join(","),
                self.dispatch_affinity_hits,
                self.dispatch_failovers,
                self.watermark_rejections,
                self.replicas.iter().map(|r| r.deaths).sum::<u64>(),
                self.replicas.iter().map(|r| r.drains).sum::<u64>(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_order_free() {
        let mut h = LatencyHistogram::default();
        for v in [50u64, 10, 30, 20, 40] {
            h.record_us(v);
        }
        assert_eq!(h.p50_us(), 30);
        assert_eq!(h.percentile_us(0.0), 10);
        assert_eq!(h.percentile_us(100.0), 50);
        assert!((h.mean_us() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.p99_us(), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn summary_reports_lifecycle_counters() {
        let mut m = ServingMetrics::default();
        m.requests_completed = 3;
        m.requests_cancelled = 2;
        m.requests_expired = 1;
        m.stream_tokens.record_value(5);
        m.stream_tokens.record_value(7);
        let s = m.summary();
        assert!(s.contains("cancelled=2"), "{s}");
        assert!(s.contains("expired=1"), "{s}");
        assert!(s.contains("stream_p50="), "{s}");
    }

    #[test]
    fn summary_reports_batched_decode_occupancy() {
        let mut m = ServingMetrics::default();
        m.decode_rounds = 5;
        m.decode_batch_size.record_value(4);
        m.decode_batch_size.record_value(2);
        m.fa_group_slots = 12;
        m.sa_group_slots = 8;
        let s = m.summary();
        assert!(s.contains("rounds=5"), "{s}");
        assert!(s.contains("batch_p50="), "{s}");
        assert!(s.contains("fa_slots=12"), "{s}");
        assert!(s.contains("sa_slots=8"), "{s}");
    }

    #[test]
    fn summary_reports_chunked_prefill_and_stall() {
        let mut m = ServingMetrics::default();
        m.prefill_chunks = 9;
        m.decode_stall_us = 2500;
        m.ttft.record_us(1000);
        m.ttft.record_us(3000);
        let s = m.summary();
        assert!(s.contains("prefill_chunks=9"), "{s}");
        assert!(s.contains("decode_stall=2.5ms"), "{s}");
        // TTFT is a histogram: both percentiles come from samples
        assert_eq!(m.ttft.count(), 2);
        assert!(s.contains("ttft_p95="), "{s}");
    }

    #[test]
    fn kv_transfer_totals_are_monotonic_non_decreasing() {
        let mut m = ServingMetrics::default();
        m.note_kv_transfer_totals(100, 2000);
        assert_eq!((m.kv_bytes_moved, m.kv_bytes_borrowed), (100, 2000));
        m.note_kv_transfer_totals(250, 4000);
        assert_eq!((m.kv_bytes_moved, m.kv_bytes_borrowed), (250, 4000));
        // a stale or reset reply must never drag the published totals
        // backwards (the old plain assignment did exactly that)
        m.note_kv_transfer_totals(0, 0);
        assert_eq!((m.kv_bytes_moved, m.kv_bytes_borrowed), (250, 4000));
        m.note_kv_transfer_totals(300, 3999);
        assert_eq!((m.kv_bytes_moved, m.kv_bytes_borrowed), (300, 4000));
    }

    #[test]
    fn pool_gauges_snapshot_and_peak_ratchets() {
        let mut m = ServingMetrics::default();
        m.note_pool_pages(10, 90, 10);
        m.note_pool_pages(4, 96, 12);
        // occupancy is a snapshot; the peak only ratchets up
        assert_eq!((m.pages_allocated, m.pages_free, m.pages_peak), (4, 96, 12));
        m.note_pool_pages(6, 94, 11);
        assert_eq!(m.pages_peak, 12);
        let s = m.summary();
        assert!(s.contains("pages=6/100"), "{s}");
        assert!(s.contains("pages_peak=12"), "{s}");
        m.requests_overloaded = 3;
        assert!(m.summary().contains("overloaded=3"), "{}", m.summary());
    }

    /// Failure-domain counters (DESIGN.md §12) surface in the summary
    /// line so an operator sees restarts and watchdog trips at a glance.
    #[test]
    fn summary_reports_failure_domain_counters() {
        let mut m = ServingMetrics::default();
        let s = m.summary();
        assert!(s.contains("restarts=0"), "{s}");
        assert!(s.contains("watchdog_trips=0"), "{s}");
        m.engine_restarts = 2;
        m.watchdog_trips = 1;
        m.requests_failed = 4;
        let s = m.summary();
        assert!(s.contains("restarts=2"), "{s}");
        assert!(s.contains("watchdog_trips=1"), "{s}");
        assert!(s.contains("failed=4"), "{s}");
    }

    /// Prefix-cache counters (DESIGN.md §13) surface in the summary
    /// line: hit/miss split, tokens reused, evictions, retained pages.
    #[test]
    fn summary_reports_prefix_cache_counters() {
        let mut m = ServingMetrics::default();
        let s = m.summary();
        assert!(s.contains("prefix_hits=0"), "{s}");
        assert!(s.contains("prefix_retained=0pages"), "{s}");
        m.prefix_hits = 3;
        m.prefix_misses = 1;
        m.prefix_tokens_reused = 96;
        m.prefix_evictions = 2;
        m.prefix_retained_pages = 12;
        let s = m.summary();
        assert!(s.contains("prefix_hits=3"), "{s}");
        assert!(s.contains("prefix_misses=1"), "{s}");
        assert!(s.contains("prefix_reused=96tok"), "{s}");
        assert!(s.contains("prefix_evictions=2"), "{s}");
        assert!(s.contains("prefix_retained=12pages"), "{s}");
    }

    /// Replica-set counters (DESIGN.md §14): per-replica dispatch and
    /// gauges appear in the summary once any replica is touched, and
    /// the section is absent before dispatch ever runs.
    #[test]
    fn summary_reports_replica_dispatch_counters() {
        let mut m = ServingMetrics::default();
        assert!(!m.summary().contains("replicas="), "{}", m.summary());
        m.replica_mut(1).dispatched = 4;
        m.replica_mut(0).dispatched = 7;
        m.replica_mut(0).committed_tokens = 320;
        m.dispatch_affinity_hits = 2;
        m.dispatch_failovers = 1;
        m.watermark_rejections = 5;
        m.replica_mut(1).deaths = 1;
        let s = m.summary();
        assert!(s.contains("replicas=2"), "{s}");
        assert!(s.contains("dispatched=[7,4]"), "{s}");
        assert!(s.contains("committed=[320,0]tok"), "{s}");
        assert!(s.contains("affinity_hits=2"), "{s}");
        assert!(s.contains("failovers=1"), "{s}");
        assert!(s.contains("watermark_rejections=5"), "{s}");
        assert!(s.contains("replica_deaths=1"), "{s}");
    }

    /// Preemption counters (DESIGN.md §15) surface in the summary line:
    /// preempt/resume event counts, pages reclaimed, starvation-cap
    /// failures, and resume-latency percentiles.
    #[test]
    fn summary_reports_preemption_counters() {
        let mut m = ServingMetrics::default();
        let s = m.summary();
        assert!(s.contains("preemptions=0"), "{s}");
        assert!(s.contains("resumes=0"), "{s}");
        assert!(s.contains("preempted_pages_freed=0"), "{s}");
        m.preemptions = 3;
        m.resumes = 2;
        m.preempted_pages_freed = 48;
        m.preemption_exhausted = 1;
        m.resume_latency.record_us(1500);
        m.resume_latency.record_us(2000);
        m.resume_latency.record_us(2500);
        let s = m.summary();
        assert!(s.contains("preemptions=3"), "{s}");
        assert!(s.contains("resumes=2"), "{s}");
        assert!(s.contains("preempted_pages_freed=48"), "{s}");
        assert!(s.contains("preemption_exhausted=1"), "{s}");
        assert!(s.contains("resume_p50=2.0ms"), "{s}");
        assert!(s.contains("resume_p95=2.5ms"), "{s}");
    }

    #[test]
    fn omsr_accounting() {
        let mut m = ServingMetrics::default();
        m.record_omsr("flux", 0.5);
        m.record_omsr("flux", 0.3);
        assert!((m.mean_omsr("flux").unwrap() - 0.4).abs() < 1e-9);
        assert!(m.mean_omsr("other").is_none());
    }
}
