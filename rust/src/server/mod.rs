//! JSON-lines TCP serving front end + client (std::net, thread-per-
//! connection; no async runtime in the offline vendor set).
//!
//! Protocol: one JSON object per line.
//!   request:  {"prompt": [u32...], "max_new": 8, "policy": "flux-ssa",
//!              "router": "balanced", "sparse_decode": false}
//!   response: {"tokens": [...], "text": "...", "omsr": 0.5,
//!              "modes": ["fa", ...], "ttft_ms": 1.2, "e2e_ms": 3.4}
//!
//! policy strings: "backbone" | "flux-ssa" | "flux-xa" | "flux-ta"
//!                 | "static:<mode-csv>" (e.g. "static:fa,fa,ssa,...")

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, Request};
use crate::router::{AttnMode, DecodeMode, Policy};
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct WireRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub policy: String,
    pub router: String,
    pub sparse_decode: bool,
}

impl Default for WireRequest {
    fn default() -> Self {
        Self {
            prompt: vec![],
            max_new: 8,
            policy: "flux-ssa".into(),
            router: "balanced".into(),
            sparse_decode: false,
        }
    }
}

impl WireRequest {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut w = WireRequest {
            prompt: j
                .get("prompt")
                .and_then(Json::as_arr)
                .context("missing 'prompt'")?
                .iter()
                .filter_map(|v| v.as_usize().map(|x| x as u32))
                .collect(),
            ..Default::default()
        };
        if let Some(m) = j.get("max_new").and_then(Json::as_usize) {
            w.max_new = m;
        }
        if let Some(p) = j.get("policy").and_then(Json::as_str) {
            w.policy = p.to_string();
        }
        if let Some(r) = j.get("router").and_then(Json::as_str) {
            w.router = r.to_string();
        }
        if let Some(s) = j.get("sparse_decode").and_then(Json::as_bool) {
            w.sparse_decode = s;
        }
        Ok(w)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("prompt", Json::from(self.prompt.iter().map(|&t| t as usize).collect::<Vec<_>>()));
        o.set("max_new", Json::from(self.max_new));
        o.set("policy", Json::from(self.policy.as_str()));
        o.set("router", Json::from(self.router.as_str()));
        o.set("sparse_decode", Json::from(self.sparse_decode));
        o
    }
}

#[derive(Debug, Clone, Default)]
pub struct WireResponse {
    pub tokens: Vec<u32>,
    pub text: String,
    pub omsr: f64,
    pub modes: Vec<String>,
    pub ttft_ms: f64,
    pub e2e_ms: f64,
    pub decode_ms_per_token: f64,
    pub error: Option<String>,
}

impl WireResponse {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("tokens", Json::from(self.tokens.iter().map(|&t| t as usize).collect::<Vec<_>>()));
        o.set("text", Json::from(self.text.as_str()));
        o.set("omsr", Json::from(self.omsr));
        o.set("modes", Json::from(self.modes.clone()));
        o.set("ttft_ms", Json::from(self.ttft_ms));
        o.set("e2e_ms", Json::from(self.e2e_ms));
        o.set("decode_ms_per_token", Json::from(self.decode_ms_per_token));
        match &self.error {
            Some(e) => o.set("error", Json::from(e.as_str())),
            None => o.set("error", Json::Null),
        };
        o
    }

    pub fn from_json(j: &Json) -> Self {
        WireResponse {
            tokens: j
                .get("tokens")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_usize().map(|x| x as u32)).collect())
                .unwrap_or_default(),
            text: j.get("text").and_then(Json::as_str).unwrap_or("").to_string(),
            omsr: j.get("omsr").and_then(Json::as_f64).unwrap_or(0.0),
            modes: j
                .get("modes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default(),
            ttft_ms: j.get("ttft_ms").and_then(Json::as_f64).unwrap_or(0.0),
            e2e_ms: j.get("e2e_ms").and_then(Json::as_f64).unwrap_or(0.0),
            decode_ms_per_token: j.get("decode_ms_per_token").and_then(Json::as_f64).unwrap_or(0.0),
            error: j.get("error").and_then(Json::as_str).map(String::from),
        }
    }
}

/// Parse a wire policy string into a [`Policy`].
pub fn parse_policy(s: &str, sparse_decode: bool, n_layers: usize) -> Result<Policy> {
    let decode = if sparse_decode { DecodeMode::Sparse } else { DecodeMode::Dense };
    match s {
        "backbone" => Ok(Policy::Backbone),
        "flux-ssa" => Ok(Policy::Flux { sa_mode: AttnMode::Ssa, decode }),
        "flux-xa" => Ok(Policy::Flux { sa_mode: AttnMode::Xa, decode }),
        "flux-ta" => Ok(Policy::Flux { sa_mode: AttnMode::Ta, decode }),
        other => {
            if let Some(csv) = other.strip_prefix("static:") {
                let modes: Result<Vec<AttnMode>> = csv.split(',').map(AttnMode::parse).collect();
                let modes = modes?;
                anyhow::ensure!(
                    modes.len() == n_layers,
                    "static policy needs {n_layers} modes, got {}",
                    modes.len()
                );
                Ok(Policy::Static { modes, decode })
            } else {
                anyhow::bail!("unknown policy '{other}'")
            }
        }
    }
}

/// Serve forever on `addr` (thread per connection).
pub fn serve(coord: Arc<Coordinator>, addr: &str, n_layers: usize) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("flux server listening on {addr}");
    for sock in listener.incoming() {
        let sock = sock?;
        let coord = coord.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(coord, sock, n_layers) {
                eprintln!("connection error: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(coord: Arc<Coordinator>, sock: TcpStream, n_layers: usize) -> Result<()> {
    let mut wr = sock.try_clone()?;
    let rd = BufReader::new(sock);
    let tok = Tokenizer::new();
    for line in rd.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = process_line(&coord, &tok, &line, n_layers);
        wr.write_all(format!("{}\n", resp.to_json()).as_bytes())?;
        wr.flush()?;
    }
    Ok(())
}

fn process_line(coord: &Coordinator, tok: &Tokenizer, line: &str, n_layers: usize) -> WireResponse {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return error_response(&format!("bad json: {e}")),
    };
    let wire = match WireRequest::from_json(&parsed) {
        Ok(w) => w,
        Err(e) => return error_response(&format!("bad request: {e}")),
    };
    let policy = match parse_policy(&wire.policy, wire.sparse_decode, n_layers) {
        Ok(p) => p,
        Err(e) => return error_response(&e.to_string()),
    };
    match coord.submit(Request {
        prompt: wire.prompt,
        max_new: wire.max_new,
        policy,
        router: wire.router,
    }) {
        Ok(r) => WireResponse {
            text: tok.decode(&r.tokens),
            tokens: r.tokens,
            omsr: r.omsr,
            modes: r.modes,
            ttft_ms: r.ttft_us as f64 / 1e3,
            e2e_ms: r.e2e_us as f64 / 1e3,
            decode_ms_per_token: r.decode_us_per_token / 1e3,
            error: None,
        },
        Err(e) => error_response(&e.to_string()),
    }
}

fn error_response(msg: &str) -> WireResponse {
    WireResponse { error: Some(msg.to_string()), ..Default::default() }
}

/// Minimal blocking client for examples and tests.
pub fn client_request(addr: &str, req: &WireRequest) -> Result<WireResponse> {
    let sock = TcpStream::connect(addr)?;
    let mut wr = sock.try_clone()?;
    wr.write_all(format!("{}\n", req.to_json()).as_bytes())?;
    wr.flush()?;
    let mut rd = BufReader::new(sock);
    let mut line = String::new();
    rd.read_line(&mut line)?;
    anyhow::ensure!(!line.is_empty(), "server closed connection");
    let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    Ok(WireResponse::from_json(&j))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing() {
        assert!(matches!(parse_policy("backbone", false, 8).unwrap(), Policy::Backbone));
        let p = parse_policy("flux-ta", true, 8).unwrap();
        assert_eq!(p.label(), "flux-fa-ta-sd");
        let s = parse_policy("static:fa,fa,ssa,ssa,fa,fa,ssa,ssa", false, 8).unwrap();
        assert_eq!(s.label(), "static-4of8");
        assert!(parse_policy("static:fa,fa", false, 8).is_err());
        assert!(parse_policy("nope", false, 8).is_err());
    }

    #[test]
    fn wire_request_roundtrip() {
        let j = Json::parse(r#"{"prompt":[1,2]}"#).unwrap();
        let w = WireRequest::from_json(&j).unwrap();
        assert_eq!(w.max_new, 8);
        assert_eq!(w.policy, "flux-ssa");
        assert!(!w.sparse_decode);
        let j2 = Json::parse(&w.to_json().to_string()).unwrap();
        let w2 = WireRequest::from_json(&j2).unwrap();
        assert_eq!(w2.prompt, vec![1, 2]);
    }

    #[test]
    fn wire_response_roundtrip() {
        let r = WireResponse {
            tokens: vec![5, 2],
            text: "w0 <eos>".into(),
            omsr: 0.5,
            modes: vec!["fa".into(), "ssa".into()],
            ttft_ms: 1.5,
            e2e_ms: 3.0,
            decode_ms_per_token: 0.7,
            error: None,
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = WireResponse::from_json(&j);
        assert_eq!(r2.tokens, r.tokens);
        assert_eq!(r2.modes, r.modes);
        assert!(r2.error.is_none());
    }
}
