//! NDJSON TCP serving front end + clients (std::net, thread-per-
//! connection; no async runtime in the offline vendor set).
//!
//! ## Wire protocol v2 (multiplexed streaming)
//!
//! One connection carries many in-flight requests. Every frame is one
//! JSON object per line; a frame with a client-assigned `id` belongs to
//! that stream. Ids are non-negative integers below 2^53 (JSON number
//! precision — larger or negative ids are mangled by any f64-based
//! JSON layer, including this one):
//!
//! ```text
//! open:    {"id": 7, "prompt": [u32...], "max_new": 8,
//!           "policy": "flux-ssa", "router": "balanced",
//!           "sparse_decode": false, "deadline_ms": 500,
//!           "stop_tokens": [3], "ignore_eos": false}
//! cancel:  {"id": 7, "cancel": true}
//!
//! events (server -> client, interleaved across streams):
//!   {"id":7,"event":"queued"}
//!   {"id":7,"event":"prefilled","token":t,"omsr":0.5,"modes":[..],
//!    "ttft_ms":1.2,"queue_ms":0.1,"cached_prefix_tokens":0}
//!   {"id":7,"event":"token","token":t,"step_ms":0.8}
//!   {"id":7,"event":"preempted","streamed":3,"preemptions":1}
//!   {"id":7,"event":"resumed","resume_ms":4.2,"preemptions":1}
//!   {"id":7,"event":"done","tokens":[..],"text":"...","omsr":0.5,
//!    "modes":[..],"ttft_ms":1.2,"e2e_ms":3.4,
//!    "decode_ms_per_token":0.8,"queue_ms":0.1}
//!   {"id":7,"event":"error","kind":"cancelled|deadline_exceeded|...",
//!    "code":"cancelled|...","retryable":false,"error":"..."}
//! ```
//!
//! `preempted`/`resumed` (DESIGN.md §15) are informational: the stream's
//! KV pages were reclaimed under pool pressure and later rebuilt; no
//! tokens are lost or repeated, the stream just pauses.
//!
//! `code` duplicates `kind` (stable machine-readable error class) and
//! `retryable` tells clients whether resubmitting the identical request
//! may succeed (true for transient admission/supervision failures:
//! queue_full, overloaded, draining, engine_failed,
//! preemption_exhausted). Retryable error frames also carry
//! `retry_after_ms`, a server-suggested floor for the client's retry
//! backoff ([`RetryPolicy`] honors it). A stream whose event channel
//! closes without a terminal event (scheduler wound down) is answered
//! with `kind:"shutdown"`, `retryable:false`.
//!
//! ## Slow-client backpressure
//!
//! Every connection's outbound frames flow through one bounded queue
//! drained by a dedicated writer thread under a write deadline. A
//! client that stops reading (full socket buffer past the deadline)
//! gets its connection closed and ONLY its own sessions cancelled —
//! sibling connections on the same server never stall behind it.
//!
//! `done` and `error` are terminal; the id may be reused afterwards.
//! A `cancel` frame (or dropping the connection) aborts the stream:
//! the scheduler releases the engine slot and KV cache between decode
//! steps and answers with `{"event":"error","kind":"cancelled"}`.
//!
//! ## v1 compatibility shim
//!
//! A request frame *without* an `id` is answered, when it completes,
//! with the original single aggregate response
//! `{"tokens":[..],"text":"...","omsr":..,"modes":[..],
//! "ttft_ms":..,"e2e_ms":..,"decode_ms_per_token":..,"queue_ms":..,
//! "error":null}`. v1 requests are served in order on a dedicated
//! per-connection worker thread — pipelined v1 responses keep their
//! request order (as in v1), and v2 frames (including cancels) are
//! never stalled behind a blocking v1 request.
//!
//! policy strings: "backbone" | "flux-ssa" | "flux-xa" | "flux-ta"
//!                 | "static:<mode-csv>" (e.g. "static:fa,fa,ssa,...")

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{CancelToken, Coordinator, Request, SessionEvent, SessionHandle};
use crate::router::{AttnMode, DecodeMode, Policy};
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct WireRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub policy: String,
    pub router: String,
    pub sparse_decode: bool,
    /// v2: client-assigned stream id; `None` selects the v1 single-shot
    /// path.
    pub id: Option<u64>,
    /// v2: wall-clock deadline from admission (ms).
    pub deadline_ms: Option<u64>,
    /// v2: stop tokens beyond EOS.
    pub stop_tokens: Vec<u32>,
    /// v2: decode through EOS (load generation / benchmarks).
    pub ignore_eos: bool,
}

impl Default for WireRequest {
    fn default() -> Self {
        Self {
            prompt: vec![],
            max_new: 8,
            policy: "flux-ssa".into(),
            router: "balanced".into(),
            sparse_decode: false,
            id: None,
            deadline_ms: None,
            stop_tokens: vec![],
            ignore_eos: false,
        }
    }
}

impl WireRequest {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut w = WireRequest {
            prompt: j
                .get("prompt")
                .and_then(Json::as_arr)
                .context("missing 'prompt'")?
                .iter()
                .filter_map(|v| v.as_usize().map(|x| x as u32))
                .collect(),
            ..Default::default()
        };
        if let Some(m) = j.get("max_new").and_then(Json::as_usize) {
            w.max_new = m;
        }
        if let Some(p) = j.get("policy").and_then(Json::as_str) {
            w.policy = p.to_string();
        }
        if let Some(r) = j.get("router").and_then(Json::as_str) {
            w.router = r.to_string();
        }
        if let Some(s) = j.get("sparse_decode").and_then(Json::as_bool) {
            w.sparse_decode = s;
        }
        w.id = j.get("id").and_then(Json::as_usize).map(|v| v as u64);
        w.deadline_ms = j.get("deadline_ms").and_then(Json::as_usize).map(|v| v as u64);
        if let Some(st) = j.get("stop_tokens").and_then(Json::as_arr) {
            w.stop_tokens = st.iter().filter_map(|v| v.as_usize().map(|x| x as u32)).collect();
        }
        if let Some(ie) = j.get("ignore_eos").and_then(Json::as_bool) {
            w.ignore_eos = ie;
        }
        Ok(w)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("prompt", Json::from(self.prompt.iter().map(|&t| t as usize).collect::<Vec<_>>()));
        o.set("max_new", Json::from(self.max_new));
        o.set("policy", Json::from(self.policy.as_str()));
        o.set("router", Json::from(self.router.as_str()));
        o.set("sparse_decode", Json::from(self.sparse_decode));
        if let Some(id) = self.id {
            o.set("id", Json::from(id as usize));
        }
        if let Some(d) = self.deadline_ms {
            o.set("deadline_ms", Json::from(d as usize));
        }
        if !self.stop_tokens.is_empty() {
            o.set(
                "stop_tokens",
                Json::from(self.stop_tokens.iter().map(|&t| t as usize).collect::<Vec<_>>()),
            );
        }
        if self.ignore_eos {
            o.set("ignore_eos", Json::from(true));
        }
        o
    }

    /// Resolve into a coordinator [`Request`] (parses the policy).
    pub fn to_request(&self, n_layers: usize) -> Result<Request> {
        let policy = parse_policy(&self.policy, self.sparse_decode, n_layers)?;
        Ok(Request {
            prompt: self.prompt.clone(),
            max_new: self.max_new,
            policy,
            router: self.router.clone(),
            deadline_ms: self.deadline_ms,
            stop_tokens: self.stop_tokens.clone(),
            ignore_eos: self.ignore_eos,
        })
    }
}

#[derive(Debug, Clone, Default)]
pub struct WireResponse {
    pub tokens: Vec<u32>,
    pub text: String,
    pub omsr: f64,
    pub modes: Vec<String>,
    pub ttft_ms: f64,
    pub e2e_ms: f64,
    pub decode_ms_per_token: f64,
    pub queue_ms: f64,
    pub error: Option<String>,
    /// Set alongside `error`: whether resubmitting the identical
    /// request may succeed (mirrors the wire frame's `retryable`).
    pub retryable: bool,
    /// Server-suggested backoff floor for retryable errors (mirrors the
    /// wire frame's `retry_after_ms`); [`RetryPolicy`] honors it as the
    /// lower bound of its decorrelated jitter.
    pub retry_after_ms: Option<u64>,
}

impl WireResponse {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("tokens", Json::from(self.tokens.iter().map(|&t| t as usize).collect::<Vec<_>>()));
        o.set("text", Json::from(self.text.as_str()));
        o.set("omsr", Json::from(self.omsr));
        o.set("modes", Json::from(self.modes.clone()));
        o.set("ttft_ms", Json::from(self.ttft_ms));
        o.set("e2e_ms", Json::from(self.e2e_ms));
        o.set("decode_ms_per_token", Json::from(self.decode_ms_per_token));
        o.set("queue_ms", Json::from(self.queue_ms));
        match &self.error {
            Some(e) => {
                o.set("error", Json::from(e.as_str()));
                o.set("retryable", Json::from(self.retryable));
                if let Some(ms) = self.retry_after_ms {
                    o.set("retry_after_ms", Json::from(ms as usize));
                }
            }
            None => o.set("error", Json::Null),
        };
        o
    }

    pub fn from_json(j: &Json) -> Self {
        WireResponse {
            tokens: j
                .get("tokens")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_usize().map(|x| x as u32)).collect())
                .unwrap_or_default(),
            text: j.get("text").and_then(Json::as_str).unwrap_or("").to_string(),
            omsr: j.get("omsr").and_then(Json::as_f64).unwrap_or(0.0),
            modes: j
                .get("modes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default(),
            ttft_ms: j.get("ttft_ms").and_then(Json::as_f64).unwrap_or(0.0),
            e2e_ms: j.get("e2e_ms").and_then(Json::as_f64).unwrap_or(0.0),
            decode_ms_per_token: j.get("decode_ms_per_token").and_then(Json::as_f64).unwrap_or(0.0),
            queue_ms: j.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0),
            error: j.get("error").and_then(Json::as_str).map(String::from),
            retryable: j.get("retryable").and_then(Json::as_bool).unwrap_or(false),
            retry_after_ms: j.get("retry_after_ms").and_then(Json::as_usize).map(|v| v as u64),
        }
    }
}

/// Parse a wire policy string into a [`Policy`].
pub fn parse_policy(s: &str, sparse_decode: bool, n_layers: usize) -> Result<Policy> {
    let decode = if sparse_decode { DecodeMode::Sparse } else { DecodeMode::Dense };
    match s {
        "backbone" => Ok(Policy::Backbone),
        "flux-ssa" => Ok(Policy::Flux { sa_mode: AttnMode::Ssa, decode }),
        "flux-xa" => Ok(Policy::Flux { sa_mode: AttnMode::Xa, decode }),
        "flux-ta" => Ok(Policy::Flux { sa_mode: AttnMode::Ta, decode }),
        other => {
            if let Some(csv) = other.strip_prefix("static:") {
                let modes: Result<Vec<AttnMode>> = csv.split(',').map(AttnMode::parse).collect();
                let modes = modes?;
                anyhow::ensure!(
                    modes.len() == n_layers,
                    "static policy needs {n_layers} modes, got {}",
                    modes.len()
                );
                Ok(Policy::Static { modes, decode })
            } else {
                anyhow::bail!("unknown policy '{other}'")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Shared write half of a connection (client side). Frames interleave
/// at line granularity.
type SharedWriter = Arc<Mutex<TcpStream>>;

/// Maximum pipelined-but-unserved v1 requests buffered per connection
/// before the reader thread blocks (bounds per-connection memory).
const V1_PIPELINE_DEPTH: usize = 64;

/// Bounded per-connection outbound frame queue: session pumps and the
/// reader thread enqueue, one writer thread drains to the socket. Full
/// queue = the client is reading slower than the server generates.
const OUTBOUND_QUEUE_DEPTH: usize = 256;

/// How long the writer thread may block on one socket write before the
/// client is declared stuck and the connection torn down.
const WRITE_DEADLINE: Duration = Duration::from_secs(5);

/// Sending half of a connection's bounded outbound queue. `send` blocks
/// while the queue is full, but never unboundedly: the writer thread's
/// write deadline guarantees it either drains the queue or declares the
/// client stuck (dropping the receiver, which errors every sender out).
/// A stuck client therefore stalls only its OWN connection's pumps, and
/// only for about one deadline.
#[derive(Clone)]
struct ConnWriter {
    tx: SyncSender<Json>,
    dead: Arc<AtomicBool>,
}

impl ConnWriter {
    /// Enqueue one frame; `Err` means the connection is gone (socket
    /// error or slow-client teardown) and the caller should wind down.
    fn send(&self, j: Json) -> Result<(), ()> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(());
        }
        self.tx.send(j).map_err(|_| ())
    }
}

/// Drain the outbound queue to the socket under [`WRITE_DEADLINE`]. On
/// any write failure — a timeout means the client stopped reading —
/// cancel only THIS connection's sessions (typed slow-client close: the
/// scheduler retires them `cancelled`, siblings on other connections
/// are untouched), shut the socket down, and exit; dropping the
/// receiver unblocks every sender with an error.
fn writer_loop(
    mut sock: TcpStream,
    rx: Receiver<Json>,
    sessions: SessionMap,
    dead: Arc<AtomicBool>,
) {
    let _ = sock.set_write_timeout(Some(WRITE_DEADLINE));
    while let Ok(j) = rx.recv() {
        if sock.write_all(format!("{j}\n").as_bytes()).and_then(|()| sock.flush()).is_err() {
            dead.store(true, Ordering::SeqCst);
            for (_, c) in sessions.lock().unwrap().drain() {
                c.cancel();
            }
            let _ = sock.shutdown(std::net::Shutdown::Both);
            return;
        }
    }
}

/// One unit of work for a connection's v1 worker thread: a request to
/// run, or a pre-formed error response (e.g. for an unparseable line)
/// that must still be answered in arrival order.
enum V1Job {
    Request(Json),
    Error(WireResponse),
}

/// Live v2 streams on one connection: wire id -> cancellation signal.
type SessionMap = Arc<Mutex<HashMap<u64, CancelToken>>>;

fn write_line(wr: &SharedWriter, j: &Json) -> std::io::Result<()> {
    let mut w = wr.lock().unwrap();
    w.write_all(format!("{j}\n").as_bytes())?;
    w.flush()
}

fn frame(id: u64, event: &str) -> Json {
    let mut o = Json::obj();
    o.set("id", Json::from(id as usize));
    o.set("event", Json::from(event));
    o
}

fn error_frame(id: u64, kind: &str, msg: &str, retryable: bool) -> Json {
    let mut o = frame(id, "error");
    o.set("kind", Json::from(kind));
    // `code` mirrors `kind`: clients written against v2.1 key on it.
    o.set("code", Json::from(kind));
    o.set("retryable", Json::from(retryable));
    o.set("error", Json::from(msg));
    o
}

/// [`error_frame`] from a typed [`RequestError`], carrying the machine-
/// readable extras: `detail` on `overloaded` frames (WHICH budget
/// tripped — `prefill_tokens` / `total_tokens` / `pages` are structural,
/// `queue_watermark` is transient backpressure) and `replica` on
/// `engine_failed` frames (which failure domain died), so clients can
/// tell structural overload from retry-after-backoff without parsing
/// the human-readable message.
fn error_frame_err(id: u64, err: &RequestError) -> Json {
    let mut o = error_frame(id, err.kind(), &err.to_string(), err.retryable());
    if let Some(detail) = err.overload_detail() {
        o.set("detail", Json::from(detail));
    }
    if let Some(replica) = err.failed_replica() {
        o.set("replica", Json::from(replica));
    }
    if let Some(ms) = retry_after_ms(err) {
        o.set("retry_after_ms", Json::from(ms as usize));
    }
    o
}

/// Server-suggested backoff floor for a retryable error (satellite of
/// DESIGN.md §15): how long resubmitting is POINTLESS, by failure
/// class. Draining dominates (the replica is finishing its in-flight
/// set); preemption exhaustion means the pool is badly oversubscribed,
/// so back off harder than a garden-variety full queue.
fn retry_after_ms(err: &RequestError) -> Option<u64> {
    if !err.retryable() {
        return None;
    }
    Some(match err.kind() {
        "draining" => 200,
        "preemption_exhausted" => 100,
        "engine_failed" => 50,
        _ => 25, // queue_full, overloaded, ...
    })
}

/// Serve forever on `addr` (thread per connection).
pub fn serve(coord: Arc<Coordinator>, addr: &str, n_layers: usize) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("flux server listening on {addr}");
    serve_listener(coord, listener, n_layers)
}

/// Accept loop over an existing listener (tests and benches bind
/// `127.0.0.1:0` first to obtain an ephemeral port).
pub fn serve_listener(coord: Arc<Coordinator>, listener: TcpListener, n_layers: usize) -> Result<()> {
    for sock in listener.incoming() {
        let sock = sock?;
        let coord = coord.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(coord, sock, n_layers) {
                eprintln!("connection error: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(coord: Arc<Coordinator>, sock: TcpStream, n_layers: usize) -> Result<()> {
    let sessions: SessionMap = Arc::new(Mutex::new(HashMap::new()));
    // all outbound frames (v2 events from the pumps, v1 responses,
    // reader-thread protocol errors) flow through one bounded queue
    // drained by a dedicated writer thread under a write deadline —
    // slow-client backpressure with per-connection blast radius
    let (out_tx, out_rx) = std::sync::mpsc::sync_channel::<Json>(OUTBOUND_QUEUE_DEPTH);
    let out = ConnWriter { tx: out_tx, dead: Arc::new(AtomicBool::new(false)) };
    {
        let wsock = sock.try_clone()?;
        let sessions = sessions.clone();
        let dead = out.dead.clone();
        std::thread::spawn(move || writer_loop(wsock, out_rx, sessions, dead));
    }
    let rd = BufReader::new(sock);
    // One worker thread serves this connection's v1 jobs in order, off
    // the reader thread: v2 frames (including cancels) are never
    // stalled behind a blocking v1 request, one connection never pins
    // more than one thread on the v1 path, and the bounded channel
    // restores the old inline loop's backpressure (a reader blocked on
    // a full queue throttles the sender through the socket buffer).
    let (v1_tx, v1_rx) = std::sync::mpsc::sync_channel::<V1Job>(V1_PIPELINE_DEPTH);
    {
        let coord = coord.clone();
        let out = out.clone();
        std::thread::spawn(move || {
            let tok = Tokenizer::new();
            for job in v1_rx {
                let resp = match job {
                    V1Job::Request(parsed) => process_request(&coord, &tok, &parsed, n_layers),
                    V1Job::Error(resp) => resp,
                };
                if out.send(resp.to_json()).is_err() {
                    return;
                }
            }
        });
    }
    let mut io_result: Result<()> = Ok(());
    for line in rd.lines() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                // abrupt disconnect (e.g. RST mid-line) still reaches
                // the drain below
                io_result = Err(e.into());
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Err(e) = handle_frame(&coord, &v1_tx, &out, &sessions, &line, n_layers) {
            io_result = Err(e);
            break;
        }
    }
    // client gone (cleanly or not): abort any streams it left running
    // so the scheduler reclaims their engine slots; dropping v1_tx and
    // out winds down the worker and (once the pumps finish) the writer
    for (_, c) in sessions.lock().unwrap().drain() {
        c.cancel();
    }
    io_result
}

/// Dispatch one inbound line. Protocol-level problems are answered on
/// the wire (the connection always survives them); only a dead outbound
/// path (socket gone or slow-client teardown) propagates.
fn handle_frame(
    coord: &Arc<Coordinator>,
    v1_tx: &SyncSender<V1Job>,
    out: &ConnWriter,
    sessions: &SessionMap,
    line: &str,
    n_layers: usize,
) -> Result<()> {
    let send = |j: Json| {
        out.send(j).map_err(|()| anyhow::anyhow!("connection writer gone (slow client?)"))
    };
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            // unparseable line: answered v1-style, through the worker,
            // so pipelined v1 responses keep arrival order
            let _ = v1_tx.send(V1Job::Error(error_response(&format!("bad json: {e}"))));
            return Ok(());
        }
    };
    let Some(id) = parsed.get("id").and_then(Json::as_usize).map(|v| v as u64) else {
        // v1 single-shot: handed to this connection's worker thread,
        // which answers in request order when each completes
        let _ = v1_tx.send(V1Job::Request(parsed));
        return Ok(());
    };

    if parsed.get("cancel").and_then(Json::as_bool).unwrap_or(false) {
        let token = sessions.lock().unwrap().get(&id).cloned();
        match token {
            Some(c) => c.cancel(), // terminal error frame comes from the pump
            None => send(error_frame(id, "unknown_id", &format!("no live stream {id}"), false))?,
        }
        return Ok(());
    }

    if sessions.lock().unwrap().contains_key(&id) {
        send(error_frame(id, "duplicate_id", &format!("stream {id} already in flight"), false))?;
        return Ok(());
    }
    let wire = match WireRequest::from_json(&parsed) {
        Ok(w) => w,
        Err(e) => {
            send(error_frame(id, "invalid", &format!("bad request: {e}"), false))?;
            return Ok(());
        }
    };
    let req = match wire.to_request(n_layers) {
        Ok(r) => r,
        Err(e) => {
            send(error_frame(id, "invalid", &e.to_string(), false))?;
            return Ok(());
        }
    };
    match coord.open(req) {
        Err(e) => send(error_frame_err(id, &e))?,
        Ok(handle) => {
            sessions.lock().unwrap().insert(id, handle.cancel_token());
            let out = out.clone();
            let sessions = sessions.clone();
            std::thread::spawn(move || pump_session(id, handle, &out, &sessions));
        }
    }
    Ok(())
}

/// Forward one session's events to the connection as NDJSON frames.
/// Exits on the terminal event, or when the outbound path dies (socket
/// gone or slow-client teardown) — dropping the handle then cancels the
/// session (cancel-on-drop).
fn pump_session(id: u64, handle: SessionHandle, out: &ConnWriter, sessions: &SessionMap) {
    let tok = Tokenizer::new();
    while let Some(ev) = handle.recv() {
        let (j, terminal) = match ev {
            SessionEvent::Queued => (frame(id, "queued"), false),
            SessionEvent::Prefilled {
                first_token,
                omsr,
                modes,
                ttft_us,
                queue_us,
                cached_prefix_tokens,
            } => {
                let mut o = frame(id, "prefilled");
                o.set("token", Json::from(first_token as usize));
                o.set("omsr", Json::from(omsr));
                o.set("modes", Json::from(modes));
                o.set("ttft_ms", Json::from(ttft_us as f64 / 1e3));
                o.set("queue_ms", Json::from(queue_us as f64 / 1e3));
                o.set("cached_prefix_tokens", Json::from(cached_prefix_tokens));
                (o, false)
            }
            SessionEvent::Token { tok: t, step_us } => {
                let mut o = frame(id, "token");
                o.set("token", Json::from(t as usize));
                o.set("step_ms", Json::from(step_us as f64 / 1e3));
                (o, false)
            }
            SessionEvent::Preempted { streamed, preemptions } => {
                let mut o = frame(id, "preempted");
                o.set("streamed", Json::from(streamed));
                o.set("preemptions", Json::from(preemptions as usize));
                (o, false)
            }
            SessionEvent::Resumed { resume_us, preemptions } => {
                let mut o = frame(id, "resumed");
                o.set("resume_ms", Json::from(resume_us as f64 / 1e3));
                o.set("preemptions", Json::from(preemptions as usize));
                (o, false)
            }
            SessionEvent::Done { stats } => {
                let mut o = frame(id, "done");
                o.set(
                    "tokens",
                    Json::from(stats.tokens.iter().map(|&t| t as usize).collect::<Vec<_>>()),
                );
                o.set("text", Json::from(tok.decode(&stats.tokens)));
                o.set("omsr", Json::from(stats.omsr));
                o.set("modes", Json::from(stats.modes));
                o.set("ttft_ms", Json::from(stats.ttft_us as f64 / 1e3));
                o.set("e2e_ms", Json::from(stats.e2e_us as f64 / 1e3));
                o.set("decode_ms_per_token", Json::from(stats.decode_us_per_token / 1e3));
                o.set("queue_ms", Json::from(stats.queue_us as f64 / 1e3));
                (o, true)
            }
            SessionEvent::Error { error } => (error_frame_err(id, &error), true),
        };
        if terminal {
            // free the id for reuse BEFORE the terminal frame is
            // visible to the client (the protocol permits immediate
            // reuse after done/error); all removals live inside this
            // function so a reused id's fresh entry is never clobbered
            sessions.lock().unwrap().remove(&id);
            let _ = out.send(j);
            return;
        }
        if out.send(j).is_err() {
            // outbound path gone; dropping `handle` cancels the session
            sessions.lock().unwrap().remove(&id);
            return;
        }
    }
    // Event channel closed without a terminal event (scheduler wound
    // down mid-stream). The protocol promises exactly one terminal
    // frame per stream, so synthesize a typed one rather than going
    // silent — clients key retry logic on it.
    sessions.lock().unwrap().remove(&id);
    let _ = out.send(error_frame(
        id,
        "shutdown",
        "stream closed: scheduler shut down before completion",
        false,
    ));
}

/// v1 path: run the request to completion and build the aggregate
/// response (`submit` is the session adapter, so v1 and v2 share the
/// scheduler code path).
fn process_request(coord: &Coordinator, tok: &Tokenizer, parsed: &Json, n_layers: usize) -> WireResponse {
    let wire = match WireRequest::from_json(parsed) {
        Ok(w) => w,
        Err(e) => return error_response(&format!("bad request: {e}")),
    };
    let req = match wire.to_request(n_layers) {
        Ok(r) => r,
        Err(e) => return error_response(&e.to_string()),
    };
    match coord.submit(req) {
        Ok(r) => WireResponse {
            text: tok.decode(&r.tokens),
            tokens: r.tokens,
            omsr: r.omsr,
            modes: r.modes,
            ttft_ms: r.ttft_us as f64 / 1e3,
            e2e_ms: r.e2e_us as f64 / 1e3,
            decode_ms_per_token: r.decode_us_per_token / 1e3,
            queue_ms: r.queue_us as f64 / 1e3,
            error: None,
            retryable: false,
            retry_after_ms: None,
        },
        Err(e) => error_response(&e.to_string()),
    }
}

fn error_response(msg: &str) -> WireResponse {
    WireResponse { error: Some(msg.to_string()), ..Default::default() }
}

// ---------------------------------------------------------------------------
// Clients
// ---------------------------------------------------------------------------

/// Minimal blocking v1 client for examples and tests.
pub fn client_request(addr: &str, req: &WireRequest) -> Result<WireResponse> {
    let sock = TcpStream::connect(addr)?;
    let mut wr = sock.try_clone()?;
    let mut v1 = req.clone();
    v1.id = None; // the v1 path is selected by the absence of an id
    wr.write_all(format!("{}\n", v1.to_json()).as_bytes())?;
    wr.flush()?;
    let mut rd = BufReader::new(sock);
    let mut line = String::new();
    rd.read_line(&mut line)?;
    anyhow::ensure!(!line.is_empty(), "server closed connection");
    let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    Ok(WireResponse::from_json(&j))
}

/// Per-stream inbox registry of a [`StreamClient`] connection.
type Inboxes = Arc<Mutex<HashMap<u64, Sender<Json>>>>;

/// Multiplexing v2 client: one TCP connection, many in-flight streams.
/// A background thread demultiplexes inbound frames by `id` into
/// per-stream channels. Dropping the client shuts the connection down
/// (winding down the demux thread and cancelling any server-side
/// streams still in flight).
pub struct StreamClient {
    wr: SharedWriter,
    next_id: AtomicU64,
    inboxes: Inboxes,
}

impl Drop for StreamClient {
    fn drop(&mut self) {
        // unblock the demux thread's read; the server sees EOF and
        // cancels this connection's live streams
        let _ = self.wr.lock().unwrap().shutdown(std::net::Shutdown::Both);
    }
}

impl StreamClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr)?;
        let wr = Arc::new(Mutex::new(sock.try_clone()?));
        let inboxes: Inboxes = Arc::new(Mutex::new(HashMap::new()));
        let demux = inboxes.clone();
        std::thread::spawn(move || {
            let rd = BufReader::new(sock);
            for line in rd.lines() {
                let Ok(line) = line else { break };
                let Ok(j) = Json::parse(&line) else { continue };
                let Some(id) = j.get("id").and_then(Json::as_usize).map(|v| v as u64) else {
                    continue; // v1 responses are not ours
                };
                let terminal =
                    matches!(j.get("event").and_then(Json::as_str), Some("done") | Some("error"));
                let mut map = demux.lock().unwrap();
                if let Some(tx) = map.get(&id) {
                    let _ = tx.send(j);
                }
                if terminal {
                    // closing the inbox ends the stream's recv loop
                    map.remove(&id);
                }
            }
            // connection closed: drop every inbox so readers unblock
            demux.lock().unwrap().clear();
        });
        Ok(Self { wr, next_id: AtomicU64::new(1), inboxes })
    }

    /// Open a stream; the request's `id` is assigned automatically.
    pub fn open(&self, req: &WireRequest) -> Result<ClientStream> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.inboxes.lock().unwrap().insert(id, tx);
        let mut w = req.clone();
        w.id = Some(id);
        if let Err(e) = write_line(&self.wr, &w.to_json()) {
            self.inboxes.lock().unwrap().remove(&id);
            return Err(e.into());
        }
        Ok(ClientStream { id, rx, wr: self.wr.clone() })
    }

    /// Run a request to completion, resubmitting on retryable failures
    /// (queue_full, overloaded, draining, engine_failed) with
    /// decorrelated-jitter backoff. Non-retryable errors and successes
    /// return immediately; after `max_retries` resubmissions the last
    /// response is returned as-is. Transport errors (connection gone)
    /// are not retried — the connection is owned by this client and
    /// will not come back. Equivalent to [`StreamClient::retry_with_policy`]
    /// with a cap of `64 * base_backoff`.
    pub fn retry_with_backoff(
        &self,
        req: &WireRequest,
        max_retries: usize,
        base_backoff: std::time::Duration,
    ) -> Result<WireResponse> {
        self.retry_with_policy(
            req,
            &RetryPolicy {
                max_retries,
                base_backoff,
                max_backoff: base_backoff.saturating_mul(64),
                seed: self.next_id.load(Ordering::Relaxed),
            },
        )
    }

    /// [`StreamClient::retry_with_backoff`] with an explicit
    /// [`RetryPolicy`] (attempt cap, backoff bounds, jitter seed).
    pub fn retry_with_policy(
        &self,
        req: &WireRequest,
        policy: &RetryPolicy,
    ) -> Result<WireResponse> {
        let mut jitter = RetryJitter::new(policy);
        for _ in 0..policy.max_retries {
            let resp = self.open(req)?.wait()?;
            if resp.error.is_none() || !resp.retryable {
                return Ok(resp);
            }
            let mut sleep = jitter.next_backoff();
            // the server's retry_after_ms hint is a FLOOR under the
            // jitter, not a replacement: the decorrelation (and its
            // geometric growth across attempts) is preserved, the
            // server just rules out sleeps it knows are pointless
            if let Some(ms) = resp.retry_after_ms {
                sleep = sleep.max(Duration::from_millis(ms));
            }
            std::thread::sleep(sleep);
        }
        self.open(req)?.wait()
    }
}

/// Retry shape for [`StreamClient::retry_with_policy`]: how many times,
/// how long, and which jitter stream.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Resubmissions after the first attempt (the attempt cap is
    /// `max_retries + 1` total submissions).
    pub max_retries: usize,
    /// Lower bound of every sleep (and the first sleep's upper bound is
    /// `3 * base_backoff`).
    pub base_backoff: std::time::Duration,
    /// Hard ceiling on any single sleep.
    pub max_backoff: std::time::Duration,
    /// Jitter-stream seed. Clients that share a seed share a sleep
    /// sequence — pass something per-client (connection id, stream id)
    /// so a replica failure does not make the whole fleet retry in
    /// lockstep.
    pub seed: u64,
}

/// Decorrelated jitter (`sleep = min(cap, uniform(base, prev * 3))`):
/// each sleep is drawn from a range anchored on the PREVIOUS sleep, so
/// synchronized clients decorrelate after one round while the expected
/// backoff still grows geometrically. The uniform draw comes from a
/// tiny splitmix-style PRNG — deterministic per seed, no external
/// dependencies.
struct RetryJitter {
    prev: std::time::Duration,
    base: std::time::Duration,
    cap: std::time::Duration,
    state: u64,
}

impl RetryJitter {
    fn new(policy: &RetryPolicy) -> Self {
        Self {
            prev: policy.base_backoff,
            base: policy.base_backoff,
            cap: policy.max_backoff,
            state: policy.seed.wrapping_add(0x9e37_79b9_7f4a_7c15),
        }
    }

    fn next_u64(&mut self) -> u64 {
        // splitmix64: full-period, passes statistical tests, three lines
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn next_backoff(&mut self) -> std::time::Duration {
        let base = self.base.as_nanos().max(1) as u64;
        let hi = self.prev.saturating_mul(3).as_nanos().min(u64::MAX as u128) as u64;
        let span = hi.saturating_sub(base);
        let draw = base + if span == 0 { 0 } else { self.next_u64() % (span + 1) };
        let sleep = std::time::Duration::from_nanos(draw).min(self.cap);
        self.prev = sleep.max(self.base);
        sleep
    }
}

/// One in-flight stream on a [`StreamClient`] connection.
pub struct ClientStream {
    id: u64,
    rx: Receiver<Json>,
    wr: SharedWriter,
}

impl ClientStream {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next frame (blocking); `None` after the terminal frame.
    pub fn recv(&self) -> Option<Json> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<Json> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Send a `{"id":N,"cancel":true}` frame for this stream.
    pub fn cancel(&self) -> Result<()> {
        let mut o = Json::obj();
        o.set("id", Json::from(self.id as usize));
        o.set("cancel", Json::from(true));
        write_line(&self.wr, &o)?;
        Ok(())
    }

    /// Drain to the terminal frame and fold the events into an
    /// aggregate [`WireResponse`] (v1-shaped, assembled client-side).
    /// On an `error` frame the partial token stream is preserved.
    pub fn wait(self) -> Result<WireResponse> {
        let mut partial: Vec<u32> = vec![];
        while let Some(j) = self.recv() {
            match j.get("event").and_then(Json::as_str) {
                Some("prefilled") | Some("token") => {
                    if let Some(t) = j.get("token").and_then(Json::as_usize) {
                        partial.push(t as u32);
                    }
                }
                Some("done") => return Ok(WireResponse::from_json(&j)),
                Some("error") => {
                    let mut resp = WireResponse::from_json(&j);
                    resp.tokens = partial;
                    if resp.error.is_none() {
                        resp.error = Some("stream failed".into());
                    }
                    return Ok(resp);
                }
                _ => {}
            }
        }
        anyhow::bail!("stream {} closed before a terminal frame", self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RequestError;

    #[test]
    fn policy_parsing() {
        assert!(matches!(parse_policy("backbone", false, 8).unwrap(), Policy::Backbone));
        let p = parse_policy("flux-ta", true, 8).unwrap();
        assert_eq!(p.label(), "flux-fa-ta-sd");
        let s = parse_policy("static:fa,fa,ssa,ssa,fa,fa,ssa,ssa", false, 8).unwrap();
        assert_eq!(s.label(), "static-4of8");
        assert!(parse_policy("static:fa,fa", false, 8).is_err());
        assert!(parse_policy("nope", false, 8).is_err());
    }

    #[test]
    fn wire_request_roundtrip() {
        let j = Json::parse(r#"{"prompt":[1,2]}"#).unwrap();
        let w = WireRequest::from_json(&j).unwrap();
        assert_eq!(w.max_new, 8);
        assert_eq!(w.policy, "flux-ssa");
        assert!(!w.sparse_decode);
        assert_eq!(w.id, None);
        assert_eq!(w.deadline_ms, None);
        let j2 = Json::parse(&w.to_json().to_string()).unwrap();
        let w2 = WireRequest::from_json(&j2).unwrap();
        assert_eq!(w2.prompt, vec![1, 2]);
    }

    #[test]
    fn wire_request_v2_fields_roundtrip() {
        let w = WireRequest {
            prompt: vec![1, 2, 3],
            id: Some(42),
            deadline_ms: Some(250),
            stop_tokens: vec![3, 9],
            ignore_eos: true,
            ..Default::default()
        };
        let j = Json::parse(&w.to_json().to_string()).unwrap();
        let w2 = WireRequest::from_json(&j).unwrap();
        assert_eq!(w2.id, Some(42));
        assert_eq!(w2.deadline_ms, Some(250));
        assert_eq!(w2.stop_tokens, vec![3, 9]);
        assert!(w2.ignore_eos);
        let req = w2.to_request(8).unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        assert_eq!(req.stop_tokens, vec![3, 9]);
        assert!(req.ignore_eos);
    }

    #[test]
    fn wire_response_roundtrip_includes_queue_ms() {
        let r = WireResponse {
            tokens: vec![5, 2],
            text: "w0 <eos>".into(),
            omsr: 0.5,
            modes: vec!["fa".into(), "ssa".into()],
            ttft_ms: 1.5,
            e2e_ms: 3.0,
            decode_ms_per_token: 0.7,
            queue_ms: 0.4,
            error: None,
            retryable: false,
            retry_after_ms: None,
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert!(j.get("queue_ms").is_some(), "queue_ms must be serialized");
        let r2 = WireResponse::from_json(&j);
        assert_eq!(r2.tokens, r.tokens);
        assert_eq!(r2.modes, r.modes);
        assert!((r2.queue_ms - 0.4).abs() < 1e-9);
        assert!(r2.error.is_none());
    }

    #[test]
    fn event_frames_carry_id_and_kind() {
        let f = frame(7, "token");
        assert_eq!(f.get("id").and_then(Json::as_usize), Some(7));
        assert_eq!(f.get("event").and_then(Json::as_str), Some("token"));
        let e = error_frame(9, RequestError::DeadlineExceeded.kind(), "late", false);
        assert_eq!(e.get("kind").and_then(Json::as_str), Some("deadline_exceeded"));
        assert_eq!(e.get("event").and_then(Json::as_str), Some("error"));
    }

    #[test]
    fn error_frames_carry_code_and_retryable() {
        let e = error_frame(3, RequestError::QueueFull.kind(), "full", true);
        assert_eq!(e.get("code").and_then(Json::as_str), Some("queue_full"));
        assert_eq!(e.get("retryable").and_then(Json::as_bool), Some(true));
        let e = error_frame(3, "invalid", "bad request", false);
        assert_eq!(e.get("code").and_then(Json::as_str), Some("invalid"));
        assert_eq!(e.get("retryable").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn typed_error_frames_carry_detail_and_replica() {
        let e = error_frame_err(
            4,
            &RequestError::Overloaded {
                detail: "queue_watermark",
                message: "all queues saturated".into(),
            },
        );
        assert_eq!(e.get("kind").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(e.get("detail").and_then(Json::as_str), Some("queue_watermark"));
        assert_eq!(e.get("retryable").and_then(Json::as_bool), Some(true));
        assert!(e.get("replica").is_none());
        let e = error_frame_err(
            5,
            &RequestError::EngineFailed { cause: "kaboom".into(), generation: 2, replica: 1 },
        );
        assert_eq!(e.get("kind").and_then(Json::as_str), Some("engine_failed"));
        assert_eq!(e.get("replica").and_then(Json::as_usize), Some(1));
        assert!(e.get("detail").is_none());
        // errors without extras keep the lean frame shape
        let e = error_frame_err(6, &RequestError::QueueFull);
        assert!(e.get("detail").is_none() && e.get("replica").is_none());
    }

    /// Retryable error frames carry the server-suggested backoff floor
    /// (DESIGN.md §15 satellite); non-retryable ones never do.
    #[test]
    fn retryable_error_frames_carry_retry_after_hint() {
        let e = error_frame_err(1, &RequestError::QueueFull);
        assert_eq!(e.get("retry_after_ms").and_then(Json::as_usize), Some(25));
        let e = error_frame_err(2, &RequestError::Draining);
        assert_eq!(e.get("retry_after_ms").and_then(Json::as_usize), Some(200));
        let e = error_frame_err(3, &RequestError::PreemptionExhausted { preemptions: 4 });
        assert_eq!(e.get("kind").and_then(Json::as_str), Some("preemption_exhausted"));
        assert_eq!(e.get("retryable").and_then(Json::as_bool), Some(true));
        assert_eq!(e.get("retry_after_ms").and_then(Json::as_usize), Some(100));
        let e = error_frame_err(4, &RequestError::Cancelled);
        assert!(e.get("retry_after_ms").is_none());
        // and the hint roundtrips through the aggregate response shape
        let r = WireResponse {
            error: Some("busy".into()),
            retryable: true,
            retry_after_ms: Some(100),
            ..Default::default()
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(WireResponse::from_json(&j).retry_after_ms, Some(100));
        // successes omit it
        let j = Json::parse(&WireResponse::default().to_json().to_string()).unwrap();
        assert_eq!(WireResponse::from_json(&j).retry_after_ms, None);
    }

    #[test]
    fn retry_jitter_is_bounded_decorrelated_and_seed_deterministic() {
        let policy = RetryPolicy {
            max_retries: 8,
            base_backoff: std::time::Duration::from_millis(10),
            max_backoff: std::time::Duration::from_millis(200),
            seed: 42,
        };
        let mut a = RetryJitter::new(&policy);
        let mut b = RetryJitter::new(&policy);
        let mut prev = policy.base_backoff;
        for _ in 0..64 {
            let s = a.next_backoff();
            // bounds: base ≤ sleep ≤ min(cap, prev*3)
            assert!(s >= policy.base_backoff, "{s:?} below base");
            assert!(s <= policy.max_backoff, "{s:?} above cap");
            assert!(s <= prev.saturating_mul(3).max(policy.base_backoff), "{s:?} vs {prev:?}");
            assert_eq!(s, b.next_backoff(), "same seed must give the same sequence");
            prev = s.max(policy.base_backoff);
        }
        // different seeds decorrelate (the whole point): the sequences
        // must not be identical
        let mut c = RetryJitter::new(&RetryPolicy { seed: 43, ..policy.clone() });
        let mut d = RetryJitter::new(&RetryPolicy { seed: 42, ..policy });
        let diverged = (0..64).any(|_| c.next_backoff() != d.next_backoff());
        assert!(diverged, "seeds 42 and 43 produced identical jitter streams");
    }

    #[test]
    fn wire_response_roundtrips_retryable_with_error() {
        let r = WireResponse {
            error: Some("overloaded: try later".into()),
            retryable: true,
            ..Default::default()
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = WireResponse::from_json(&j);
        assert!(r2.retryable);
        assert_eq!(r2.error.as_deref(), Some("overloaded: try later"));
        // success responses omit the flag and parse back as false
        let ok = WireResponse::default();
        let j = Json::parse(&ok.to_json().to_string()).unwrap();
        assert!(!WireResponse::from_json(&j).retryable);
    }
}
