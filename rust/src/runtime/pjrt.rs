//! PJRT execution backend (cargo feature `pjrt`): loads the AOT HLO-text
//! artifacts produced by `python -m compile.aot` and executes them
//! through the PJRT C API.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Interchange is HLO *text* — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §3).
//!
//! The in-tree `xla` crate is a type-level stub whose client constructor
//! fails at runtime; point the path dependency at the real crate to
//! execute against PJRT. Either way this module satisfies the
//! [`super::Backend`] seam, so everything above the runtime is agnostic.
//!
//! `PjrtBackend` is deliberately `!Send`: PJRT handles are raw pointers.
//! The [`crate::engine`] owns it on a dedicated executor thread.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::{Arg, Backend, ExeStats, HostTensor, TensorView};

/// Device-boundary staging: both owned tensors and zero-copy views are
/// read through [`TensorView`] — the host-side copy happens exactly once
/// here, into the device literal.
fn to_literal(t: TensorView<'_>) -> Result<Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(t.data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

fn from_literal(lit: &Literal) -> Result<HostTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("{e:?}"))?
        .dims()
        .iter()
        .map(|&d| d as usize)
        .collect();
    Ok(HostTensor {
        shape,
        data: lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
    })
}

/// Loads, compiles and caches the AOT executables.
pub struct PjrtBackend {
    client: PjRtClient,
    dir: PathBuf,
    exes: HashMap<String, PjRtLoadedExecutable>,
    stats: HashMap<String, ExeStats>,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Self {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            exes: HashMap::new(),
            stats: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Compile (and cache) the executable `exe` from `<dir>/<exe>.hlo.txt`.
    fn load(&mut self, exe: &str) -> Result<()> {
        if self.exes.contains_key(exe) {
            return Ok(());
        }
        let path = self.dir.join(format!("{exe}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("loading {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let compiled = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("compiling {exe}"))?;
        self.exes.insert(exe.to_string(), compiled);
        Ok(())
    }

    fn is_loaded(&self, exe: &str) -> bool {
        self.exes.contains_key(exe)
    }

    /// Execute with host-tensor arguments; returns the decomposed output
    /// tuple (every artifact is lowered with `return_tuple=True`).
    fn run(&mut self, exe: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let compiled = self
            .exes
            .get(exe)
            .ok_or_else(|| anyhow::anyhow!("executable {exe} not loaded"))?;
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            lits.push(match a {
                Arg::F32(_) | Arg::F32View(_) => to_literal(a.view()?)?,
                Arg::I32(v) => Literal::vec1(v),
            });
        }
        let refs: Vec<&Literal> = lits.iter().collect();
        let out = compiled
            .execute::<&Literal>(&refs)
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in &parts {
            tensors.push(from_literal(p)?);
        }
        let st = self.stats.entry(exe.to_string()).or_default();
        st.calls += 1;
        st.total_us += t0.elapsed().as_micros() as u64;
        Ok(tensors)
    }

    fn stats(&self) -> &HashMap<String, ExeStats> {
        &self.stats
    }

    fn reset_stats(&mut self) {
        self.stats.clear();
    }

    fn note_kv_transfer(&mut self, exe: &str, bytes_moved: u64, bytes_borrowed: u64) {
        let st = self.stats.entry(exe.to_string()).or_default();
        st.kv_bytes_moved += bytes_moved;
        st.kv_bytes_borrowed += bytes_borrowed;
    }

    /// The AOT artifacts are lowered per request with fixed signatures;
    /// the variable-arity batched decode entry points (DESIGN.md §9)
    /// are a host-backend capability. The engine degrades to the serial
    /// per-request decode walk here.
    fn accepts_decode_batch(&self) -> bool {
        false
    }

    /// The history-aware chunked prefill kernels (DESIGN.md §10) are
    /// likewise host-backend-only: the AOT layers assume an empty KV
    /// history. The engine degrades a chunked prefill job to one
    /// monolithic prefill call here.
    fn accepts_prefill_chunks(&self) -> bool {
        false
    }
}
