//! Execution backends: the engine-facing [`Backend`] trait plus the
//! host-side interchange types and artifact weight loading (DESIGN.md §3).
//!
//! The engine never talks to a device API directly — it calls named
//! executables (`layer_fa_prefill_256`, `decode_qkv`, `lm_head`, …)
//! through `Backend::run` with [`HostTensor`] / i32 arguments and gets
//! [`HostTensor`] outputs back. Two implementations exist:
//!
//! * [`ref_backend::RefBackend`] — pure-Rust CPU kernels mirroring the
//!   math of `python/compile/kernels/ref.py`. The default: hermetic,
//!   deterministic, zero native dependencies. Drives the whole test
//!   suite via [`synthetic`] artifacts.
//! * [`pjrt::PjrtBackend`] (cargo feature `pjrt`) — loads the AOT
//!   HLO-text artifacts produced by `python -m compile.aot` and executes
//!   them through the PJRT C API via the `xla` crate.
//!
//! Backends are deliberately NOT required to be `Send`: PJRT handles are
//! raw pointers. The [`crate::engine`] owns its backend on a dedicated
//! executor thread and the coordinator talks to that thread over
//! channels.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

pub mod chaos;
pub mod ref_backend;
pub mod synthetic;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use ref_backend::RefBackend;

/// A host-side f32 tensor: shape + row-major data. The lingua franca
/// between the coordinator, KV caches and every execution backend.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Borrowed view of an f32 tensor: shape + row-major data, both
/// borrowed from whoever owns the buffer (a KV cache, a weight store, a
/// slice of a larger tensor). This is the zero-copy half of the
/// interchange — the KV caches hand out views of their internal
/// executable-layout buffers so the decode hot path stages arguments
/// without cloning (DESIGN.md §7).
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    pub shape: &'a [usize],
    pub data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

impl HostTensor {
    /// Borrow this tensor as a zero-copy view.
    pub fn view(&self) -> TensorView<'_> {
        TensorView { shape: &self.shape, data: &self.data }
    }
}

/// One borrowed executable argument. Mirrors the dtypes the AOT
/// executables accept: f32 tensors (owned or borrowed-view) and i32
/// scalar-vectors (positions, valid lengths).
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a HostTensor),
    /// Zero-copy variant: the backend reads straight out of the owner's
    /// buffer (KV caches on the aligned decode fast path).
    F32View(TensorView<'a>),
    I32(&'a [i32]),
}

impl<'a> Arg<'a> {
    /// Unwrap as an owned f32 tensor (backend-side argument checking).
    /// Fails on `F32View` — kernels that accept borrowed caches should
    /// use [`Arg::view`] instead.
    pub fn f32(&self) -> Result<&'a HostTensor> {
        match self {
            Arg::F32(t) => Ok(t),
            Arg::F32View(_) => {
                anyhow::bail!("expected owned f32 tensor argument, got borrowed view")
            }
            Arg::I32(_) => anyhow::bail!("expected f32 tensor argument, got i32"),
        }
    }

    /// Unwrap as an f32 view — works for both `F32` (borrowing the owned
    /// tensor) and `F32View` arguments.
    pub fn view(&self) -> Result<TensorView<'a>> {
        match self {
            Arg::F32(t) => Ok(t.view()),
            Arg::F32View(v) => Ok(*v),
            Arg::I32(_) => anyhow::bail!("expected f32 tensor argument, got i32"),
        }
    }

    /// Unwrap as an i32 vector.
    pub fn i32(&self) -> Result<&'a [i32]> {
        match self {
            Arg::I32(v) => Ok(v),
            Arg::F32(_) | Arg::F32View(_) => {
                anyhow::bail!("expected i32 argument, got f32 tensor")
            }
        }
    }
}

/// Cumulative execution statistics per executable (feeds the §Perf pass
/// and the Fig 9 router-overhead bench).
#[derive(Debug, Default, Clone)]
pub struct ExeStats {
    pub calls: u64,
    pub total_us: u64,
    /// KV-cache bytes physically copied (re-bucketed / re-laid-out) to
    /// stage this executable's arguments. Zero on the aligned decode
    /// fast path — the integration suite pins this.
    pub kv_bytes_moved: u64,
    /// KV-cache bytes staged as borrowed views instead of copies — the
    /// "copies avoided" counter of the zero-copy interchange.
    pub kv_bytes_borrowed: u64,
    /// Prefill rows that carried real prompt tokens — with
    /// `rows_padded`, the bucket-padding compute-utilization ledger
    /// (`flux bench` reports valid/(valid+padded) per configuration).
    pub rows_valid: u64,
    /// Prefill rows that were bucket padding (computed as zeros or
    /// skipped, but occupying the executable's row budget either way).
    pub rows_padded: u64,
}

/// An executable provider: loads named executables from the artifact
/// directory (or validates them against the model config, for the
/// reference backend) and runs them on host tensors.
///
/// This is the multi-backend seam: the serving stack above it (engine,
/// coordinator, eval, CLI) is backend-agnostic.
pub trait Backend {
    /// Short backend identifier ("ref", "pjrt", …) for logs and tests.
    fn name(&self) -> &'static str;

    /// Prepare executable `exe` (compile / validate). Idempotent.
    fn load(&mut self, exe: &str) -> Result<()>;

    fn is_loaded(&self, exe: &str) -> bool;

    /// Execute `exe`; returns the decomposed output tuple. Errors if the
    /// executable was never loaded or the arguments mismatch its
    /// signature.
    fn run(&mut self, exe: &str, args: &[Arg]) -> Result<Vec<HostTensor>>;

    fn stats(&self) -> &HashMap<String, ExeStats>;

    fn reset_stats(&mut self);

    /// Record KV-interchange accounting for `exe`: bytes of cache data
    /// physically copied vs staged as borrowed views when preparing its
    /// arguments. The engine calls this from the decode hot path;
    /// backends fold it into [`Backend::stats`]. Default: dropped.
    fn note_kv_transfer(&mut self, exe: &str, bytes_moved: u64, bytes_borrowed: u64) {
        let _ = (exe, bytes_moved, bytes_borrowed);
    }

    /// Record prefill row accounting for `exe`: rows that carried real
    /// prompt tokens vs bucket-padding rows. The engine calls this once
    /// per prefill layer call; backends fold it into [`Backend::stats`]
    /// so `flux bench` can report compute utilization. Default: dropped.
    fn note_prefill_rows(&mut self, exe: &str, rows_valid: u64, rows_padded: u64) {
        let _ = (exe, rows_valid, rows_padded);
    }

    /// Set the kernel worker count for backends with host-side compute
    /// (the reference kernels). No-op for device backends; results are
    /// bit-identical for every worker count (DESIGN.md §7).
    fn set_threads(&mut self, n: usize) {
        let _ = n;
    }

    /// Whether `layer_*_prefill_*` executables accept the optional 10th
    /// valid-length argument (padded-tail skipping, DESIGN.md §7). The
    /// AOT artifacts are lowered for the fixed 9-input signature, so
    /// device backends default to `false`; the engine only appends the
    /// argument when the backend opts in.
    fn accepts_prefill_valid_arg(&self) -> bool {
        false
    }

    /// Whether the backend serves the history-aware chunked prefill
    /// entry points (`layer_{mode}_prefill_chunk_{S}` — DESIGN.md §10),
    /// which attend a bucketed prompt chunk over the request's
    /// already-staged KV prefix passed as borrowed views. The AOT
    /// artifacts only lower the empty-history monolithic layers, so
    /// device backends default to `false`; the engine then degrades a
    /// chunked prefill job to one monolithic prefill call.
    fn accepts_prefill_chunks(&self) -> bool {
        false
    }

    /// Whether the backend serves the batched decode entry points
    /// (`decode_qkv_batch`, `attend_batch_fa`, `attend_batch_sa`,
    /// `lm_head_batch` — DESIGN.md §9), which take a whole same-mode
    /// request group per call with per-request KV cache arguments of
    /// possibly different bucket sizes. The AOT artifacts are lowered
    /// per request with fixed signatures, so device backends default to
    /// `false`; the engine then degrades transparently to the serial
    /// per-request decode walk.
    fn accepts_decode_batch(&self) -> bool {
        false
    }
}

/// Default kernel worker count: `FLUX_THREADS` when set (clamped to
/// ≥ 1), otherwise the machine's available parallelism capped at 8 —
/// the reference kernels are memory-bound well before that on typical
/// hosts. Determinism never depends on this value.
pub fn flux_threads_default() -> usize {
    if let Ok(v) = std::env::var("FLUX_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Select and construct a backend for an artifact directory.
///
/// `hint` is the optional `"backend"` field of `manifest.json`
/// (`synthetic` artifacts say `"ref"`). Resolution:
/// * default build — always the pure-Rust [`RefBackend`];
/// * `--features pjrt` — [`pjrt::PjrtBackend`] unless the manifest asks
///   for `"ref"` explicitly.
pub fn open_backend(
    cfg: &crate::config::MetaConfig,
    hint: Option<&str>,
) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        if hint != Some("ref") {
            return Ok(Box::new(pjrt::PjrtBackend::new(&cfg.artifacts_dir)?));
        }
    }
    let _ = hint;
    Ok(Box::new(RefBackend::new(cfg.clone())))
}

/// Weight blob loader: `weights.bin` (raw little-endian f32) + the JSON
/// manifest written by `python/compile/train.py::export_flat_bin` or by
/// [`synthetic::write_artifacts`].
#[derive(Debug)]
pub struct WeightStore {
    tensors: HashMap<String, HostTensor>,
}

impl WeightStore {
    pub fn load(bin_path: impl AsRef<Path>, manifest_path: impl AsRef<Path>) -> Result<Self> {
        let blob = std::fs::read(&bin_path)
            .with_context(|| format!("reading {:?}", bin_path.as_ref()))?;
        let manifest = crate::util::json::Json::parse(
            &std::fs::read_to_string(&manifest_path)?,
        )
        .map_err(|e| anyhow::anyhow!("weights manifest: {e}"))?;
        let mut tensors = HashMap::new();
        for e in manifest.as_arr().context("manifest must be an array")? {
            let name = e.get("name").and_then(|v| v.as_str()).context("entry name")?;
            let offset = e.get("offset").and_then(|v| v.as_usize()).context("entry offset")?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(|v| v.as_arr())
                .context("entry shape")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n * 4 <= blob.len(), "weight {name} out of range");
            let bytes = &blob[offset..offset + n * 4];
            let mut data = vec![0f32; n];
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            tensors.insert(name.to_string(), HostTensor::new(shape, data));
        }
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight {name} missing from manifest"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    /// Slice layer `i` out of a stacked `(L, ...)` tensor.
    pub fn layer_slice(&self, name: &str, i: usize) -> Result<HostTensor> {
        let t = self.get(name)?;
        anyhow::ensure!(!t.shape.is_empty(), "scalar tensor has no layer axis");
        let per: usize = t.shape[1..].iter().product();
        anyhow::ensure!(i < t.shape[0], "layer index {i} out of range");
        Ok(HostTensor::new(
            t.shape[1..].to_vec(),
            t.data[i * per..(i + 1) * per].to_vec(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_unwrapping() {
        let t = HostTensor::new(vec![2], vec![1.0, 2.0]);
        let pos = [5i32];
        assert_eq!(Arg::F32(&t).f32().unwrap().data, vec![1.0, 2.0]);
        assert_eq!(Arg::I32(&pos).i32().unwrap(), &[5]);
        assert!(Arg::F32(&t).i32().is_err());
        assert!(Arg::I32(&pos).f32().is_err());
    }

    #[test]
    fn arg_views_are_zero_copy_compatible() {
        let t = HostTensor::new(vec![2, 1], vec![3.0, 4.0]);
        // owned args are viewable; views report the same shape + data
        let v1 = Arg::F32(&t).view().unwrap();
        assert_eq!(v1.shape, &[2, 1]);
        assert_eq!(v1.data, &[3.0, 4.0]);
        let shape = [2usize, 1];
        let data = [3.0f32, 4.0];
        let v = TensorView { shape: &shape, data: &data };
        let v2 = Arg::F32View(v).view().unwrap();
        assert_eq!(v2.shape, v1.shape);
        assert_eq!(v2.data, v1.data);
        assert_eq!(v2.numel(), 2);
        // a borrowed view never silently converts to an owned tensor
        assert!(Arg::F32View(v).f32().is_err());
        assert!(Arg::F32View(v).i32().is_err());
        let pos = [1i32];
        assert!(Arg::I32(&pos).view().is_err());
    }

    #[test]
    fn weight_store_layer_slice() {
        let dir = std::env::temp_dir().join("flux_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
        std::fs::write(
            dir.join("w.json"),
            r#"[{"name":"layers.w","offset":0,"shape":[3,2,2]}]"#,
        )
        .unwrap();
        let ws = WeightStore::load(dir.join("w.bin"), dir.join("w.json")).unwrap();
        let l1 = ws.layer_slice("layers.w", 1).unwrap();
        assert_eq!(l1.shape, vec![2, 2]);
        assert_eq!(l1.data, vec![4.0, 5.0, 6.0, 7.0]);
    }
}
