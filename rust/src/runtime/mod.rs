//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Interchange is HLO *text* — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §1).
//!
//! The runtime is deliberately `!Send`: PJRT handles are raw pointers.
//! The [`crate::engine`] owns it on a dedicated executor thread and the
//! async coordinator talks to that thread over channels.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// A host-side f32 tensor: shape + row-major data. The lingua franca
/// between the coordinator, KV caches and the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(Literal::vec1(&self.data).reshape(&dims)?)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit
            .array_shape()?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        Ok(Self { shape, data: lit.to_vec::<f32>()? })
    }
}

/// i32 scalar-vector helper (valid lengths, positions).
pub fn i32_literal(vals: &[i32]) -> Literal {
    Literal::vec1(vals)
}

/// Cumulative execution statistics per executable (feeds the §Perf pass
/// and the Fig 9 router-overhead bench).
#[derive(Debug, Default, Clone)]
pub struct ExeStats {
    pub calls: u64,
    pub total_us: u64,
}

/// Loads, compiles and caches the AOT executables.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    exes: HashMap<String, PjRtLoadedExecutable>,
    stats: HashMap<String, ExeStats>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Self {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            exes: HashMap::new(),
            stats: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) the executable `name` from
    /// `<dir>/<name>.hlo.txt`.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("loading {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("compiling {name}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute `name` with literal arguments; returns the decomposed
    /// output tuple as host tensors (every artifact is lowered with
    /// `return_tuple=True`).
    pub fn run(&mut self, name: &str, args: &[&Literal]) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("executable {name} not loaded"))?;
        let out = exe.execute::<&Literal>(args).map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in &parts {
            tensors.push(HostTensor::from_literal(p)?);
        }
        let st = self.stats.entry(name.to_string()).or_default();
        st.calls += 1;
        st.total_us += t0.elapsed().as_micros() as u64;
        Ok(tensors)
    }

    /// Raw-literal variant for callers that keep outputs as literals.
    pub fn run_raw(&mut self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("executable {name} not loaded"))?;
        let out = exe.execute::<&Literal>(args).map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let st = self.stats.entry(name.to_string()).or_default();
        st.calls += 1;
        st.total_us += t0.elapsed().as_micros() as u64;
        Ok(parts)
    }

    pub fn stats(&self) -> &HashMap<String, ExeStats> {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.clear();
    }
}

/// Weight blob loader: `weights.bin` (raw little-endian f32) + the JSON
/// manifest written by `python/compile/train.py::export_flat_bin`.
#[derive(Debug)]
pub struct WeightStore {
    tensors: HashMap<String, HostTensor>,
}

impl WeightStore {
    pub fn load(bin_path: impl AsRef<Path>, manifest_path: impl AsRef<Path>) -> Result<Self> {
        let blob = std::fs::read(&bin_path)
            .with_context(|| format!("reading {:?}", bin_path.as_ref()))?;
        let manifest = crate::util::json::Json::parse(
            &std::fs::read_to_string(&manifest_path)?,
        )
        .map_err(|e| anyhow::anyhow!("weights manifest: {e}"))?;
        let mut tensors = HashMap::new();
        for e in manifest.as_arr().context("manifest must be an array")? {
            let name = e.get("name").and_then(|v| v.as_str()).context("entry name")?;
            let offset = e.get("offset").and_then(|v| v.as_usize()).context("entry offset")?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(|v| v.as_arr())
                .context("entry shape")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n * 4 <= blob.len(), "weight {name} out of range");
            let bytes = &blob[offset..offset + n * 4];
            let mut data = vec![0f32; n];
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            tensors.insert(name.to_string(), HostTensor::new(shape, data));
        }
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight {name} missing from manifest"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    /// Slice layer `i` out of a stacked `(L, ...)` tensor.
    pub fn layer_slice(&self, name: &str, i: usize) -> Result<HostTensor> {
        let t = self.get(name)?;
        anyhow::ensure!(!t.shape.is_empty(), "scalar tensor has no layer axis");
        let per: usize = t.shape[1..].iter().product();
        anyhow::ensure!(i < t.shape[0], "layer index {i} out of range");
        Ok(HostTensor::new(
            t.shape[1..].to_vec(),
            t.data[i * per..(i + 1) * per].to_vec(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn weight_store_layer_slice() {
        let dir = std::env::temp_dir().join("flux_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
        std::fs::write(
            dir.join("w.json"),
            r#"[{"name":"layers.w","offset":0,"shape":[3,2,2]}]"#,
        )
        .unwrap();
        let ws = WeightStore::load(dir.join("w.bin"), dir.join("w.json")).unwrap();
        let l1 = ws.layer_slice("layers.w", 1).unwrap();
        assert_eq!(l1.shape, vec![2, 2]);
        assert_eq!(l1.data, vec![4.0, 5.0, 6.0, 7.0]);
    }
}
