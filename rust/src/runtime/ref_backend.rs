//! `RefBackend`: pure-Rust CPU reference implementations of every AOT
//! executable, mirroring the math of `python/compile/kernels/ref.py`
//! (RMSNorm → RoPE → attention variant → residual MLP).
//!
//! This is the default execution backend: hermetic (no Python / JAX /
//! XLA), deterministic (fixed summation order everywhere), and exact in
//! the serving-correctness sense — a decode step attends over cached K/V
//! with the *same* inner `attend_one` routine the prefill rows use, so
//! `prefill(p) + decode(t)` is bit-identical to `prefill(p ++ t)` for
//! dense layers (the teacher-forcing invariant the integration and
//! property tests pin down).
//!
//! The kernels are multi-threaded (worker count from `FLUX_THREADS` /
//! [`Backend::set_threads`]) yet bit-identical to the serial path:
//! work is partitioned over *disjoint output rows* (matmul output rows
//! or column stripes, attention heads) and every row keeps the serial
//! per-row accumulation order, so a worker count only changes who
//! computes a row, never any floating-point summation order
//! (DESIGN.md §7).
//!
//! Executable name contract (same names the PJRT artifacts use):
//!   `layer_{fa,ssa,ta,xa}_prefill_{S}`, `decode_qkv`,
//!   `decode_attend_fa_{K}`, `decode_attend_sa`, `router`, `lm_head`;
//! host-backend-only batched decode entry points (DESIGN.md §9):
//!   `decode_qkv_batch`, `attend_batch_fa`, `attend_batch_sa`,
//!   `lm_head_batch` — advertised via `Backend::accepts_decode_batch`;
//! host-backend-only chunked prefill entry points (DESIGN.md §10):
//!   `layer_{fa,ssa,ta,xa}_prefill_chunk_{S}` — a bucketed prompt chunk
//!   attending over the request's staged KV prefix, advertised via
//!   `Backend::accepts_prefill_chunks`.

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use anyhow::Result;

use crate::config::MetaConfig;
use super::{Arg, Backend, ExeStats, HostTensor};

/// Attention variant of a prefill executable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Fa,
    Ssa,
    Ta,
    Xa,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ExeKind {
    Prefill { mode: Mode, bucket: usize },
    /// history-aware chunked prefill: one bucketed prompt chunk
    /// attending over the request's staged KV prefix (DESIGN.md §10)
    PrefillChunk { mode: Mode, bucket: usize },
    DecodeQkv,
    DecodeAttend { kbuf: usize },
    /// batched stage-1 projection over a whole decode round (B rows)
    DecodeQkvBatch,
    /// batched stage-2 attend over one same-mode (layer, mode) group;
    /// per-request KV buckets ride on the argument shapes
    AttendBatch { sparse: bool },
    LmHeadBatch,
    Router,
    LmHead,
}

/// Pure-Rust reference backend, parameterized by the model config (the
/// PJRT artifacts bake these constants into the lowered HLO instead).
pub struct RefBackend {
    cfg: MetaConfig,
    /// kernel worker count; results are bit-identical for every value
    threads: usize,
    loaded: HashSet<String>,
    stats: HashMap<String, ExeStats>,
}

impl RefBackend {
    pub fn new(cfg: MetaConfig) -> Self {
        let threads = super::flux_threads_default();
        Self::with_threads(cfg, threads)
    }

    /// Construct with an explicit worker count (tests and the bench
    /// harness pin this to compare serial vs parallel runs bit-for-bit).
    pub fn with_threads(cfg: MetaConfig, threads: usize) -> Self {
        Self { cfg, threads: threads.max(1), loaded: HashSet::new(), stats: HashMap::new() }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn parse_exe(&self, exe: &str) -> Result<ExeKind> {
        if let Some(rest) = exe.strip_prefix("layer_") {
            let sep = rest
                .find("_prefill_")
                .ok_or_else(|| anyhow::anyhow!("bad prefill executable name '{exe}'"))?;
            let mode = match &rest[..sep] {
                "fa" => Mode::Fa,
                "ssa" => Mode::Ssa,
                "ta" => Mode::Ta,
                "xa" => Mode::Xa,
                other => anyhow::bail!("unknown attention mode '{other}' in '{exe}'"),
            };
            let tail = &rest[sep + "_prefill_".len()..];
            let (chunked, bucket_str) = match tail.strip_prefix("chunk_") {
                Some(b) => (true, b),
                None => (false, tail),
            };
            let bucket: usize = bucket_str.parse()?;
            anyhow::ensure!(
                self.cfg.prefill_buckets.contains(&bucket),
                "prefill bucket {bucket} not in config buckets {:?}",
                self.cfg.prefill_buckets
            );
            return Ok(if chunked {
                ExeKind::PrefillChunk { mode, bucket }
            } else {
                ExeKind::Prefill { mode, bucket }
            });
        }
        if exe == "decode_qkv" {
            return Ok(ExeKind::DecodeQkv);
        }
        if let Some(b) = exe.strip_prefix("decode_attend_fa_") {
            let kbuf: usize = b.parse()?;
            anyhow::ensure!(
                self.cfg.decode_kv_buckets.contains(&kbuf),
                "decode bucket {kbuf} not in config buckets {:?}",
                self.cfg.decode_kv_buckets
            );
            return Ok(ExeKind::DecodeAttend { kbuf });
        }
        if exe == "decode_attend_sa" {
            return Ok(ExeKind::DecodeAttend { kbuf: self.cfg.sa_buf });
        }
        if exe == "decode_qkv_batch" {
            return Ok(ExeKind::DecodeQkvBatch);
        }
        if exe == "attend_batch_fa" {
            return Ok(ExeKind::AttendBatch { sparse: false });
        }
        if exe == "attend_batch_sa" {
            return Ok(ExeKind::AttendBatch { sparse: true });
        }
        if exe == "lm_head_batch" {
            return Ok(ExeKind::LmHeadBatch);
        }
        if exe == "router" {
            return Ok(ExeKind::Router);
        }
        if exe == "lm_head" {
            return Ok(ExeKind::LmHead);
        }
        anyhow::bail!("RefBackend: unknown executable '{exe}'")
    }

    fn dispatch(&self, exe: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        match self.parse_exe(exe)? {
            ExeKind::Prefill { mode, bucket } => self.prefill_layer(mode, bucket, args),
            ExeKind::PrefillChunk { mode, bucket } => self.prefill_chunk(mode, bucket, args),
            ExeKind::DecodeQkv => self.decode_qkv(args),
            ExeKind::DecodeAttend { kbuf } => self.decode_attend(kbuf, args),
            ExeKind::DecodeQkvBatch => self.decode_qkv_batch(args),
            ExeKind::AttendBatch { sparse } => self.attend_batch(sparse, args),
            ExeKind::LmHeadBatch => self.lm_head_batch(args),
            ExeKind::Router => self.router_mlp(args),
            ExeKind::LmHead => self.lm_head(args),
        }
    }

    /// One transformer layer over a bucketed prompt.
    /// Args: x (S,d), norm1 (d), wq/wk/wv/wo (d,d), norm2 (d),
    /// w_ff1 (d,ff), w_ff2 (ff,d), optional valid (1,) i32.
    /// Returns (x_out (S,d), k (H,S,D), v (H,S,D)); k is post-RoPE.
    ///
    /// When `valid < S` (prompt padded up to the bucket), only the first
    /// `valid` rows are computed — padded tail rows of every output are
    /// zero instead of burning full attention + MLP on dead rows. For
    /// inputs whose tail rows are zero (the engine always embeds-with-
    /// zero-padding) the valid rows are bit-identical to the full-bucket
    /// computation; with 9 args `valid` defaults to `S` (old behavior,
    /// exact).
    fn prefill_layer(&self, mode: Mode, s: usize, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, ff) = (m.d_model, m.d_ff);
        anyhow::ensure!(
            args.len() == 9 || args.len() == 10,
            "prefill layer expects 9 args (+ optional valid length), got {}",
            args.len()
        );
        let x = args[0].f32()?;
        want(x, &[s, d], "prefill x")?;
        let norm1 = args[1].f32()?;
        let wq = args[2].f32()?;
        let wk = args[3].f32()?;
        let wv = args[4].f32()?;
        let wo = args[5].f32()?;
        let norm2 = args[6].f32()?;
        let w_ff1 = args[7].f32()?;
        let w_ff2 = args[8].f32()?;
        want(norm1, &[d], "norm1")?;
        want(wq, &[d, d], "wq")?;
        want(w_ff1, &[d, ff], "w_ff1")?;
        want(w_ff2, &[ff, d], "w_ff2")?;
        let valid = if args.len() == 10 {
            let va = args[9].i32()?;
            anyhow::ensure!(va.len() == 1, "valid_len must be a single i32");
            let v = va[0] as usize;
            anyhow::ensure!((1..=s).contains(&v), "valid {v} out of range 1..={s}");
            v
        } else {
            s
        };
        self.prefill_impl(
            mode,
            s,
            x,
            [norm1, wq, wk, wv, wo, norm2, w_ff1, w_ff2],
            valid,
            None,
            0,
            s,
        )
    }

    /// History-aware chunked prefill layer (DESIGN.md §10): one bucketed
    /// prompt chunk attending over the request's already-staged KV
    /// prefix, passed as zero-copy views.
    /// Args: x (Sc,d) — chunk hidden rows with a zero tail past `valid`;
    /// norm1 (d); wq/wk/wv/wo (d,d); norm2 (d); w_ff1 (d,ff);
    /// w_ff2 (ff,d); k_hist/v_hist (H, C, D) — the staged prefix in
    /// natural append order (C ≥ base; rows `base..C` are ignored);
    /// meta (3,) i32 = [base, valid, total_bucket] where `base` is the
    /// chunk's absolute start position (== staged history length),
    /// `valid` the real token rows in this chunk, and `total_bucket`
    /// the request-level monolithic bucket (governs the TA dense-tail
    /// condition and the XA threshold row width).
    /// Returns (x_out (Sc,d), k (H,Sc,D), v (H,Sc,D)) for the chunk
    /// rows — bit-identical to the same rows of a monolithic prefill
    /// at bucket `total_bucket` (pinned by `tests/chunked.rs`).
    fn prefill_chunk(&self, mode: Mode, s: usize, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
        anyhow::ensure!(
            args.len() == 12,
            "prefill chunk expects 12 args (x, 8 weights, k_hist, v_hist, meta), got {}",
            args.len()
        );
        let x = args[0].f32()?;
        want(x, &[s, d], "chunk x")?;
        let norm1 = args[1].f32()?;
        let wq = args[2].f32()?;
        let wk = args[3].f32()?;
        let wv = args[4].f32()?;
        let wo = args[5].f32()?;
        let norm2 = args[6].f32()?;
        let w_ff1 = args[7].f32()?;
        let w_ff2 = args[8].f32()?;
        want(norm1, &[d], "norm1")?;
        want(wq, &[d, d], "wq")?;
        want(w_ff1, &[d, ff], "w_ff1")?;
        want(w_ff2, &[ff, d], "w_ff2")?;
        let kc = args[9].view()?;
        let vc = args[10].view()?;
        anyhow::ensure!(
            kc.shape.len() == 3 && kc.shape[0] == h && kc.shape[2] == dd,
            "chunk k_hist: expected (H, C, D), got {:?}",
            kc.shape
        );
        let cap = kc.shape[1];
        want_view(&vc, &[h, cap, dd], "chunk v_hist")?;
        let meta = args[11].i32()?;
        anyhow::ensure!(meta.len() == 3, "chunk meta must be [base, valid, total_bucket]");
        let (base, valid, total) = (meta[0] as usize, meta[1] as usize, meta[2] as usize);
        anyhow::ensure!((1..=s).contains(&valid), "chunk valid {valid} out of range 1..={s}");
        anyhow::ensure!(base <= cap, "history length {base} exceeds staged capacity {cap}");
        anyhow::ensure!(
            base + valid <= total,
            "chunk rows {base}+{valid} exceed total bucket {total}"
        );
        self.prefill_impl(
            mode,
            s,
            x,
            [norm1, wq, wk, wv, wo, norm2, w_ff1, w_ff2],
            valid,
            Some((kc, vc)),
            base,
            total,
        )
    }

    /// Shared prefill math for the empty-history monolithic layers and
    /// the history-aware chunk layers. `base` is the chunk's absolute
    /// start position (== the staged history length), `total` the
    /// request-level monolithic bucket; the monolithic path calls with
    /// `base == 0`, no history and `total == s`. Every per-row
    /// computation — RMSNorm, the matmul accumulation order, RoPE at
    /// absolute positions, ascending-absolute-j attention through
    /// [`attend_hist`] — is independent of how the prompt was split, so
    /// chunked output is bit-identical to monolithic output.
    #[allow(clippy::too_many_arguments)]
    fn prefill_impl(
        &self,
        mode: Mode,
        s: usize,
        x: &HostTensor,
        w: [&HostTensor; 8],
        valid: usize,
        hist: Option<(super::TensorView<'_>, super::TensorView<'_>)>,
        base: usize,
        total: usize,
    ) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
        let [norm1, wq, wk, wv, wo, norm2, w_ff1, w_ff2] = w;
        let (hist_k, hist_v, hist_cap) = match &hist {
            Some((k, v)) => (k.data, v.data, k.shape[1]),
            None => (&[][..], &[][..], 0usize),
        };
        let nt = self.threads;

        let eps = m.rms_eps as f32;
        let xn = rms_norm_rows(&x.data, &norm1.data, valid, d, eps);
        let q = matmul_mt(&xn, &wq.data, valid, d, d, nt);
        let k = matmul_mt(&xn, &wk.data, valid, d, d, nt);
        let v = matmul_mt(&xn, &wv.data, valid, d, d, nt);

        // (valid, d) -> per-head (H, S, D) with a zero tail, RoPE on q
        // and k at absolute positions base..base+valid.
        let mut qh = to_heads_padded(&q, valid, s, h, dd);
        let mut kh = to_heads_padded(&k, valid, s, h, dd);
        let vh = to_heads_padded(&v, valid, s, h, dd);
        for hh in 0..h {
            for t in 0..valid {
                let o = (hh * s + t) * dd;
                rope_in_place(&mut qh[o..o + dd], base + t, m.rope_theta);
                rope_in_place(&mut kh[o..o + dd], base + t, m.rope_theta);
            }
        }

        // XAttention selects kv blocks once per layer from the roped
        // q/k (head-summed antidiagonal scores, ref.py xattn_block_mask)
        // — scored over history + chunk so retrieval reaches any prefix
        // block, with the threshold row width fixed by `total`.
        let xa_sel = if mode == Mode::Xa {
            Some(self.xa_selected_blocks(&qh, &kh, s, valid, base, total, hist_k, hist_cap)?)
        } else {
            None
        };

        let sp = &self.cfg.sparsity;
        let (sink, local, last_q) = (sp.sink_size, sp.local_size, sp.triangle_last_q);
        let block = sp.block_size;
        let nb_total = if block > 0 { total / block } else { 0 };

        // per-row kv index sets over ABSOLUTE positions, computed once
        // and shared by all heads
        let mut js_all: Vec<Vec<usize>> = Vec::with_capacity(valid);
        let mut attn_pairs = 0usize;
        for t in 0..valid {
            let i = base + t;
            let mut js: Vec<usize> = Vec::new();
            match mode {
                Mode::Fa => js.extend(0..=i),
                Mode::Ssa => js.extend((0..=i).filter(|&j| j < sink || i - j < local)),
                Mode::Ta => {
                    if i + last_q >= total {
                        js.extend(0..=i); // dense last-q rows
                    } else {
                        js.extend((0..=i).filter(|&j| j < sink || i - j < local));
                    }
                }
                Mode::Xa => {
                    let sel = xa_sel.as_ref().unwrap();
                    js.extend((0..=i).filter(|&j| sel[(t / block) * nb_total + j / block]));
                }
            }
            attn_pairs += js.len();
            js_all.push(js);
        }

        // attention, parallel over heads (disjoint ctx slices; each head
        // runs the identical serial row loop -> bit-identical results);
        // absolute kv index j < base reads the staged history views,
        // j >= base the chunk's own roped k/v
        let mut ctx = vec![0f32; h * s * dd];
        let attn_threads = par_threads(nt, h, attn_pairs * h * dd);
        par_rows(attn_threads, &mut ctx, h, s * dd, |hh, ctx_h| {
            let cur = hh * s * dd;
            let (hk, hv) = if hist_cap > 0 {
                (
                    &hist_k[hh * hist_cap * dd..(hh + 1) * hist_cap * dd],
                    &hist_v[hh * hist_cap * dd..(hh + 1) * hist_cap * dd],
                )
            } else {
                (&[][..], &[][..])
            };
            for t in 0..valid {
                attend_hist(
                    &qh[cur + t * dd..cur + (t + 1) * dd],
                    hk,
                    hv,
                    &kh[cur..cur + s * dd],
                    &vh[cur..cur + s * dd],
                    base,
                    dd,
                    &js_all[t],
                    &mut ctx_h[t * dd..(t + 1) * dd],
                );
            }
        });

        // merge heads back to (S, d), then residual attn output + MLP —
        // only the valid rows; padded output rows stay zero
        let mut merged = vec![0f32; s * d];
        for t in 0..valid {
            for hh in 0..h {
                let src = (hh * s + t) * dd;
                let dst = t * d + hh * dd;
                merged[dst..dst + dd].copy_from_slice(&ctx[src..src + dd]);
            }
        }
        let attn_out = matmul_mt(&merged[..valid * d], &wo.data, valid, d, d, nt);
        let mut x2 = vec![0f32; s * d];
        for i in 0..valid * d {
            x2[i] = x.data[i] + attn_out[i];
        }
        let xn2 = rms_norm_rows(&x2, &norm2.data, valid, d, eps);
        let mut mid = matmul_mt(&xn2, &w_ff1.data, valid, d, ff, nt);
        for v in mid.iter_mut() {
            *v = gelu(*v);
        }
        let ffo = matmul_mt(&mid, &w_ff2.data, valid, ff, d, nt);
        for i in 0..valid * d {
            x2[i] += ffo[i];
        }

        Ok(vec![
            HostTensor::new(vec![s, d], x2),
            HostTensor::new(vec![h, s, dd], kh),
            HostTensor::new(vec![h, s, dd], vh),
        ])
    }

    /// XAttention block selection (ref.py `xattn_block_mask`): score
    /// every causal (q-block, kv-block) pair by strided antidiagonal
    /// |q.k| probes summed over heads; keep the per-row top-`keep`
    /// blocks plus the structural sink / local / diagonal blocks.
    ///
    /// Generalized over a staged history prefix: q rows come from the
    /// current chunk (`s` rows starting at absolute position `base`),
    /// kv rows from history (`j < base`, the `hist_k` views) or the
    /// chunk itself. `total` fixes the threshold row width (`nb_total`)
    /// so per-row top-`keep` selection matches the monolithic
    /// computation exactly; only row blocks holding valid rows are
    /// scored (rows past `valid` never consult the selection).
    #[allow(clippy::too_many_arguments)]
    fn xa_selected_blocks(
        &self,
        qh: &[f32],
        kh: &[f32],
        s: usize,
        valid: usize,
        base: usize,
        total: usize,
        hist_k: &[f32],
        hist_cap: usize,
    ) -> Result<Vec<bool>> {
        let sp = &self.cfg.sparsity;
        let (h, dd) = (self.cfg.model.n_heads, self.cfg.model.head_dim);
        let block = sp.block_size;
        anyhow::ensure!(s % block == 0, "bucket {s} not divisible by block {block}");
        anyhow::ensure!(base % block == 0, "chunk base {base} not divisible by block {block}");
        anyhow::ensure!(total % block == 0, "total bucket {total} not divisible by block {block}");
        let nb_total = total / block;
        let ncb = s / block;
        let b0 = base / block;
        // only row blocks containing valid rows need a selection — this
        // also keeps bi < nb_total when a short last chunk's bucket
        // overhangs the total bucket
        let ncb_used = ncb.min(valid.div_ceil(block));
        let scale = 1.0 / (dd as f32).sqrt();
        let stride = sp.xattn_stride.max(1);

        let mut scores = vec![0f32; ncb * nb_total];
        for hh in 0..h {
            let qbase = hh * s * dd;
            for rb in 0..ncb_used {
                let bi = b0 + rb;
                for bj in 0..=bi {
                    let mut acc = 0f32;
                    let mut r = 0usize;
                    while r < block {
                        let c = block - 1 - r; // (block-1-r) % block for r < block
                        let qrow = &qh[qbase + (rb * block + r) * dd..][..dd];
                        let j = bj * block + c; // absolute kv row
                        let krow = if j < base {
                            &hist_k[(hh * hist_cap + j) * dd..][..dd]
                        } else {
                            &kh[qbase + (j - base) * dd..][..dd]
                        };
                        let mut dot = 0f32;
                        for t in 0..dd {
                            dot += qrow[t] * krow[t];
                        }
                        acc += (dot * scale).abs();
                        r += stride;
                    }
                    scores[rb * nb_total + bj] += acc;
                }
            }
        }
        const NEG_INF: f32 = -1e30;

        let keep = ((nb_total as f64 * sp.xattn_keep_ratio) as usize).max(1);
        let sink_blocks = (sp.sink_size / block).max(1);
        let local_blocks = (sp.local_size / block).max(1);
        let mut sel = vec![false; ncb * nb_total];
        for rb in 0..ncb_used {
            let bi = b0 + rb;
            let mut row: Vec<f32> = (0..nb_total)
                .map(|bj| if bj <= bi { scores[rb * nb_total + bj] } else { NEG_INF })
                .collect();
            row.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let thresh = row[nb_total - keep];
            for bj in 0..=bi {
                let structural = bj < sink_blocks || (bi - bj) < local_blocks;
                sel[rb * nb_total + bj] = structural || scores[rb * nb_total + bj] >= thresh;
            }
        }
        Ok(sel)
    }

    /// Decode stage 1: project + RoPE the current token.
    /// Args: x (d,), pos (1,) i32, norm1 (d), wq/wk/wv (d,d).
    /// Returns q, k, v each (H, D).
    fn decode_qkv(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, h, dd) = (m.d_model, m.n_heads, m.head_dim);
        anyhow::ensure!(args.len() == 6, "decode_qkv expects 6 args, got {}", args.len());
        let x = args[0].f32()?;
        want(x, &[d], "decode x")?;
        let pos_arr = args[1].i32()?;
        anyhow::ensure!(pos_arr.len() == 1, "pos must be a single i32");
        let pos = pos_arr[0] as usize;
        let norm1 = args[2].f32()?;
        let wq = args[3].f32()?;
        let wk = args[4].f32()?;
        let wv = args[5].f32()?;
        want(wq, &[d, d], "wq")?;

        let xn = rms_norm_rows(&x.data, &norm1.data, 1, d, m.rms_eps as f32);
        let mut q = matmul_mt(&xn, &wq.data, 1, d, d, self.threads);
        let mut k = matmul_mt(&xn, &wk.data, 1, d, d, self.threads);
        let v = matmul_mt(&xn, &wv.data, 1, d, d, self.threads);
        // (d,) reinterpreted as (H, D) is the same contiguous buffer
        for hh in 0..h {
            rope_in_place(&mut q[hh * dd..(hh + 1) * dd], pos, m.rope_theta);
            rope_in_place(&mut k[hh * dd..(hh + 1) * dd], pos, m.rope_theta);
        }
        Ok(vec![
            HostTensor::new(vec![h, dd], q),
            HostTensor::new(vec![h, dd], k),
            HostTensor::new(vec![h, dd], v),
        ])
    }

    /// Decode stage 2: attend over the cache (which already contains the
    /// current token) and finish the layer.
    /// Args: x (d,), q (H,D), k_cache (H,K,D), v_cache (H,K,D),
    /// valid (1,) i32, wo (d,d), norm2 (d), w_ff1 (d,ff), w_ff2 (ff,d).
    ///
    /// The k/v cache arguments accept borrowed views (`Arg::F32View`) —
    /// the zero-copy decode fast path reads straight out of the KV
    /// cache's internal buffers.
    fn decode_attend(&self, kbuf: usize, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
        anyhow::ensure!(args.len() == 9, "decode_attend expects 9 args, got {}", args.len());
        let x = args[0].f32()?;
        want(x, &[d], "decode x")?;
        let q = args[1].f32()?;
        want(q, &[h, dd], "decode q")?;
        let kc = args[2].view()?;
        let vc = args[3].view()?;
        want_view(&kc, &[h, kbuf, dd], "k cache")?;
        want_view(&vc, &[h, kbuf, dd], "v cache")?;
        let valid_arr = args[4].i32()?;
        anyhow::ensure!(valid_arr.len() == 1, "valid_len must be a single i32");
        let valid = valid_arr[0] as usize;
        anyhow::ensure!((1..=kbuf).contains(&valid), "valid {valid} out of range 1..={kbuf}");
        let wo = args[5].f32()?;
        let norm2 = args[6].f32()?;
        let w_ff1 = args[7].f32()?;
        let w_ff2 = args[8].f32()?;

        let js: Vec<usize> = (0..valid).collect();
        let mut ctx = vec![0f32; d];
        let (q_data, kc_data, vc_data) = (&q.data, kc.data, vc.data);
        par_rows(par_threads(self.threads, h, h * valid * dd), &mut ctx, h, dd, |hh, out| {
            let base = hh * kbuf * dd;
            attend_one(
                &q_data[hh * dd..(hh + 1) * dd],
                &kc_data[base..base + kbuf * dd],
                &vc_data[base..base + kbuf * dd],
                dd,
                &js,
                out,
            );
        });
        let eps = m.rms_eps as f32;
        let attn_out = matmul_mt(&ctx, &wo.data, 1, d, d, self.threads);
        let mut x2: Vec<f32> = x.data.iter().zip(&attn_out).map(|(a, b)| a + b).collect();
        let xn2 = rms_norm_rows(&x2, &norm2.data, 1, d, eps);
        let mut mid = matmul_mt(&xn2, &w_ff1.data, 1, d, ff, self.threads);
        for v in mid.iter_mut() {
            *v = gelu(*v);
        }
        let ffo = matmul_mt(&mid, &w_ff2.data, 1, ff, d, self.threads);
        for (a, b) in x2.iter_mut().zip(&ffo) {
            *a += b;
        }
        Ok(vec![HostTensor::new(vec![d], x2)])
    }

    /// Batched decode stage 1 over `B` requests (DESIGN.md §9).
    /// Args: x (B,d), pos (B,) i32, norm1 (d), wq/wk/wv (d,d).
    /// Returns q, k, v each (B, H, D). Row `b` is bit-identical to
    /// `decode_qkv` over request `b` alone: RMSNorm, the per-output-
    /// element matmul accumulation order and RoPE are all per-row.
    fn decode_qkv_batch(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, h, dd) = (m.d_model, m.n_heads, m.head_dim);
        anyhow::ensure!(args.len() == 6, "decode_qkv_batch expects 6 args, got {}", args.len());
        let x = args[0].f32()?;
        anyhow::ensure!(
            x.shape.len() == 2 && x.shape[1] == d && x.shape[0] >= 1,
            "decode_qkv_batch x: expected (B, {d}), got {:?}",
            x.shape
        );
        let bb = x.shape[0];
        let pos = args[1].i32()?;
        anyhow::ensure!(pos.len() == bb, "pos must carry one entry per batch row");
        let norm1 = args[2].f32()?;
        let wq = args[3].f32()?;
        let wk = args[4].f32()?;
        let wv = args[5].f32()?;
        want(wq, &[d, d], "wq")?;
        let nt = self.threads;

        let xn = rms_norm_rows(&x.data, &norm1.data, bb, d, m.rms_eps as f32);
        let mut q = matmul_mt(&xn, &wq.data, bb, d, d, nt);
        let mut k = matmul_mt(&xn, &wk.data, bb, d, d, nt);
        let v = matmul_mt(&xn, &wv.data, bb, d, d, nt);
        // row b reinterpreted as (H, D) is the same contiguous buffer
        for (b, &p) in pos.iter().enumerate() {
            for hh in 0..h {
                let o = b * d + hh * dd;
                rope_in_place(&mut q[o..o + dd], p as usize, m.rope_theta);
                rope_in_place(&mut k[o..o + dd], p as usize, m.rope_theta);
            }
        }
        Ok(vec![
            HostTensor::new(vec![bb, h, dd], q),
            HostTensor::new(vec![bb, h, dd], k),
            HostTensor::new(vec![bb, h, dd], v),
        ])
    }

    /// Batched decode stage 2 over one same-mode request group — the
    /// paper's contiguous (layer, mode) bucketing (DESIGN.md §9).
    /// Args: x (B,d), q (B,H,D), valid (B,) i32, wo (d,d), norm2 (d),
    /// w_ff1 (d,ff), w_ff2 (ff,d), then one (k_cache, v_cache) pair per
    /// request, each (H, K_b, D) — owned or borrowed views, and K_b may
    /// differ per request (FA requests at different cache depths share
    /// one call; SA requests all use the ring's SA_BUF).
    /// Returns x_out (B,d). Attention parallelizes over the
    /// (request, head) product; every output row keeps the serial
    /// accumulation order, so row `b` is bit-identical to
    /// `decode_attend_{fa_K,sa}` over request `b` alone.
    fn attend_batch(&self, sparse: bool, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
        anyhow::ensure!(
            args.len() >= 9 && (args.len() - 7) % 2 == 0,
            "attend_batch expects 7 shared args + per-request (k, v) pairs, got {}",
            args.len()
        );
        let bb = (args.len() - 7) / 2;
        let x = args[0].f32()?;
        want(x, &[bb, d], "attend_batch x")?;
        let q = args[1].f32()?;
        want(q, &[bb, h, dd], "attend_batch q")?;
        let valid_arr = args[2].i32()?;
        anyhow::ensure!(valid_arr.len() == bb, "valid must carry one entry per batch row");
        let wo = args[3].f32()?;
        let norm2 = args[4].f32()?;
        let w_ff1 = args[5].f32()?;
        let w_ff2 = args[6].f32()?;
        want(wo, &[d, d], "wo")?;
        want(w_ff1, &[d, ff], "w_ff1")?;
        want(w_ff2, &[ff, d], "w_ff2")?;

        // per-request caches: bucket sizes ride on the argument shapes
        let mut caches = Vec::with_capacity(bb);
        let mut max_valid = 0usize;
        let mut attn_pairs = 0usize;
        for bi in 0..bb {
            let kc = args[7 + 2 * bi].view()?;
            let vc = args[8 + 2 * bi].view()?;
            anyhow::ensure!(
                kc.shape.len() == 3 && kc.shape[0] == h && kc.shape[2] == dd,
                "attend_batch k cache {bi}: expected (H, K, D), got {:?}",
                kc.shape
            );
            let kbuf = kc.shape[1];
            if sparse {
                anyhow::ensure!(
                    kbuf == self.cfg.sa_buf,
                    "sparse cache {bi}: buffer {kbuf} != SA_BUF {}",
                    self.cfg.sa_buf
                );
            } else {
                anyhow::ensure!(
                    self.cfg.decode_kv_buckets.contains(&kbuf),
                    "decode bucket {kbuf} not in config buckets {:?}",
                    self.cfg.decode_kv_buckets
                );
            }
            want_view(&vc, &[h, kbuf, dd], "attend_batch v cache")?;
            let valid = valid_arr[bi] as usize;
            anyhow::ensure!((1..=kbuf).contains(&valid), "valid {valid} out of range 1..={kbuf}");
            max_valid = max_valid.max(valid);
            attn_pairs += valid;
            caches.push((kc, vc, kbuf, valid));
        }

        // attention over the (request, head) product: B*H disjoint
        // output rows instead of a single request's H — far better
        // worker utilization at small H, still bit-identical
        let js_all: Vec<usize> = (0..max_valid).collect();
        let mut ctx = vec![0f32; bb * d];
        let rows = bb * h;
        let q_data = &q.data;
        par_rows(
            par_threads(self.threads, rows, attn_pairs * h * dd),
            &mut ctx,
            rows,
            dd,
            |r, out| {
                let (bi, hh) = (r / h, r % h);
                let (kc, vc, kbuf, valid) = caches[bi];
                let base = hh * kbuf * dd;
                attend_one(
                    &q_data[r * dd..(r + 1) * dd],
                    &kc.data[base..base + kbuf * dd],
                    &vc.data[base..base + kbuf * dd],
                    dd,
                    &js_all[..valid],
                    out,
                );
            },
        );

        // row r = bi*H + hh lands at ctx[bi*d + hh*D] — already the
        // merged (B, d) layout the serial path builds per request
        let eps = m.rms_eps as f32;
        let nt = self.threads;
        let attn_out = matmul_mt(&ctx, &wo.data, bb, d, d, nt);
        let mut x2: Vec<f32> = x.data.iter().zip(&attn_out).map(|(a, b)| a + b).collect();
        let xn2 = rms_norm_rows(&x2, &norm2.data, bb, d, eps);
        let mut mid = matmul_mt(&xn2, &w_ff1.data, bb, d, ff, nt);
        for v in mid.iter_mut() {
            *v = gelu(*v);
        }
        let ffo = matmul_mt(&mid, &w_ff2.data, bb, ff, d, nt);
        for (a, b) in x2.iter_mut().zip(&ffo) {
            *a += b;
        }
        Ok(vec![HostTensor::new(vec![bb, d], x2)])
    }

    /// Final norm + vocabulary projection for a whole decode round:
    /// x (B,d) -> logits (B,V) in one (B,d)×(d,V) matmul. Each row is
    /// bit-identical to a per-request `lm_head` call.
    fn lm_head_batch(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, v) = (m.d_model, m.vocab_size);
        anyhow::ensure!(args.len() == 3, "lm_head_batch expects 3 args, got {}", args.len());
        let x = args[0].view()?;
        anyhow::ensure!(
            x.shape.len() == 2 && x.shape[1] == d && x.shape[0] >= 1,
            "lm_head_batch x: expected (B, {d}), got {:?}",
            x.shape
        );
        let bb = x.shape[0];
        let norm_f = args[1].f32()?;
        let w = args[2].f32()?;
        want(norm_f, &[d], "norm_f")?;
        want(w, &[d, v], "lm_head weight")?;
        let xn = rms_norm_rows(x.data, &norm_f.data, bb, d, m.rms_eps as f32);
        let logits = matmul_mt(&xn, &w.data, bb, d, v, self.threads);
        Ok(vec![HostTensor::new(vec![bb, v], logits)])
    }

    /// Layer-Router MLP: desc (2d,) -> logits (2,) in [SA, FA] order.
    fn router_mlp(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let d2 = 2 * self.cfg.model.d_model;
        anyhow::ensure!(args.len() == 5, "router expects 5 args, got {}", args.len());
        let desc = args[0].f32()?;
        want(desc, &[d2], "router descriptor")?;
        let w1 = args[1].f32()?;
        let b1 = args[2].f32()?;
        let w2 = args[3].f32()?;
        let b2 = args[4].f32()?;
        anyhow::ensure!(w1.shape.len() == 2 && w1.shape[0] == d2, "router w1 shape");
        let rh = w1.shape[1];
        want(b1, &[rh], "router b1")?;
        want(w2, &[rh, 2], "router w2")?;
        want(b2, &[2], "router b2")?;

        let mut h1 = matmul(&desc.data, &w1.data, 1, d2, rh);
        for (a, b) in h1.iter_mut().zip(&b1.data) {
            *a = gelu(*a + b);
        }
        let mut logits = matmul(&h1, &w2.data, 1, rh, 2);
        for (a, b) in logits.iter_mut().zip(&b2.data) {
            *a += b;
        }
        Ok(vec![HostTensor::new(vec![2], logits)])
    }

    /// Final norm + vocabulary projection for one token.
    /// Args: x (d,), norm_f (d,), lm_head (d, V). `x` accepts a borrowed
    /// view (the prefill path hands over a slice of its hidden state
    /// instead of materializing the last row).
    fn lm_head(&self, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = &self.cfg.model;
        let (d, v) = (m.d_model, m.vocab_size);
        anyhow::ensure!(args.len() == 3, "lm_head expects 3 args, got {}", args.len());
        let x = args[0].view()?;
        want_view(&x, &[d], "lm_head x")?;
        let norm_f = args[1].f32()?;
        let w = args[2].f32()?;
        want(norm_f, &[d], "norm_f")?;
        want(w, &[d, v], "lm_head weight")?;
        let xn = rms_norm_rows(x.data, &norm_f.data, 1, d, m.rms_eps as f32);
        let logits = matmul_mt(&xn, &w.data, 1, d, v, self.threads);
        Ok(vec![HostTensor::new(vec![v], logits)])
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn load(&mut self, exe: &str) -> Result<()> {
        self.parse_exe(exe)?; // name + config validation
        self.loaded.insert(exe.to_string());
        Ok(())
    }

    fn is_loaded(&self, exe: &str) -> bool {
        self.loaded.contains(exe)
    }

    fn run(&mut self, exe: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        anyhow::ensure!(self.loaded.contains(exe), "executable {exe} not loaded");
        let t0 = Instant::now();
        let out = self.dispatch(exe, args)?;
        let st = self.stats.entry(exe.to_string()).or_default();
        st.calls += 1;
        st.total_us += t0.elapsed().as_micros() as u64;
        Ok(out)
    }

    fn stats(&self) -> &HashMap<String, ExeStats> {
        &self.stats
    }

    fn reset_stats(&mut self) {
        self.stats.clear();
    }

    fn note_kv_transfer(&mut self, exe: &str, bytes_moved: u64, bytes_borrowed: u64) {
        let st = self.stats.entry(exe.to_string()).or_default();
        st.kv_bytes_moved += bytes_moved;
        st.kv_bytes_borrowed += bytes_borrowed;
    }

    fn note_prefill_rows(&mut self, exe: &str, rows_valid: u64, rows_padded: u64) {
        let st = self.stats.entry(exe.to_string()).or_default();
        st.rows_valid += rows_valid;
        st.rows_padded += rows_padded;
    }

    fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
    }

    fn accepts_prefill_valid_arg(&self) -> bool {
        true
    }

    fn accepts_decode_batch(&self) -> bool {
        true
    }

    fn accepts_prefill_chunks(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// numeric helpers — fixed summation order is the determinism/parity
// contract: prefill rows and decode steps share these exact routines
// ---------------------------------------------------------------------------

fn want(t: &HostTensor, shape: &[usize], what: &str) -> Result<()> {
    anyhow::ensure!(
        t.shape.as_slice() == shape,
        "{what}: expected shape {shape:?}, got {:?}",
        t.shape
    );
    Ok(())
}

fn want_view(t: &super::TensorView, shape: &[usize], what: &str) -> Result<()> {
    anyhow::ensure!(
        t.shape == shape,
        "{what}: expected shape {shape:?}, got {:?}",
        t.shape
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// deterministic parallelism substrate: work splits over DISJOINT output
// rows / column stripes only; every row keeps the serial accumulation
// order, so any worker count produces bit-identical results
// ---------------------------------------------------------------------------

/// Minimum per-kernel work (multiply-accumulates) before scoped worker
/// threads pay for their spawn cost (~tens of µs per scope).
const PAR_MIN_WORK: usize = 1 << 17;

/// Worker count for a kernel of `work` multiply-accumulates over `rows`
/// independent rows. Never affects results, only wall-clock.
fn par_threads(threads: usize, rows: usize, work: usize) -> usize {
    if threads <= 1 || work < PAR_MIN_WORK {
        1
    } else {
        threads.min(rows).max(1)
    }
}

/// Run `f(row, out_row)` over the `rows` leading rows of `out` (each
/// `row_size` long), rows partitioned contiguously across `threads`
/// scoped workers. Exactly one worker produces each row with the same
/// per-row code as the serial path — bit-identical for every `threads`.
fn par_rows(
    threads: usize,
    out: &mut [f32],
    rows: usize,
    row_size: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    let out = &mut out[..rows * row_size];
    if threads <= 1 || rows <= 1 {
        for (r, row) in out.chunks_mut(row_size).enumerate() {
            f(r, row);
        }
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ci, chunk) in out.chunks_mut(per * row_size).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, row) in chunk.chunks_mut(row_size).enumerate() {
                    f(ci * per + j, row);
                }
            });
        }
    });
}

/// `x (rows, din) @ w (din, dout)` with `threads` workers, bit-identical
/// to [`matmul`] for every thread count (per output element the din-
/// ascending accumulation order is preserved). Multi-row inputs split
/// by output row; single-row inputs — the decode hot path's `lm_head`
/// (d × V) and FF pair — use a blocked column-stripe microkernel where
/// each worker streams its contiguous stripe of every `w` row.
fn matmul_mt(x: &[f32], w: &[f32], rows: usize, din: usize, dout: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * din);
    debug_assert_eq!(w.len(), din * dout);
    let nt = par_threads(threads, if rows > 1 { rows } else { dout }, rows * din * dout);
    if nt <= 1 {
        return matmul(x, w, rows, din, dout);
    }
    let mut out = vec![0f32; rows * dout];
    if rows > 1 {
        par_rows(nt, &mut out, rows, dout, |r, or| {
            let xr = &x[r * din..(r + 1) * din];
            for i in 0..din {
                let xv = xr[i];
                let wr = &w[i * dout..(i + 1) * dout];
                for (o, wv) in or.iter_mut().zip(wr) {
                    *o += xv * *wv;
                }
            }
        });
    } else {
        let per = dout.div_ceil(nt);
        std::thread::scope(|scope| {
            for (ci, oc) in out.chunks_mut(per).enumerate() {
                let c0 = ci * per;
                scope.spawn(move || {
                    for i in 0..din {
                        let xv = x[i];
                        let wr = &w[i * dout + c0..i * dout + c0 + oc.len()];
                        for (o, wv) in oc.iter_mut().zip(wr) {
                            *o += xv * *wv;
                        }
                    }
                });
            }
        });
    }
    out
}

/// Row-wise RMSNorm: `x * rsqrt(mean(x^2) + eps) * scale`.
fn rms_norm_rows(x: &[f32], scale: &[f32], rows: usize, d: usize, eps: f32) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut ss = 0f32;
        for &v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + eps).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for i in 0..d {
            or[i] = xr[i] * inv * scale[i];
        }
    }
    out
}

/// `x (rows, din) @ w (din, dout)`, accumulating over `din` in index
/// order (row-major w keeps the inner loop contiguous).
fn matmul(x: &[f32], w: &[f32], rows: usize, din: usize, dout: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * din);
    debug_assert_eq!(w.len(), din * dout);
    let mut out = vec![0f32; rows * dout];
    for r in 0..rows {
        let xr = &x[r * din..(r + 1) * din];
        let or = &mut out[r * dout..(r + 1) * dout];
        for i in 0..din {
            let xv = xr[i];
            let wr = &w[i * dout..(i + 1) * dout];
            for o in 0..dout {
                or[o] += xv * wr[o];
            }
        }
    }
    out
}

/// tanh-approximated GELU (jax.nn.gelu default).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Rotate adjacent pairs of one head vector by position `pos`
/// (model.py `apply_rope`: pair (2i, 2i+1), angle pos / theta^(2i/D)).
fn rope_in_place(v: &mut [f32], pos: usize, theta: f64) {
    let dd = v.len();
    let half = dd / 2;
    for i in 0..half {
        let inv = (1.0 / theta.powf((2 * i) as f64 / dd as f64)) as f32;
        let ang = pos as f32 * inv;
        let (sin, cos) = ang.sin_cos();
        let x1 = v[2 * i];
        let x2 = v[2 * i + 1];
        v[2 * i] = x1 * cos - x2 * sin;
        v[2 * i + 1] = x1 * sin + x2 * cos;
    }
}

/// `(valid, d)` row-major to `(H, S, D)` per-head layout; rows
/// `valid..s` (bucket padding) stay zero.
fn to_heads_padded(x: &[f32], valid: usize, s: usize, h: usize, dd: usize) -> Vec<f32> {
    debug_assert!(valid <= s);
    let d = h * dd;
    let mut out = vec![0f32; h * s * dd];
    for t in 0..valid {
        for hh in 0..h {
            let src = t * d + hh * dd;
            let dst = (hh * s + t) * dd;
            out[dst..dst + dd].copy_from_slice(&x[src..src + dd]);
        }
    }
    out
}

/// Softmax-attend one query over the keys listed in `js` (ascending
/// indices into the `(K, D)` per-head k/v slices). Shared verbatim by
/// prefill rows and decode steps — the teacher-forcing parity anchor.
fn attend_one(q: &[f32], k: &[f32], v: &[f32], dd: usize, js: &[usize], out: &mut [f32]) {
    attend_hist(q, &[], &[], k, v, 0, dd, js, out);
}

/// The general two-segment form of [`attend_one`]: `js` holds ascending
/// ABSOLUTE indices; `j < split` reads row `j` of the staged-history
/// per-head slices, `j >= split` row `j - split` of the current chunk's
/// slices. The floating-point op sequence depends only on `js` and the
/// row values — never on which segment a row lives in — so the chunked
/// prefill path (`split > 0`) is bit-identical to attending over the
/// virtual concatenation, which is what the monolithic path computes.
#[allow(clippy::too_many_arguments)]
fn attend_hist(
    q: &[f32],
    k_hist: &[f32],
    v_hist: &[f32],
    k_cur: &[f32],
    v_cur: &[f32],
    split: usize,
    dd: usize,
    js: &[usize],
    out: &mut [f32],
) {
    debug_assert!(!js.is_empty());
    let scale = 1.0 / (dd as f32).sqrt();
    let mut scores = Vec::with_capacity(js.len());
    let mut maxv = f32::NEG_INFINITY;
    for &j in js {
        let kr = if j < split {
            &k_hist[j * dd..(j + 1) * dd]
        } else {
            &k_cur[(j - split) * dd..(j - split + 1) * dd]
        };
        let mut dot = 0f32;
        for t in 0..dd {
            dot += q[t] * kr[t];
        }
        let sc = dot * scale;
        if sc > maxv {
            maxv = sc;
        }
        scores.push(sc);
    }
    let mut denom = 0f32;
    for sc in scores.iter_mut() {
        *sc = (*sc - maxv).exp();
        denom += *sc;
    }
    out.fill(0.0);
    for (idx, &j) in js.iter().enumerate() {
        let w = scores[idx];
        let vr = if j < split {
            &v_hist[j * dd..(j + 1) * dd]
        } else {
            &v_cur[(j - split) * dd..(j - split + 1) * dd]
        };
        for t in 0..dd {
            out[t] += w * vr[t];
        }
    }
    let inv = 1.0 / denom;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synthetic::DEFAULT_META;
    use std::path::PathBuf;

    fn backend() -> RefBackend {
        let cfg = MetaConfig::from_json_str(DEFAULT_META, PathBuf::from("/tmp")).unwrap();
        RefBackend::new(cfg)
    }

    #[test]
    fn exe_name_parsing() {
        let b = backend();
        assert!(matches!(
            b.parse_exe("layer_fa_prefill_128").unwrap(),
            ExeKind::Prefill { mode: Mode::Fa, bucket: 128 }
        ));
        assert!(matches!(
            b.parse_exe("layer_xa_prefill_512").unwrap(),
            ExeKind::Prefill { mode: Mode::Xa, bucket: 512 }
        ));
        assert!(matches!(b.parse_exe("decode_qkv").unwrap(), ExeKind::DecodeQkv));
        assert!(matches!(
            b.parse_exe("decode_attend_fa_256").unwrap(),
            ExeKind::DecodeAttend { kbuf: 256 }
        ));
        // sa buffer size comes from the config, not the name
        let sa = b.parse_exe("decode_attend_sa").unwrap();
        assert_eq!(sa, ExeKind::DecodeAttend { kbuf: b.cfg.sa_buf });
        // batched decode entry points (buckets ride on argument shapes)
        assert!(matches!(b.parse_exe("decode_qkv_batch").unwrap(), ExeKind::DecodeQkvBatch));
        assert!(matches!(
            b.parse_exe("attend_batch_fa").unwrap(),
            ExeKind::AttendBatch { sparse: false }
        ));
        assert!(matches!(
            b.parse_exe("attend_batch_sa").unwrap(),
            ExeKind::AttendBatch { sparse: true }
        ));
        assert!(matches!(b.parse_exe("lm_head_batch").unwrap(), ExeKind::LmHeadBatch));
        // chunked prefill entry points (DESIGN.md §10)
        assert!(matches!(
            b.parse_exe("layer_fa_prefill_chunk_128").unwrap(),
            ExeKind::PrefillChunk { mode: Mode::Fa, bucket: 128 }
        ));
        assert!(matches!(
            b.parse_exe("layer_xa_prefill_chunk_256").unwrap(),
            ExeKind::PrefillChunk { mode: Mode::Xa, bucket: 256 }
        ));
        assert!(b.parse_exe("layer_fa_prefill_chunk_77").is_err()); // not a bucket
        assert!(b.parse_exe("layer_fa_prefill_77").is_err()); // not a bucket
        assert!(b.parse_exe("warp_drive").is_err());
    }

    #[test]
    fn run_requires_load() {
        let mut b = backend();
        let x = HostTensor::zeros(vec![b.cfg.model.d_model]);
        let nf = HostTensor::new(vec![b.cfg.model.d_model], vec![1.0; b.cfg.model.d_model]);
        let w = HostTensor::zeros(vec![b.cfg.model.d_model, b.cfg.model.vocab_size]);
        let args = [Arg::F32(&x), Arg::F32(&nf), Arg::F32(&w)];
        assert!(b.run("lm_head", &args).is_err());
        b.load("lm_head").unwrap();
        let out = b.run("lm_head", &args).unwrap();
        assert_eq!(out[0].shape, vec![b.cfg.model.vocab_size]);
        assert_eq!(b.stats()["lm_head"].calls, 1);
    }

    #[test]
    fn attend_one_is_convex_combination() {
        // with two keys, the output must lie between the two values
        let q = [1.0f32, 0.0];
        let k = [1.0f32, 0.0, -1.0, 0.0]; // (2, 2)
        let v = [0.0f32, 0.0, 1.0, 1.0];
        let mut out = [9.0f32, 9.0];
        attend_one(&q, &k, &v, 2, &[0, 1], &mut out);
        assert!(out[0] > 0.0 && out[0] < 1.0);
        assert!((out[0] - out[1]).abs() < 1e-6);
        // single key: output equals its value exactly
        attend_one(&q, &k, &v, 2, &[1], &mut out);
        assert_eq!(out, [1.0, 1.0]);
    }

    #[test]
    fn rope_preserves_norm_and_is_position_dependent() {
        let base = [0.3f32, -0.7, 1.1, 0.2];
        let mut a = base;
        let mut b = base;
        rope_in_place(&mut a, 3, 10000.0);
        rope_in_place(&mut b, 4, 10000.0);
        let n0: f32 = base.iter().map(|x| x * x).sum();
        let na: f32 = a.iter().map(|x| x * x).sum();
        assert!((n0 - na).abs() < 1e-4, "rotation must preserve norm");
        assert!(a != b, "different positions must rotate differently");
        let mut c = base;
        rope_in_place(&mut c, 0, 10000.0);
        assert_eq!(c, base, "position 0 is the identity rotation");
    }

    #[test]
    fn rms_norm_unit_rows() {
        let d = 4;
        let x = vec![2.0f32; d];
        let scale = vec![1.0f32; d];
        let out = rms_norm_rows(&x, &scale, 1, d, 1e-5);
        // mean(x^2) = 4 -> rsqrt ~ 0.5 -> out ~ 1.0
        for &o in &out {
            assert!((o - 1.0).abs() < 1e-3);
        }
    }

    fn mk_tensor(shape: Vec<usize>, seed: u64) -> HostTensor {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let n: usize = shape.iter().product();
        HostTensor::new(shape, (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect())
    }

    #[test]
    fn multithreaded_matmul_bit_identical() {
        // covers the single-row column-stripe microkernel (above the
        // work threshold), the multi-row row split, and the small-work
        // serial fallback — all must match the serial kernel bitwise
        for &(rows, din, dout) in
            &[(1usize, 64usize, 4096usize), (1, 512, 1024), (257, 64, 96), (3, 128, 128)]
        {
            let x = mk_tensor(vec![rows, din], rows as u64 * 31 + dout as u64);
            let w = mk_tensor(vec![din, dout], din as u64 * 7 + 1);
            let base = matmul(&x.data, &w.data, rows, din, dout);
            for threads in [1usize, 2, 3, 8] {
                let got = matmul_mt(&x.data, &w.data, rows, din, dout, threads);
                assert_eq!(
                    base, got,
                    "matmul_mt diverged: rows={rows} din={din} dout={dout} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn prefill_skips_padded_tail_rows_with_parity() {
        // zero-tail input (what the engine's padded embedding produces):
        // the valid-rows path must be bit-identical to the full-bucket
        // computation on the valid rows and on all of k/v, and must zero
        // the padded output rows instead of leaving attention garbage
        let mut b = backend();
        let m = b.cfg.model.clone();
        let s = 128usize;
        let valid = 100usize;
        let d = m.d_model;
        for mode in ["fa", "ssa", "ta", "xa"] {
            let exe = format!("layer_{mode}_prefill_128");
            b.load(&exe).unwrap();
            let mut x = mk_tensor(vec![s, d], 11);
            for i in valid * d..s * d {
                x.data[i] = 0.0;
            }
            let n1 = HostTensor::new(vec![d], vec![1.0; d]);
            let wq = mk_tensor(vec![d, d], 2);
            let wk = mk_tensor(vec![d, d], 3);
            let wv = mk_tensor(vec![d, d], 4);
            let wo = mk_tensor(vec![d, d], 5);
            let n2 = n1.clone();
            let f1 = mk_tensor(vec![d, m.d_ff], 6);
            let f2 = mk_tensor(vec![m.d_ff, d], 7);
            let args9 = [
                Arg::F32(&x), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk), Arg::F32(&wv),
                Arg::F32(&wo), Arg::F32(&n2), Arg::F32(&f1), Arg::F32(&f2),
            ];
            let valid_arr = [valid as i32];
            let args10 = [
                Arg::F32(&x), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk), Arg::F32(&wv),
                Arg::F32(&wo), Arg::F32(&n2), Arg::F32(&f1), Arg::F32(&f2),
                Arg::I32(&valid_arr),
            ];
            let full = b.run(&exe, &args9).unwrap();
            let skip = b.run(&exe, &args10).unwrap();
            assert_eq!(full[1], skip[1], "{mode}: k must be bit-identical");
            assert_eq!(full[2], skip[2], "{mode}: v must be bit-identical");
            assert_eq!(
                &full[0].data[..valid * d],
                &skip[0].data[..valid * d],
                "{mode}: valid hidden rows must be bit-identical"
            );
            assert!(
                skip[0].data[valid * d..].iter().all(|&v| v == 0.0),
                "{mode}: padded output rows must be zeroed"
            );
        }
    }

    #[test]
    fn decode_attend_accepts_views_and_matches_owned_path() {
        let mut b = backend();
        let m = b.cfg.model.clone();
        let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
        let kbuf = 128usize;
        b.load("decode_attend_fa_128").unwrap();
        let x = mk_tensor(vec![d], 21);
        let q = mk_tensor(vec![h, dd], 22);
        let kc = mk_tensor(vec![h, kbuf, dd], 23);
        let vc = mk_tensor(vec![h, kbuf, dd], 24);
        let valid_arr = [57i32];
        let wo = mk_tensor(vec![d, d], 25);
        let n2 = HostTensor::new(vec![d], vec![1.0; d]);
        let f1 = mk_tensor(vec![d, ff], 26);
        let f2 = mk_tensor(vec![ff, d], 27);
        let owned = b
            .run(
                "decode_attend_fa_128",
                &[
                    Arg::F32(&x), Arg::F32(&q), Arg::F32(&kc), Arg::F32(&vc),
                    Arg::I32(&valid_arr), Arg::F32(&wo), Arg::F32(&n2),
                    Arg::F32(&f1), Arg::F32(&f2),
                ],
            )
            .unwrap();
        let viewed = b
            .run(
                "decode_attend_fa_128",
                &[
                    Arg::F32(&x), Arg::F32(&q), Arg::F32View(kc.view()), Arg::F32View(vc.view()),
                    Arg::I32(&valid_arr), Arg::F32(&wo), Arg::F32(&n2),
                    Arg::F32(&f1), Arg::F32(&f2),
                ],
            )
            .unwrap();
        assert_eq!(owned, viewed, "view-staged KV must produce byte-identical output");
        // kv transfer accounting lands in stats
        b.note_kv_transfer("decode_attend_fa_128", 0, 4096);
        b.note_kv_transfer("decode_attend_fa_128", 128, 0);
        let st = &b.stats()["decode_attend_fa_128"];
        assert_eq!(st.kv_bytes_borrowed, 4096);
        assert_eq!(st.kv_bytes_moved, 128);
    }

    #[test]
    fn ssa_prefill_is_deterministic_and_shaped() {
        let mut b = backend();
        let m = b.cfg.model.clone();
        let s = 128usize;
        b.load("layer_ssa_prefill_128").unwrap();
        let mk = |shape: Vec<usize>, seed: u64| {
            let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
            let n: usize = shape.iter().product();
            HostTensor::new(shape, (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect())
        };
        let x = mk(vec![s, m.d_model], 1);
        let n1 = HostTensor::new(vec![m.d_model], vec![1.0; m.d_model]);
        let wq = mk(vec![m.d_model, m.d_model], 2);
        let wk = mk(vec![m.d_model, m.d_model], 3);
        let wv = mk(vec![m.d_model, m.d_model], 4);
        let wo = mk(vec![m.d_model, m.d_model], 5);
        let n2 = n1.clone();
        let f1 = mk(vec![m.d_model, m.d_ff], 6);
        let f2 = mk(vec![m.d_ff, m.d_model], 7);
        let args = [
            Arg::F32(&x), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk), Arg::F32(&wv),
            Arg::F32(&wo), Arg::F32(&n2), Arg::F32(&f1), Arg::F32(&f2),
        ];
        let o1 = b.run("layer_ssa_prefill_128", &args).unwrap();
        let o2 = b.run("layer_ssa_prefill_128", &args).unwrap();
        assert_eq!(o1[0].shape, vec![s, m.d_model]);
        assert_eq!(o1[1].shape, vec![m.n_heads, s, m.head_dim]);
        assert_eq!(o1, o2, "reference kernels must be bitwise deterministic");
        assert!(o1[0].data.iter().all(|v| v.is_finite()));
    }

    /// The batched-decode determinism contract at the kernel level:
    /// every row of `decode_qkv_batch` / `attend_batch_fa` /
    /// `lm_head_batch` must be bit-identical to the per-request serial
    /// executable over that row alone — including rows at *different*
    /// KV buckets in the same attend call, and for every worker count.
    #[test]
    fn batch_kernels_rowwise_bit_identical_to_serial() {
        let cfg = MetaConfig::from_json_str(DEFAULT_META, PathBuf::from("/tmp")).unwrap();
        let m = cfg.model.clone();
        let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
        let buckets = [128usize, 256, 128]; // mixed buckets in one call
        let valids = [100usize, 200, 57];
        let bb = buckets.len();

        let norm1 = HostTensor::new(vec![d], vec![1.0; d]);
        let wq = mk_tensor(vec![d, d], 41);
        let wk = mk_tensor(vec![d, d], 42);
        let wv = mk_tensor(vec![d, d], 43);
        let wo = mk_tensor(vec![d, d], 44);
        let norm2 = norm1.clone();
        let f1 = mk_tensor(vec![d, ff], 45);
        let f2 = mk_tensor(vec![ff, d], 46);
        let norm_f = norm1.clone();
        let lm_w = mk_tensor(vec![d, m.vocab_size], 47);
        let x_all = mk_tensor(vec![bb, d], 48);
        let pos_all: Vec<i32> = vec![100, 200, 57];
        let kcs: Vec<HostTensor> =
            (0..bb).map(|i| mk_tensor(vec![h, buckets[i], dd], 50 + i as u64)).collect();
        let vcs: Vec<HostTensor> =
            (0..bb).map(|i| mk_tensor(vec![h, buckets[i], dd], 60 + i as u64)).collect();

        for threads in [1usize, 3, 8] {
            let mut b = RefBackend::with_threads(cfg.clone(), threads);
            for exe in [
                "decode_qkv", "decode_qkv_batch", "decode_attend_fa_128",
                "decode_attend_fa_256", "attend_batch_fa", "lm_head", "lm_head_batch",
            ] {
                b.load(exe).unwrap();
            }

            // --- stage 1: qkv ---
            let qkv_b = b
                .run(
                    "decode_qkv_batch",
                    &[
                        Arg::F32(&x_all), Arg::I32(&pos_all), Arg::F32(&norm1),
                        Arg::F32(&wq), Arg::F32(&wk), Arg::F32(&wv),
                    ],
                )
                .unwrap();
            for bi in 0..bb {
                let xr = HostTensor::new(vec![d], x_all.data[bi * d..(bi + 1) * d].to_vec());
                let pos = [pos_all[bi]];
                let qkv_s = b
                    .run(
                        "decode_qkv",
                        &[
                            Arg::F32(&xr), Arg::I32(&pos), Arg::F32(&norm1),
                            Arg::F32(&wq), Arg::F32(&wk), Arg::F32(&wv),
                        ],
                    )
                    .unwrap();
                for out in 0..3 {
                    assert_eq!(
                        &qkv_s[out].data[..],
                        &qkv_b[out].data[bi * d..(bi + 1) * d],
                        "qkv output {out} row {bi} diverged ({threads} workers)"
                    );
                }
            }

            // --- stage 2: attend, mixed buckets in one call ---
            let q_all = &qkv_b[0];
            let valid_all: Vec<i32> = valids.iter().map(|&v| v as i32).collect();
            let mut call: Vec<Arg> = vec![
                Arg::F32(&x_all), Arg::F32(q_all), Arg::I32(&valid_all), Arg::F32(&wo),
                Arg::F32(&norm2), Arg::F32(&f1), Arg::F32(&f2),
            ];
            for bi in 0..bb {
                call.push(Arg::F32View(kcs[bi].view()));
                call.push(Arg::F32View(vcs[bi].view()));
            }
            let batched = b.run("attend_batch_fa", &call).unwrap();
            assert_eq!(batched[0].shape, vec![bb, d]);
            for bi in 0..bb {
                let xr = HostTensor::new(vec![d], x_all.data[bi * d..(bi + 1) * d].to_vec());
                let qr = HostTensor::new(vec![h, dd], q_all.data[bi * d..(bi + 1) * d].to_vec());
                let valid = [valids[bi] as i32];
                let serial = b
                    .run(
                        &format!("decode_attend_fa_{}", buckets[bi]),
                        &[
                            Arg::F32(&xr), Arg::F32(&qr), Arg::F32(&kcs[bi]), Arg::F32(&vcs[bi]),
                            Arg::I32(&valid), Arg::F32(&wo), Arg::F32(&norm2),
                            Arg::F32(&f1), Arg::F32(&f2),
                        ],
                    )
                    .unwrap();
                assert_eq!(
                    &serial[0].data[..],
                    &batched[0].data[bi * d..(bi + 1) * d],
                    "attend row {bi} (bucket {}) diverged ({threads} workers)",
                    buckets[bi]
                );
            }

            // --- lm_head over the attend output rows ---
            let logits_b = b
                .run(
                    "lm_head_batch",
                    &[Arg::F32(&batched[0]), Arg::F32(&norm_f), Arg::F32(&lm_w)],
                )
                .unwrap();
            assert_eq!(logits_b[0].shape, vec![bb, m.vocab_size]);
            for bi in 0..bb {
                let xr =
                    HostTensor::new(vec![d], batched[0].data[bi * d..(bi + 1) * d].to_vec());
                let serial = b
                    .run("lm_head", &[Arg::F32(&xr), Arg::F32(&norm_f), Arg::F32(&lm_w)])
                    .unwrap();
                assert_eq!(
                    &serial[0].data[..],
                    &logits_b[0].data[bi * m.vocab_size..(bi + 1) * m.vocab_size],
                    "lm_head row {bi} diverged ({threads} workers)"
                );
            }
        }
    }

    /// The chunked-prefill determinism contract at the kernel level:
    /// splitting a prompt into history-aware chunk calls must reproduce
    /// the monolithic layer's outputs row for row, bit for bit — per
    /// mode, across the TA dense tail and the XA block-threshold width.
    #[test]
    fn chunked_prefill_kernel_matches_monolithic_rows() {
        let mut b = backend();
        let m = b.cfg.model.clone();
        let (d, h, dd) = (m.d_model, m.n_heads, m.head_dim);
        let total = 128usize; // monolithic bucket == chunk bucket here
        let valid = 100usize;
        let split = 64usize; // chunk boundary (multiple of block 16)
        let n1 = HostTensor::new(vec![d], vec![1.0; d]);
        let wq = mk_tensor(vec![d, d], 82);
        let wk = mk_tensor(vec![d, d], 83);
        let wv = mk_tensor(vec![d, d], 84);
        let wo = mk_tensor(vec![d, d], 85);
        let n2 = n1.clone();
        let f1 = mk_tensor(vec![d, m.d_ff], 86);
        let f2 = mk_tensor(vec![m.d_ff, d], 87);
        for mode in ["fa", "ssa", "ta", "xa"] {
            let mono_exe = format!("layer_{mode}_prefill_{total}");
            let chunk_exe = format!("layer_{mode}_prefill_chunk_{total}");
            b.load(&mono_exe).unwrap();
            b.load(&chunk_exe).unwrap();
            let mut x = mk_tensor(vec![total, d], 81);
            for i in valid * d..total * d {
                x.data[i] = 0.0;
            }
            let valid_arr = [valid as i32];
            let mono = b
                .run(
                    &mono_exe,
                    &[
                        Arg::F32(&x), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk),
                        Arg::F32(&wv), Arg::F32(&wo), Arg::F32(&n2), Arg::F32(&f1),
                        Arg::F32(&f2), Arg::I32(&valid_arr),
                    ],
                )
                .unwrap();

            // chunk 1: rows 0..split, empty history
            let mut x1 = HostTensor::zeros(vec![total, d]);
            x1.data[..split * d].copy_from_slice(&x.data[..split * d]);
            let empty = HostTensor::zeros(vec![h, 0, dd]);
            let meta1 = [0i32, split as i32, total as i32];
            let c1 = b
                .run(
                    &chunk_exe,
                    &[
                        Arg::F32(&x1), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk),
                        Arg::F32(&wv), Arg::F32(&wo), Arg::F32(&n2), Arg::F32(&f1),
                        Arg::F32(&f2), Arg::F32View(empty.view()), Arg::F32View(empty.view()),
                        Arg::I32(&meta1),
                    ],
                )
                .unwrap();

            // stage chunk 1's k/v as the history prefix (natural order)
            let mut hist_k = HostTensor::zeros(vec![h, total, dd]);
            let mut hist_v = HostTensor::zeros(vec![h, total, dd]);
            for hh in 0..h {
                let o = hh * total * dd;
                hist_k.data[o..o + split * dd].copy_from_slice(&c1[1].data[o..o + split * dd]);
                hist_v.data[o..o + split * dd].copy_from_slice(&c1[2].data[o..o + split * dd]);
            }

            // chunk 2: rows split..valid attending over the prefix
            let n2_rows = valid - split;
            let mut x2 = HostTensor::zeros(vec![total, d]);
            x2.data[..n2_rows * d].copy_from_slice(&x.data[split * d..valid * d]);
            let meta2 = [split as i32, n2_rows as i32, total as i32];
            let c2 = b
                .run(
                    &chunk_exe,
                    &[
                        Arg::F32(&x2), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk),
                        Arg::F32(&wv), Arg::F32(&wo), Arg::F32(&n2), Arg::F32(&f1),
                        Arg::F32(&f2), Arg::F32View(hist_k.view()), Arg::F32View(hist_v.view()),
                        Arg::I32(&meta2),
                    ],
                )
                .unwrap();

            // hidden rows: chunk 1 == mono[0..split], chunk 2 == mono[split..valid]
            assert_eq!(
                &c1[0].data[..split * d],
                &mono[0].data[..split * d],
                "{mode}: chunk 1 hidden rows diverged"
            );
            assert_eq!(
                &c2[0].data[..n2_rows * d],
                &mono[0].data[split * d..valid * d],
                "{mode}: chunk 2 hidden rows diverged"
            );
            // k/v rows per head, at the chunk-local offsets
            for hh in 0..h {
                let o = hh * total * dd;
                assert_eq!(
                    &c1[1].data[o..o + split * dd],
                    &mono[1].data[o..o + split * dd],
                    "{mode}: chunk 1 k rows diverged (head {hh})"
                );
                assert_eq!(
                    &c2[1].data[o..o + n2_rows * dd],
                    &mono[1].data[o + split * dd..o + valid * dd],
                    "{mode}: chunk 2 k rows diverged (head {hh})"
                );
                assert_eq!(
                    &c2[2].data[o..o + n2_rows * dd],
                    &mono[2].data[o + split * dd..o + valid * dd],
                    "{mode}: chunk 2 v rows diverged (head {hh})"
                );
            }
        }
    }

    #[test]
    fn attend_batch_rejects_malformed_groups() {
        let mut b = backend();
        let m = b.cfg.model.clone();
        let (d, h, dd) = (m.d_model, m.n_heads, m.head_dim);
        for exe in ["attend_batch_fa", "attend_batch_sa"] {
            b.load(exe).unwrap();
        }
        let x = mk_tensor(vec![1, d], 70);
        let q = mk_tensor(vec![1, h, dd], 71);
        let wo = mk_tensor(vec![d, d], 72);
        let n2 = HostTensor::new(vec![d], vec![1.0; d]);
        let f1 = mk_tensor(vec![d, m.d_ff], 73);
        let f2 = mk_tensor(vec![m.d_ff, d], 74);
        let valid = [5i32];
        // a 192-slot cache is neither a published decode bucket (FA)
        // nor SA_BUF-sized (SA): both groups must reject it
        let kc = mk_tensor(vec![h, 192, dd], 75);
        let vc = mk_tensor(vec![h, 192, dd], 76);
        for exe in ["attend_batch_fa", "attend_batch_sa"] {
            let err = b
                .run(
                    exe,
                    &[
                        Arg::F32(&x), Arg::F32(&q), Arg::I32(&valid), Arg::F32(&wo),
                        Arg::F32(&n2), Arg::F32(&f1), Arg::F32(&f2),
                        Arg::F32View(kc.view()), Arg::F32View(vc.view()),
                    ],
                )
                .unwrap_err();
            assert!(err.to_string().contains("192"), "{exe}: {err}");
        }
        // missing the v half of a (k, v) pair
        let err = b
            .run(
                "attend_batch_fa",
                &[
                    Arg::F32(&x), Arg::F32(&q), Arg::I32(&valid), Arg::F32(&wo),
                    Arg::F32(&n2), Arg::F32(&f1), Arg::F32(&f2),
                    Arg::F32View(kc.view()),
                ],
            )
            .unwrap_err();
        assert!(err.to_string().contains("pairs"), "{err}");
    }
}
