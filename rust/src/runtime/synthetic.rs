//! Synthetic artifact generator: emits a complete, deterministic
//! artifact directory (`manifest.json`, `model_meta.json`,
//! `weights.bin/json`, `router_balanced.bin/json`) from
//! [`crate::util::rng::Rng`], so the engine, coordinator, eval harness
//! and CLI run end-to-end with zero Python / JAX / XLA.
//!
//! The generated manifest carries `"backend": "ref"`, routing
//! [`crate::engine::Engine::load`] to the pure-Rust
//! [`super::RefBackend`]. Weights are untrained (random normal, weight-
//! tied `lm_head = embed^T`, unit norms) — the test suite pins serving
//! *invariants* (determinism, teacher-forcing parity, KV bounds,
//! routing plumbing), none of which depend on trained weights.
//!
//! The `router_balanced` variant is bias-dominated by construction:
//! even layers route FA, odd layers SA, with a tiny descriptor-dependent
//! term that cannot flip the margin. That makes routing deterministic
//! and gives every Flux-policy request a stable 0.5 Omega_MSR mix of
//! full and sparse layers — both cache layouts get exercised.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::MetaConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Default synthetic model configuration: small enough that the full
/// integration suite runs in seconds, large enough to cover every
/// bucket/mask code path (4 layers, 4 heads, 1k-token prefill buckets).
pub const DEFAULT_META: &str = r#"{
  "model": {"vocab_size": 512, "d_model": 32, "n_layers": 4,
            "n_heads": 4, "head_dim": 8, "d_ff": 64,
            "max_seq_len": 2048, "rope_theta": 10000.0,
            "rms_eps": 1e-5},
  "sparsity": {"sink_size": 16, "local_size": 64, "block_size": 16,
               "xattn_stride": 4, "xattn_keep_ratio": 0.25,
               "triangle_last_q": 32, "pool_size": 16},
  "router": {"d_hidden": 16, "tau_start": 2.0, "tau_end": 0.3,
             "t_retrieval": 0.45, "t_holistic": 1.0},
  "prefill_buckets": [128, 256, 512, 1024],
  "decode_kv_buckets": [128, 256, 512, 1024, 2048],
  "sa_decode_window": 81,
  "sa_buf": 128
}"#;

/// Standard normal sample (Box–Muller over the SplitMix64 substrate).
fn normal(rng: &mut Rng) -> f64 {
    let u1 = rng.f64().max(1e-12);
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Accumulates tensors into a flat little-endian f32 blob + the JSON
/// manifest layout `python/compile/train.py::export_flat_bin` writes.
struct BlobWriter {
    bytes: Vec<u8>,
    entries: Json,
}

impl BlobWriter {
    fn new() -> Self {
        Self { bytes: Vec::new(), entries: Json::Arr(vec![]) }
    }

    fn push(&mut self, name: &str, shape: &[usize], data: &[f32]) {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "{name} shape mismatch");
        let mut e = Json::obj();
        e.set("name", Json::from(name));
        e.set("offset", Json::from(self.bytes.len()));
        e.set("shape", Json::from(shape.to_vec()));
        self.entries.push(e);
        for v in data {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn save(self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::write(dir.join(format!("{stem}.bin")), &self.bytes)
            .with_context(|| format!("writing {stem}.bin"))?;
        std::fs::write(dir.join(format!("{stem}.json")), self.entries.to_string())
            .with_context(|| format!("writing {stem}.json"))?;
        Ok(())
    }
}

fn normal_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (normal(rng) * scale) as f32).collect()
}

/// The executable list the manifest advertises for a config (the same
/// names `python -m compile.aot` lowers).
pub fn executable_names(cfg: &MetaConfig) -> Vec<String> {
    let mut out = Vec::new();
    for &s in &cfg.prefill_buckets {
        for mode in ["fa", "ssa", "ta", "xa"] {
            out.push(format!("layer_{mode}_prefill_{s}"));
        }
    }
    out.push("decode_qkv".to_string());
    for &k in &cfg.decode_kv_buckets {
        out.push(format!("decode_attend_fa_{k}"));
    }
    out.push("decode_attend_sa".to_string());
    out.push("router".to_string());
    out.push("lm_head".to_string());
    out
}

/// Write a full synthetic artifact directory for `meta_json` (a
/// `model_meta.json` document — see [`DEFAULT_META`]). Deterministic in
/// `(meta_json, seed)`; overwrites existing files.
pub fn write_artifacts(dir: &Path, meta_json: &str, seed: u64) -> Result<PathBuf> {
    let cfg = MetaConfig::from_json_str(meta_json, dir.to_path_buf())
        .context("synthetic meta config")?;
    cfg.validate()?;
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    std::fs::write(dir.join("model_meta.json"), meta_json)?;

    let m = &cfg.model;
    let (v, d, l, ff) = (m.vocab_size, m.d_model, m.n_layers, m.d_ff);
    let mut rng = Rng::seed_from_u64(seed ^ 0xF1DE_C0DE);

    // backbone weights (weight-tied lm_head = embed^T, like the export)
    let mut w = BlobWriter::new();
    let embed = normal_vec(&mut rng, v * d, 1.0 / (d as f64).sqrt());
    w.push("embed", &[v, d], &embed);
    w.push("layers.norm1", &[l, d], &vec![1.0f32; l * d]);
    w.push("layers.wq", &[l, d, d], &normal_vec(&mut rng, l * d * d, 1.0 / (d as f64).sqrt()));
    w.push("layers.wk", &[l, d, d], &normal_vec(&mut rng, l * d * d, 1.0 / (d as f64).sqrt()));
    w.push("layers.wv", &[l, d, d], &normal_vec(&mut rng, l * d * d, 1.0 / (d as f64).sqrt()));
    w.push("layers.wo", &[l, d, d], &normal_vec(&mut rng, l * d * d, 1.0 / (d as f64).sqrt()));
    w.push("layers.norm2", &[l, d], &vec![1.0f32; l * d]);
    w.push("layers.w_ff1", &[l, d, ff], &normal_vec(&mut rng, l * d * ff, 1.0 / (d as f64).sqrt()));
    w.push("layers.w_ff2", &[l, ff, d], &normal_vec(&mut rng, l * ff * d, 1.0 / (ff as f64).sqrt()));
    w.push("norm_f", &[d], &vec![1.0f32; d]);
    let mut lm_head = vec![0f32; d * v];
    for t in 0..v {
        for i in 0..d {
            lm_head[i * v + t] = embed[t * d + i];
        }
    }
    w.push("lm_head", &[d, v], &lm_head);
    w.save(dir, "weights")?;

    // "balanced" router: even layers FA, odd layers SA, via a bias
    // margin (1.0) that the tiny data-dependent term cannot flip
    let rh = cfg.router.d_hidden;
    let mut r = BlobWriter::new();
    r.push("w1", &[l, 2 * d, rh], &normal_vec(&mut rng, l * 2 * d * rh, 1e-3 / (2.0 * d as f64).sqrt()));
    r.push("b1", &[l, rh], &vec![0.0f32; l * rh]);
    r.push("w2", &[l, rh, 2], &normal_vec(&mut rng, l * rh * 2, 1e-3));
    let mut b2 = vec![0.0f32; l * 2];
    for layer in 0..l {
        // logits order is [SA, FA]; is_fa = logits[1] > logits[0]
        if layer % 2 == 0 {
            b2[layer * 2 + 1] = 1.0;
        } else {
            b2[layer * 2] = 1.0;
        }
    }
    r.push("b2", &[l, 2], &b2);
    r.save(dir, "router_balanced")?;

    let mut manifest = Json::obj();
    manifest.set("backend", Json::from("ref"));
    manifest.set("executables", Json::from(executable_names(&cfg)));
    manifest.set(
        "weights",
        Json::from(vec!["weights.bin".to_string(), "router_balanced.bin".to_string()]),
    );
    std::fs::write(dir.join("manifest.json"), manifest.to_string())?;
    Ok(dir.to_path_buf())
}

/// Locate (or lazily generate) the default artifact directory for tests,
/// benches and examples:
/// 1. `$FLUX_ARTIFACTS` when set and populated (real AOT artifacts win);
/// 2. otherwise a cached synthetic set under the system temp dir,
///    generated atomically (write to a scratch dir, rename into place)
///    so concurrent test binaries cannot observe a half-written tree.
///
/// In-process concurrency (parallel `cargo test` threads share a pid and
/// therefore a scratch path) is serialized through a `OnceLock`;
/// cross-process races are resolved by the atomic rename.
pub fn ensure_default() -> Result<PathBuf> {
    static DEFAULT_DIR: std::sync::OnceLock<std::result::Result<PathBuf, String>> =
        std::sync::OnceLock::new();
    match DEFAULT_DIR.get_or_init(|| ensure_default_uncached().map_err(|e| e.to_string())) {
        Ok(p) => Ok(p.clone()),
        Err(e) => Err(anyhow::anyhow!("{e}")),
    }
}

fn ensure_default_uncached() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("FLUX_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
        eprintln!(
            "FLUX_ARTIFACTS={p:?} has no manifest.json; falling back to synthetic artifacts"
        );
    } else {
        // the CLI's default export location (`make artifacts`): real
        // trained artifacts win over synthetic ones when present
        let p = PathBuf::from("artifacts");
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
    }
    let dir = std::env::temp_dir().join("flux-synthetic-artifacts-v1");
    if dir.join("manifest.json").exists() {
        return Ok(dir);
    }
    let scratch = std::env::temp_dir().join(format!(
        "flux-synthetic-artifacts-v1.scratch-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&scratch);
    write_artifacts(&scratch, DEFAULT_META, 0)?;
    match std::fs::rename(&scratch, &dir) {
        Ok(()) => {}
        Err(e) => {
            // lost the race to another process: its tree is complete
            let _ = std::fs::remove_dir_all(&scratch);
            anyhow::ensure!(
                dir.join("manifest.json").exists(),
                "synthetic artifact dir {dir:?} unusable: {e}"
            );
        }
    }
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::WeightStore;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("flux-synth-test-{tag}-{}", std::process::id()))
    }

    #[test]
    fn artifacts_are_complete_and_loadable() {
        let dir = scratch("complete");
        write_artifacts(&dir, DEFAULT_META, 3).unwrap();
        for f in [
            "manifest.json",
            "model_meta.json",
            "weights.bin",
            "weights.json",
            "router_balanced.bin",
            "router_balanced.json",
        ] {
            assert!(dir.join(f).exists(), "missing {f}");
        }
        let cfg = MetaConfig::load(&dir).unwrap();
        let ws = WeightStore::load(dir.join("weights.bin"), dir.join("weights.json")).unwrap();
        let embed = ws.get("embed").unwrap();
        assert_eq!(embed.shape, vec![cfg.model.vocab_size, cfg.model.d_model]);
        let wq1 = ws.layer_slice("layers.wq", 1).unwrap();
        assert_eq!(wq1.shape, vec![cfg.model.d_model, cfg.model.d_model]);
        // weight tying: lm_head == embed^T
        let lm = ws.get("lm_head").unwrap();
        let (v, d) = (cfg.model.vocab_size, cfg.model.d_model);
        assert_eq!(lm.data[3 * v + 7], embed.data[7 * d + 3]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn generation_is_deterministic_in_seed() {
        let d1 = scratch("det1");
        let d2 = scratch("det2");
        write_artifacts(&d1, DEFAULT_META, 9).unwrap();
        write_artifacts(&d2, DEFAULT_META, 9).unwrap();
        let b1 = std::fs::read(d1.join("weights.bin")).unwrap();
        let b2 = std::fs::read(d2.join("weights.bin")).unwrap();
        assert_eq!(b1, b2, "same seed must produce identical blobs");
        let d3 = scratch("det3");
        write_artifacts(&d3, DEFAULT_META, 10).unwrap();
        let b3 = std::fs::read(d3.join("weights.bin")).unwrap();
        assert_ne!(b1, b3, "different seeds must differ");
        for d in [d1, d2, d3] {
            let _ = std::fs::remove_dir_all(&d);
        }
    }

    #[test]
    fn executable_list_covers_every_bucket_and_mode() {
        let cfg = MetaConfig::from_json_str(DEFAULT_META, PathBuf::from("/tmp")).unwrap();
        let names = executable_names(&cfg);
        assert_eq!(
            names.len(),
            cfg.prefill_buckets.len() * 4 + 1 + cfg.decode_kv_buckets.len() + 1 + 2
        );
        assert!(names.contains(&"layer_xa_prefill_1024".to_string()));
        assert!(names.contains(&"decode_attend_fa_2048".to_string()));
        assert!(names.contains(&"decode_attend_sa".to_string()));
    }

    #[test]
    fn ensure_default_is_idempotent() {
        let a = ensure_default().unwrap();
        let b = ensure_default().unwrap();
        assert_eq!(a, b);
        assert!(a.join("manifest.json").exists());
    }
}
