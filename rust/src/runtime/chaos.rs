//! Deterministic fault injection: [`ChaosBackend`] wraps any
//! [`Backend`] and injects failures at chosen `run`-call indices,
//! driven by a [`FaultPlan`] (DESIGN.md §12).
//!
//! This is the testing substrate for the failure-domain work: engine
//! supervision, the round watchdog and graceful drain are only
//! verifiable if kernel failures, panics and stalls can be produced *on
//! demand and reproducibly*. A plan is either written out explicitly
//! (`FLUX_FAULT_PLAN="panic@120,stall:800@40"`) or derived from a seed
//! (`FLUX_FAULT_SEED=7`) through the same SplitMix64 RNG the workload
//! generators use — the same seed always yields the same schedule.
//!
//! Fault kinds:
//! * `err`   — the kernel call returns a typed `Err` (the per-request
//!   failure path: the scheduler retires that request, engine survives);
//! * `panic` — the kernel call panics on the engine thread (the engine
//!   death path: caught by the job-loop `catch_unwind`, surfaced as
//!   [`crate::engine::EngineFailed`], recovered by supervision);
//! * `stall:<ms>` — the call sleeps before executing (the hang path:
//!   trips the scheduler's round watchdog when one is configured);
//! * `pool`  — an `Err` shaped like KV pool exhaustion (exercises the
//!   allocation-failure error path without a real full pool).
//!
//! A plan describes ONE engine lifetime: a respawned engine is always
//! fault-free, so recovery tests can assert post-restart bit-identity
//! against a clean run.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::{Arg, Backend, ExeStats, HostTensor};
use crate::util::rng::Rng;

/// What to inject at one `run`-call index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Return a typed kernel `Err` instead of executing.
    Err,
    /// Panic on the engine thread instead of executing.
    Panic,
    /// Sleep this many milliseconds, then execute normally.
    Stall(u64),
    /// Return an `Err` shaped like KV pool exhaustion.
    PoolExhausted,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultKind::Err => write!(f, "err"),
            FaultKind::Panic => write!(f, "panic"),
            FaultKind::Stall(ms) => write!(f, "stall:{ms}"),
            FaultKind::PoolExhausted => write!(f, "pool"),
        }
    }
}

/// A deterministic fault schedule: `run`-call index → fault. Indices
/// count every `Backend::run` invocation of one engine lifetime
/// (prefill layers, router nets, decode kernels alike), starting at 0.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: BTreeMap<u64, FaultKind>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder: inject `kind` at `run`-call number `index`.
    pub fn with(mut self, index: u64, kind: FaultKind) -> Self {
        self.faults.insert(index, kind);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn get(&self, index: u64) -> Option<FaultKind> {
        self.faults.get(&index).copied()
    }

    /// Parse the `FLUX_FAULT_PLAN` syntax: comma-separated
    /// `<kind>@<index>` entries where `<kind>` is `err`, `panic`,
    /// `pool`, or `stall:<ms>` — e.g. `"panic@120,stall:800@40"`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, index) = entry
                .split_once('@')
                .with_context(|| format!("fault entry '{entry}' missing '@<index>'"))?;
            let index: u64 = index
                .trim()
                .parse()
                .with_context(|| format!("fault entry '{entry}': bad call index"))?;
            let kind = match kind.trim() {
                "err" => FaultKind::Err,
                "panic" => FaultKind::Panic,
                "pool" => FaultKind::PoolExhausted,
                other => match other.strip_prefix("stall:") {
                    Some(ms) => FaultKind::Stall(
                        ms.parse()
                            .with_context(|| format!("fault entry '{entry}': bad stall ms"))?,
                    ),
                    None => bail!("fault entry '{entry}': unknown kind '{other}'"),
                },
            };
            plan.faults.insert(index, kind);
        }
        Ok(plan)
    }

    /// Derive a schedule from a seed: 1–3 faults at call indices in
    /// [10, 400) — early enough that any real serving workload reaches
    /// them — with kinds weighted toward the recoverable classes.
    /// Deterministic: the same seed always yields the same plan.
    pub fn seeded(seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC0A5_F001);
        let mut plan = Self::new();
        let n = 1 + rng.gen_range(3);
        for _ in 0..n {
            let index = rng.range(10, 400) as u64;
            let kind = match rng.categorical(&[0.35, 0.25, 0.2, 0.2]) {
                0 => FaultKind::Err,
                1 => FaultKind::Panic,
                2 => FaultKind::Stall(rng.range(400, 900) as u64),
                _ => FaultKind::PoolExhausted,
            };
            plan.faults.insert(index, kind);
        }
        plan
    }

    /// The CLI/CI entry point: `FLUX_FAULT_PLAN` (explicit schedule)
    /// takes precedence over `FLUX_FAULT_SEED` (derived schedule);
    /// neither set means no injection. Tests construct plans
    /// programmatically instead — env mutation races across parallel
    /// test threads.
    pub fn from_env() -> Result<Option<Self>> {
        if let Ok(spec) = std::env::var("FLUX_FAULT_PLAN") {
            if !spec.trim().is_empty() {
                return Ok(Some(Self::parse(&spec).context("FLUX_FAULT_PLAN")?));
            }
        }
        if let Ok(seed) = std::env::var("FLUX_FAULT_SEED") {
            if !seed.trim().is_empty() {
                let seed: u64 = seed.trim().parse().context("FLUX_FAULT_SEED")?;
                return Ok(Some(Self::seeded(seed)));
            }
        }
        Ok(None)
    }
}

impl std::fmt::Display for FaultPlan {
    /// Round-trips through [`FaultPlan::parse`] (logging / bench ledger).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for (index, kind) in &self.faults {
            if !first {
                write!(f, ",")?;
            }
            write!(f, "{kind}@{index}")?;
            first = false;
        }
        Ok(())
    }
}

/// A [`Backend`] decorator that counts `run` calls and injects the
/// plan's fault when the counter hits a scheduled index. Everything
/// else — loading, stats, capability flags — delegates to the wrapped
/// backend, so the engine above is none the wiser until the fault fires.
pub struct ChaosBackend {
    inner: Box<dyn Backend>,
    plan: FaultPlan,
    calls: u64,
}

impl ChaosBackend {
    pub fn new(inner: Box<dyn Backend>, plan: FaultPlan) -> Self {
        Self { inner, plan, calls: 0 }
    }

    /// Wrap `inner` unless the plan is empty (no-fault plans add no
    /// indirection).
    pub fn wrap(inner: Box<dyn Backend>, plan: FaultPlan) -> Box<dyn Backend> {
        if plan.is_empty() {
            inner
        } else {
            Box::new(Self::new(inner, plan))
        }
    }
}

impl Backend for ChaosBackend {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn load(&mut self, exe: &str) -> Result<()> {
        self.inner.load(exe)
    }

    fn is_loaded(&self, exe: &str) -> bool {
        self.inner.is_loaded(exe)
    }

    fn run(&mut self, exe: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let idx = self.calls;
        self.calls += 1;
        match self.plan.get(idx) {
            Some(FaultKind::Err) => {
                bail!("chaos: injected kernel failure at call {idx} ({exe})")
            }
            Some(FaultKind::Panic) => {
                panic!("chaos: injected kernel panic at call {idx} ({exe})")
            }
            Some(FaultKind::Stall(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                self.inner.run(exe, args)
            }
            Some(FaultKind::PoolExhausted) => {
                bail!("kv pool exhausted: chaos-injected at call {idx} ({exe})")
            }
            None => self.inner.run(exe, args),
        }
    }

    fn stats(&self) -> &std::collections::HashMap<String, ExeStats> {
        self.inner.stats()
    }

    fn reset_stats(&mut self) {
        self.inner.reset_stats()
    }

    fn note_kv_transfer(&mut self, exe: &str, bytes_moved: u64, bytes_borrowed: u64) {
        self.inner.note_kv_transfer(exe, bytes_moved, bytes_borrowed)
    }

    fn note_prefill_rows(&mut self, exe: &str, rows_valid: u64, rows_padded: u64) {
        self.inner.note_prefill_rows(exe, rows_valid, rows_padded)
    }

    fn set_threads(&mut self, n: usize) {
        self.inner.set_threads(n)
    }

    fn accepts_prefill_valid_arg(&self) -> bool {
        self.inner.accepts_prefill_valid_arg()
    }

    fn accepts_prefill_chunks(&self) -> bool {
        self.inner.accepts_prefill_chunks()
    }

    fn accepts_decode_batch(&self) -> bool {
        self.inner.accepts_decode_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MetaConfig;
    use crate::runtime::RefBackend;

    #[test]
    fn plan_parse_roundtrip() {
        let plan = FaultPlan::parse("panic@120, stall:800@40,err@3,pool@9").unwrap();
        assert_eq!(plan.get(120), Some(FaultKind::Panic));
        assert_eq!(plan.get(40), Some(FaultKind::Stall(800)));
        assert_eq!(plan.get(3), Some(FaultKind::Err));
        assert_eq!(plan.get(9), Some(FaultKind::PoolExhausted));
        assert_eq!(plan.get(4), None);
        let reparsed = FaultPlan::parse(&plan.to_string()).unwrap();
        assert_eq!(plan, reparsed);
    }

    #[test]
    fn plan_parse_rejects_garbage() {
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("panic@x").is_err());
        assert!(FaultPlan::parse("frobnicate@3").is_err());
        assert!(FaultPlan::parse("stall:abc@3").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn seeded_plans_are_deterministic_and_nonempty() {
        for seed in 0..32 {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            assert_eq!(a, b, "seed {seed} must be reproducible");
            assert!(!a.is_empty(), "seed {seed} produced an empty plan");
        }
        assert_ne!(FaultPlan::seeded(1), FaultPlan::seeded(2));
    }

    #[test]
    fn chaos_backend_injects_at_exact_index() {
        let cfg: MetaConfig = MetaConfig::from_json_str(
            crate::config::TEST_META_JSON,
            std::path::PathBuf::from("/tmp"),
        )
        .unwrap();
        let plan = FaultPlan::new().with(1, FaultKind::Err).with(2, FaultKind::PoolExhausted);
        let mut b = ChaosBackend::new(Box::new(RefBackend::new(cfg)), plan);
        b.load("lm_head").unwrap();
        assert!(b.is_loaded("lm_head"));
        let h = HostTensor::zeros(vec![1, 16]);
        // call 0: clean (delegates; argument errors from the ref kernel
        // are fine — we only care that injection did not fire)
        let r0 = b.run("lm_head", &[Arg::F32(&h)]);
        let _ = r0;
        // call 1: injected kernel failure
        let e1 = b.run("lm_head", &[Arg::F32(&h)]).unwrap_err().to_string();
        assert!(e1.contains("chaos: injected kernel failure at call 1"), "{e1}");
        // call 2: pool-exhaustion-shaped failure
        let e2 = b.run("lm_head", &[Arg::F32(&h)]).unwrap_err().to_string();
        assert!(e2.contains("kv pool exhausted"), "{e2}");
    }
}
