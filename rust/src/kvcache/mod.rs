//! KV-cache manager: a paged block pool shared by every request, with
//! per-layer full caches (bucketed growth) and sparse sink+local ring
//! buffers (the paper's sparse-decode configuration, section 3.3)
//! allocated as page runs inside it.
//!
//! ## The page pool
//!
//! [`KvPool`] owns two float arenas (one for K, one for V) divided into
//! fixed-size pages (`page_floats` floats each; the engine sizes a page
//! as 32 tokens × H × D). Every cache allocates a [`PageBlock`] — its
//! per-layer block table — covering `ceil(needed_floats / page_floats)`
//! pages, and retirement frees the pages back to the pool instead of
//! dropping a monolithic buffer, so FA and SA layers (and chunked-
//! prefill staging) all draw from ONE memory budget and the scheduler
//! can admit against it (DESIGN.md §11).
//!
//! A block's pages are CONTIGUOUS (the block table is a run of
//! consecutive page ids). This is deliberate: the decode executables
//! consume `(H, capacity, D)` row-major buffers as zero-copy
//! [`TensorView`]s, and a scattered page table would force a gather on
//! every decode step — exactly the copy traffic the zero-copy fast path
//! exists to avoid (`kv_bytes_moved == 0` on aligned buckets is pinned
//! by tests). First-fit allocation over a coalescing free list keeps
//! fragmentation bounded; the arenas grow lazily up to the page budget.
//!
//! Layout contract with the AOT decode executables (unchanged):
//!   * full cache  -> `(H, K_bucket, D)` row-major, `valid_len` slots
//!     filled from the front;
//!   * sparse cache -> `(H, SA_BUF, D)` with the sink tokens first and
//!     the local window following as a ring (oldest entry overwritten in
//!     place).
//!
//! Both caches keep their pool region *in executable layout* and hand
//! out zero-copy [`TensorView`]s for the decode hot path. Because every
//! cache owns a disjoint page run, a batched decode round (DESIGN.md
//! §9) stages many requests' views into ONE `attend_batch_{fa,sa}` call
//! simultaneously — the borrows are all shared borrows of the pool.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{HostTensor, TensorView};

pub mod prefix;

/// A contiguous run of pages inside a [`KvPool`] — the (degenerate,
/// consecutive-ids) block table of one cache. Copy on purpose: the
/// cache stores it by value; freeing goes through [`KvPool::free`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageBlock {
    /// first page id of the run
    pub start: usize,
    /// number of pages in the run
    pub pages: usize,
}

/// Fixed-size page pool backing every KV cache (K and V arenas grown
/// lazily up to `total_pages`). Single-threaded by design — it lives
/// inside the [`crate::engine::Engine`] on the executor thread.
#[derive(Debug)]
pub struct KvPool {
    page_floats: usize,
    total_pages: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// free runs over the grown region, sorted by start, coalesced
    free: Vec<PageBlock>,
    /// pages materialized in the arenas so far
    grown_pages: usize,
    allocated_pages: usize,
    peak_pages: usize,
    /// Extra shared references per block start, beyond the implicit one
    /// the allocating owner holds. Populated only by the prefix cache
    /// (`kvcache::prefix`) when a radix split makes two nodes window
    /// into one page run; a block with an entry here survives `free`
    /// until the last reference drops.
    refs: HashMap<usize, u32>,
}

impl KvPool {
    pub fn new(page_floats: usize, total_pages: usize) -> Self {
        assert!(page_floats > 0, "page size must be positive");
        Self {
            page_floats,
            total_pages,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            grown_pages: 0,
            allocated_pages: 0,
            peak_pages: 0,
            refs: HashMap::new(),
        }
    }

    /// Pool sized in model terms: pages of `page_tokens` tokens
    /// (`page_tokens * n_heads * head_dim` floats) covering a budget of
    /// `budget_tokens` cacheable tokens.
    pub fn with_budget(
        page_tokens: usize,
        n_heads: usize,
        head_dim: usize,
        budget_tokens: usize,
    ) -> Self {
        let page_floats = page_tokens.max(1) * n_heads * head_dim;
        Self::new(page_floats, budget_tokens.div_ceil(page_tokens.max(1)))
    }

    pub fn page_floats(&self) -> usize {
        self.page_floats
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages currently allocated to caches.
    pub fn pages_allocated(&self) -> usize {
        self.allocated_pages
    }

    /// Pages still available against the budget (free-listed runs plus
    /// the not-yet-grown tail — both are admissible).
    pub fn pages_free(&self) -> usize {
        self.total_pages - self.allocated_pages
    }

    /// High-water mark of allocated pages over the pool's lifetime.
    pub fn pages_peak(&self) -> usize {
        self.peak_pages
    }

    /// Pages needed to hold `n_floats` floats.
    pub fn pages_for(&self, n_floats: usize) -> usize {
        n_floats.div_ceil(self.page_floats).max(1)
    }

    /// Whether the pool has drained back to its fully-free state: no
    /// page allocated, and the free list coalesced to (at most) the one
    /// run spanning the whole grown arena. A violation means a request
    /// lifecycle leaked pages or the coalescing free-list invariant
    /// broke — the error describes which (DESIGN.md §12).
    pub fn drained(&self) -> std::result::Result<(), String> {
        self.drained_with_retained(0)
    }

    /// Like [`KvPool::drained`], but tolerating exactly `retained`
    /// pages held on purpose by the prefix index (`kvcache::prefix`):
    /// any other allocated page is a leak, and the error says which
    /// side of the ledger disagrees. With `retained == 0` this is the
    /// strict full-drain check.
    pub fn drained_with_retained(&self, retained: usize) -> std::result::Result<(), String> {
        if self.allocated_pages != retained {
            return Err(format!(
                "{} of {} pages allocated but the prefix index retains {} ({} leaked)",
                self.allocated_pages,
                self.total_pages,
                retained,
                self.allocated_pages.saturating_sub(retained)
            ));
        }
        if retained != 0 {
            return Ok(());
        }
        if !self.refs.is_empty() {
            return Err(format!(
                "{} shared page references outstanding after full drain",
                self.refs.len()
            ));
        }
        if self.free.len() > 1 {
            return Err(format!(
                "free list fragmented into {} runs after full drain",
                self.free.len()
            ));
        }
        if let Some(run) = self.free.first() {
            if run.start != 0 || run.pages != self.grown_pages {
                return Err(format!(
                    "free run [{}, {}) does not span the grown arena of {} pages",
                    run.start,
                    run.start + run.pages,
                    self.grown_pages
                ));
            }
        } else if self.grown_pages != 0 {
            return Err(format!("empty free list but {} pages grown", self.grown_pages));
        }
        Ok(())
    }

    /// Panicking form of [`KvPool::drained`] for test teardown.
    pub fn debug_assert_drained(&self) {
        if let Err(leak) = self.drained() {
            panic!("kv pool not drained: {leak}");
        }
    }

    /// Allocate a zeroed contiguous run covering `n_floats` floats (in
    /// each of the K and V arenas). Fails — typed, no panic — when the
    /// budget can't cover it; the caller surfaces that as a per-request
    /// error or an `Overloaded` admission rejection.
    pub fn alloc(&mut self, n_floats: usize) -> Result<PageBlock> {
        let need = self.pages_for(n_floats);
        let block = self.reserve(need)?;
        let a = block.start * self.page_floats;
        let b = (block.start + block.pages) * self.page_floats;
        self.k[a..b].fill(0.0);
        self.v[a..b].fill(0.0);
        self.allocated_pages += block.pages;
        self.peak_pages = self.peak_pages.max(self.allocated_pages);
        Ok(block)
    }

    /// Find or grow a run of `need` pages (no zeroing / accounting).
    fn reserve(&mut self, need: usize) -> Result<PageBlock> {
        // first fit over the free list
        if let Some(i) = self.free.iter().position(|r| r.pages >= need) {
            let run = self.free[i];
            if run.pages == need {
                self.free.remove(i);
            } else {
                self.free[i] = PageBlock { start: run.start + need, pages: run.pages - need };
            }
            return Ok(PageBlock { start: run.start, pages: need });
        }
        // grow the arenas at the tail; a free run ending exactly at the
        // grown edge extends into the growth so doubling patterns don't
        // strand tail fragments
        let (start, reuse_tail) = match self.free.last().copied() {
            Some(r) if r.start + r.pages == self.grown_pages => (r.start, r.pages),
            _ => (self.grown_pages, 0),
        };
        let grow_by = need - reuse_tail;
        if self.grown_pages + grow_by > self.total_pages {
            anyhow::bail!(
                "kv pool exhausted: need {need} pages, {} free of {} budget",
                self.pages_free(),
                self.total_pages
            );
        }
        if reuse_tail > 0 {
            self.free.pop();
        }
        self.grown_pages += grow_by;
        let floats = self.grown_pages * self.page_floats;
        self.k.resize(floats, 0.0);
        self.v.resize(floats, 0.0);
        Ok(PageBlock { start, pages: need })
    }

    /// Add a shared reference to an allocated block: one later
    /// [`KvPool::free`] of the same block drops the reference instead
    /// of returning pages. Only the prefix cache calls this — request
    /// caches always own their runs exclusively.
    pub fn retain(&mut self, block: PageBlock) {
        debug_assert!(block.start + block.pages <= self.grown_pages, "retain of unallocated block");
        *self.refs.entry(block.start).or_insert(0) += 1;
    }

    /// Return a block's pages to the free list (coalescing neighbours).
    /// Returns `true` when the pages were actually freed and `false`
    /// when the block is shared ([`KvPool::retain`]) and only a
    /// reference was dropped — callers tracking retained-page ledgers
    /// use the return; exclusive owners may ignore it.
    pub fn free(&mut self, block: PageBlock) -> bool {
        if let Some(n) = self.refs.get_mut(&block.start) {
            *n -= 1;
            if *n == 0 {
                self.refs.remove(&block.start);
            }
            return false;
        }
        debug_assert!(block.start + block.pages <= self.grown_pages, "free of unallocated block");
        debug_assert!(self.allocated_pages >= block.pages, "double free");
        self.allocated_pages -= block.pages;
        let i = self.free.partition_point(|r| r.start < block.start);
        self.free.insert(i, block);
        // coalesce with the right then left neighbour
        if i + 1 < self.free.len() && self.free[i].start + self.free[i].pages == self.free[i + 1].start
        {
            self.free[i].pages += self.free[i + 1].pages;
            self.free.remove(i + 1);
        }
        if i > 0 && self.free[i - 1].start + self.free[i - 1].pages == self.free[i].start {
            self.free[i - 1].pages += self.free[i].pages;
            self.free.remove(i);
        }
        true
    }

    /// Copy `rows` token rows per head between two `(H, cap, D)`
    /// pool regions, in both the K and V arenas. This is how the
    /// prefix cache moves page-aligned prefix runs between node
    /// storage and request staging — a pool-internal memcpy, never a
    /// kernel call, so prefill row counters never see reused tokens.
    #[allow(clippy::too_many_arguments)]
    pub fn copy_rows(
        &mut self,
        src: PageBlock,
        src_cap: usize,
        src_off: usize,
        dst: PageBlock,
        dst_cap: usize,
        dst_off: usize,
        rows: usize,
        n_heads: usize,
        head_dim: usize,
    ) {
        debug_assert!(src_off + rows <= src_cap);
        debug_assert!(dst_off + rows <= dst_cap);
        let d = head_dim;
        for hh in 0..n_heads {
            let s0 = src.start * self.page_floats + (hh * src_cap + src_off) * d;
            let t0 = dst.start * self.page_floats + (hh * dst_cap + dst_off) * d;
            self.k.copy_within(s0..s0 + rows * d, t0);
            self.v.copy_within(s0..s0 + rows * d, t0);
        }
    }

    /// Copy the first `n_floats` floats of one block's region into
    /// another (both arenas) — whole-buffer snapshot/restore for SA
    /// ring state held by the prefix cache.
    pub fn copy_region(&mut self, src: PageBlock, dst: PageBlock, n_floats: usize) {
        let s0 = src.start * self.page_floats;
        let t0 = dst.start * self.page_floats;
        self.k.copy_within(s0..s0 + n_floats, t0);
        self.v.copy_within(s0..s0 + n_floats, t0);
    }

    fn range(&self, block: PageBlock) -> std::ops::Range<usize> {
        block.start * self.page_floats..(block.start + block.pages) * self.page_floats
    }

    /// Borrow a block's K-arena floats.
    pub fn k_of(&self, block: PageBlock) -> &[f32] {
        &self.k[self.range(block)]
    }

    pub fn v_of(&self, block: PageBlock) -> &[f32] {
        &self.v[self.range(block)]
    }

    /// Borrow a block's K- and V-arena floats mutably (one call so a
    /// cache can write both halves of an append without re-borrowing).
    pub fn kv_mut(&mut self, block: PageBlock) -> (&mut [f32], &mut [f32]) {
        let r = self.range(block);
        (&mut self.k[r.clone()], &mut self.v[r])
    }
}

/// Full-history KV cache for one layer (FA / retrieval layers): a block
/// table over the pool holding `(H, capacity, D)` row-major.
#[derive(Debug)]
pub struct FullCache {
    n_heads: usize,
    head_dim: usize,
    capacity: usize, // current bucket
    len: usize,
    /// executable-layout shape `[H, capacity, D]`, kept in sync with
    /// `capacity` so [`FullCache::view`] can borrow it
    shape: [usize; 3],
    block: PageBlock,
}

impl FullCache {
    pub fn new(pool: &mut KvPool, n_heads: usize, head_dim: usize, capacity: usize) -> Result<Self> {
        let block = pool.alloc(n_heads * capacity * head_dim)?;
        Ok(Self {
            n_heads,
            head_dim,
            capacity,
            len: 0,
            shape: [n_heads, capacity, head_dim],
            block,
        })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// KV bytes currently held (memory accounting for Table 1 notes) —
    /// the logical `(H, capacity, D)` extent, not the page-rounded run.
    pub fn bytes(&self) -> usize {
        2 * self.n_heads * self.capacity * self.head_dim * 4
    }

    /// Pages held in the pool.
    pub fn pages(&self) -> usize {
        self.block.pages
    }

    /// Return this cache's pages to the pool. Consumes the cache — a
    /// freed block table must never be viewed again.
    pub fn free(self, pool: &mut KvPool) {
        pool.free(self.block);
    }

    /// number of floats the `(H, capacity, D)` layout occupies
    fn floats(&self) -> usize {
        self.n_heads * self.capacity * self.head_dim
    }

    /// Bulk-load prefill outputs `k`, `v` shaped `(H, S_bucket, D)` of
    /// which the first `valid` columns are real tokens — exactly one
    /// whole-prompt [`FullCache::append_prefill_chunk`] from empty.
    pub fn load_prefill(
        &mut self,
        pool: &mut KvPool,
        k: &HostTensor,
        v: &HostTensor,
        valid: usize,
    ) -> Result<()> {
        self.len = 0;
        self.append_prefill_chunk(pool, k, v, valid)
    }

    /// Append one token's `(H, D)` k/v. Fails (typed) when the pool
    /// can't cover the next capacity doubling.
    pub fn append(&mut self, pool: &mut KvPool, k_new: &[f32], v_new: &[f32]) -> Result<()> {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k_new.len(), h * d);
        self.ensure_capacity(pool, self.len + 1)?;
        let cap = self.capacity;
        let (kb, vb) = pool.kv_mut(self.block);
        for hh in 0..h {
            let dst = (hh * cap + self.len) * d;
            kb[dst..dst + d].copy_from_slice(&k_new[hh * d..(hh + 1) * d]);
            vb[dst..dst + d].copy_from_slice(&v_new[hh * d..(hh + 1) * d]);
        }
        self.len += 1;
        Ok(())
    }

    /// Append-at-offset priming for chunked prefill (DESIGN.md §10):
    /// bulk-append a chunk's `(H, S_chunk, D)` k/v outputs (first
    /// `valid` rows real) at the current length, leaving the pool region
    /// bit-identical to a monolithic [`FullCache::load_prefill`] of the
    /// concatenated prompt — the staged prefix later chunks attend over
    /// through [`FullCache::view`] with zero copies.
    pub fn append_prefill_chunk(
        &mut self,
        pool: &mut KvPool,
        k: &HostTensor,
        v: &HostTensor,
        valid: usize,
    ) -> Result<()> {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.shape.len(), 3);
        assert_eq!(k.shape[0], h);
        assert_eq!(k.shape[2], d);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        self.ensure_capacity(pool, self.len + valid)?;
        let cap = self.capacity;
        let (kb, vb) = pool.kv_mut(self.block);
        for hh in 0..h {
            for t in 0..valid {
                let src = (hh * s_in + t) * d;
                let dst = (hh * cap + self.len + t) * d;
                kb[dst..dst + d].copy_from_slice(&k.data[src..src + d]);
                vb[dst..dst + d].copy_from_slice(&v.data[src..src + d]);
            }
        }
        self.len += valid;
        Ok(())
    }

    /// Prime this cache's tail with `rows` token rows per head copied
    /// from a pool-resident prefix segment (the radix cache's node
    /// storage, laid out `(H, src_cap, D)` starting at row `src_off`).
    /// A prefix hit lands cached KV here without running any prefill
    /// kernel, so the chunk loop starts after the shared prefix.
    pub fn prime_from_pool(
        &mut self,
        pool: &mut KvPool,
        src: PageBlock,
        src_cap: usize,
        src_off: usize,
        rows: usize,
    ) {
        assert!(self.len + rows <= self.capacity, "primed prefix exceeds staging capacity");
        pool.copy_rows(
            src,
            src_cap,
            src_off,
            self.block,
            self.capacity,
            self.len,
            rows,
            self.n_heads,
            self.head_dim,
        );
        self.len += rows;
    }

    /// Pre-flight for [`FullCache::append`]: grow (or confirm) capacity
    /// for one more token WITHOUT writing anything. On failure the
    /// cache is restored bit-identically, so a scheduler can reserve
    /// capacity for every layer of a decode step before mutating any of
    /// them — a step that cannot reserve fails with all caches
    /// untouched and is safe to retry after preemption frees pages
    /// (DESIGN.md §15).
    pub fn reserve_for_append(&mut self, pool: &mut KvPool) -> Result<()> {
        self.ensure_capacity(pool, self.len + 1)
    }

    fn ensure_capacity(&mut self, pool: &mut KvPool, need: usize) -> Result<()> {
        if need <= self.capacity {
            return Ok(());
        }
        let mut cap = self.capacity.max(1);
        while cap < need {
            cap *= 2;
        }
        let (h, d) = (self.n_heads, self.head_dim);
        // copy the valid prefix out, free the old run FIRST (so the
        // grown allocation may reuse those very pages — growth never
        // transiently holds old+new and the scheduler's worst-case page
        // reservation stays an upper bound), then re-lay-out
        let old_cap = self.capacity;
        let mut k_old = vec![0.0; h * self.len * d];
        let mut v_old = vec![0.0; h * self.len * d];
        {
            let ks = pool.k_of(self.block);
            let vs = pool.v_of(self.block);
            for hh in 0..h {
                let src = hh * old_cap * d;
                let dst = hh * self.len * d;
                let n = self.len * d;
                k_old[dst..dst + n].copy_from_slice(&ks[src..src + n]);
                v_old[dst..dst + n].copy_from_slice(&vs[src..src + n]);
            }
        }
        pool.free(self.block);
        let block = match pool.alloc(h * cap * d) {
            Ok(b) => b,
            Err(e) => {
                // the run we just freed is still free-listed, so an
                // allocation of the old size cannot fail — restore the
                // cache exactly as it was and surface the typed error
                self.block = pool
                    .alloc(h * old_cap * d)
                    .expect("re-allocating the just-freed run cannot fail");
                let (kb, vb) = pool.kv_mut(self.block);
                for hh in 0..h {
                    let src = hh * self.len * d;
                    let dst = hh * old_cap * d;
                    let n = self.len * d;
                    kb[dst..dst + n].copy_from_slice(&k_old[src..src + n]);
                    vb[dst..dst + n].copy_from_slice(&v_old[src..src + n]);
                }
                return Err(e);
            }
        };
        let (kb, vb) = pool.kv_mut(block);
        for hh in 0..h {
            let src = hh * self.len * d;
            let dst = hh * cap * d;
            let n = self.len * d;
            kb[dst..dst + n].copy_from_slice(&k_old[src..src + n]);
            vb[dst..dst + n].copy_from_slice(&v_old[src..src + n]);
        }
        self.block = block;
        self.capacity = cap;
        self.shape = [h, cap, d];
        Ok(())
    }

    /// Zero-copy view of the pool-resident `(H, capacity, D)` region.
    /// Valid as decode-executable arguments only when the capacity
    /// equals the selected bucket —
    /// [`crate::config::MetaConfig::decode_attend_bucket`] prefers the
    /// capacity exactly so this is the decode fast path.
    pub fn view<'a>(&'a self, pool: &'a KvPool) -> (TensorView<'a>, TensorView<'a>) {
        let n = self.floats();
        (
            TensorView { shape: &self.shape, data: &pool.k_of(self.block)[..n] },
            TensorView { shape: &self.shape, data: &pool.v_of(self.block)[..n] },
        )
    }

    /// Re-bucket into `(H, bucket, D)` tensors for the decode executable.
    ///
    /// Fast path for the decode hot loop: when the cache's internal
    /// capacity already equals the requested bucket (the common case —
    /// both are published decode buckets grown in lockstep, and
    /// [`crate::config::MetaConfig::decode_attend_bucket`] prefers the
    /// capacity exactly for this reason), the pool region is already in
    /// executable layout and is cloned wholesale instead of re-laid-out
    /// per head (see EXPERIMENTS.md §Perf).
    pub fn as_tensors(&self, pool: &KvPool, bucket: usize) -> (HostTensor, HostTensor) {
        assert!(bucket >= self.len, "bucket {bucket} < len {}", self.len);
        let (h, d) = (self.n_heads, self.head_dim);
        let n = self.floats();
        let ks = &pool.k_of(self.block)[..n];
        let vs = &pool.v_of(self.block)[..n];
        if bucket == self.capacity {
            return (
                HostTensor::new(vec![h, bucket, d], ks.to_vec()),
                HostTensor::new(vec![h, bucket, d], vs.to_vec()),
            );
        }
        let mut k = vec![0.0; h * bucket * d];
        let mut v = vec![0.0; h * bucket * d];
        for hh in 0..h {
            let src0 = hh * self.capacity * d;
            let dst0 = hh * bucket * d;
            let nn = self.len * d;
            k[dst0..dst0 + nn].copy_from_slice(&ks[src0..src0 + nn]);
            v[dst0..dst0 + nn].copy_from_slice(&vs[src0..src0 + nn]);
        }
        (
            HostTensor::new(vec![h, bucket, d], k),
            HostTensor::new(vec![h, bucket, d], v),
        )
    }
}

/// Sink + local-window ring cache for sparse-decode layers. Holds at
/// most `sink + local` live tokens; the full history is never retained —
/// this is the paper's KV-memory reduction.
///
/// The backing store IS the executable layout: one `(H, SA_BUF, D)`
/// region pair allocated from the SAME pool as the full caches (so FA
/// and SA layers share one memory budget), incrementally maintained on
/// `append` (the window region is a true ring — the oldest entry is
/// overwritten in place, O(H·D) per token), so decode reads it through
/// [`SparseCache::view`] with zero copies. Slot layout: sink tokens
/// occupy slots `0..sink_len`; the window occupies slots
/// `sink_len..sink_len+win_len` with the write cursor cycling through
/// them. Ring order is deterministic in the append history, and the
/// attention executable treats the buffer as a set, so this is exact.
#[derive(Debug)]
pub struct SparseCache {
    n_heads: usize,
    head_dim: usize,
    sink: usize,
    local: usize,
    buf: usize,
    /// executable-layout shape `[H, SA_BUF, D]` (borrowed by `view`)
    shape: [usize; 3],
    sink_len: usize,
    total_seen: usize,
    block: PageBlock,
}

impl SparseCache {
    pub fn new(
        pool: &mut KvPool,
        n_heads: usize,
        head_dim: usize,
        sink: usize,
        local: usize,
        buf: usize,
    ) -> Result<Self> {
        assert!(buf >= sink + local + 1);
        let block = pool.alloc(n_heads * buf * head_dim)?;
        Ok(Self {
            n_heads,
            head_dim,
            sink,
            local,
            buf,
            shape: [n_heads, buf, head_dim],
            sink_len: 0,
            total_seen: 0,
            block,
        })
    }

    /// Window entries currently live (tokens appended past the sink,
    /// capped by the ring size).
    fn win_len(&self) -> usize {
        (self.total_seen - self.sink_len).min(self.local)
    }

    pub fn len(&self) -> usize {
        self.sink_len + self.win_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total_seen(&self) -> usize {
        self.total_seen
    }

    pub fn bytes(&self) -> usize {
        2 * self.buf * self.n_heads * self.head_dim * 4
    }

    pub fn pages(&self) -> usize {
        self.block.pages
    }

    /// Return this ring's pages to the pool (consumes the cache).
    pub fn free(self, pool: &mut KvPool) {
        pool.free(self.block);
    }

    fn floats(&self) -> usize {
        self.n_heads * self.buf * self.head_dim
    }

    /// Scatter one token's `(H*D)` k/v into buffer slot `slot`.
    fn write_slot(&mut self, pool: &mut KvPool, slot: usize, k_new: &[f32], v_new: &[f32]) {
        let (h, d) = (self.n_heads, self.head_dim);
        let buf = self.buf;
        let (kb, vb) = pool.kv_mut(self.block);
        for hh in 0..h {
            let dst = (hh * buf + slot) * d;
            kb[dst..dst + d].copy_from_slice(&k_new[hh * d..(hh + 1) * d]);
            vb[dst..dst + d].copy_from_slice(&v_new[hh * d..(hh + 1) * d]);
        }
    }

    /// Load from prefill outputs, keeping only sink + trailing window —
    /// the "fully bypassing full historical KV storage" step. Ring
    /// phases are primed exactly as if every prefill token had been
    /// appended one by one, so prefill+decode and pure-append histories
    /// produce identical buffers.
    pub fn load_prefill(&mut self, pool: &mut KvPool, k: &HostTensor, v: &HostTensor, valid: usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        let hd = h * d;
        let grab = |src: &HostTensor, t: usize| -> Vec<f32> {
            let mut out = vec![0.0; hd];
            for hh in 0..h {
                let s0 = (hh * s_in + t) * d;
                out[hh * d..(hh + 1) * d].copy_from_slice(&src.data[s0..s0 + d]);
            }
            out
        };
        {
            let (kb, vb) = pool.kv_mut(self.block);
            kb.fill(0.0);
            vb.fill(0.0);
        }
        self.sink_len = valid.min(self.sink);
        self.total_seen = valid;
        for t in 0..self.sink_len {
            let (kk, vv) = (grab(k, t), grab(v, t));
            self.write_slot(pool, t, &kk, &vv);
        }
        // trailing window: token t (t >= sink_len) is the
        // (t - sink_len)-th window append, so it lands on ring slot
        // sink_len + (t - sink_len) % local — same phase as append()
        let win_len = self.win_len();
        for t in (valid - win_len)..valid {
            let slot = self.sink_len + (t - self.sink_len) % self.local.max(1);
            let (kk, vv) = (grab(k, t), grab(v, t));
            self.write_slot(pool, slot, &kk, &vv);
        }
    }

    /// Ring-prime one prefill chunk (DESIGN.md §10): sequentially
    /// [`SparseCache::append`] the chunk's `(H, S_chunk, D)` k/v rows
    /// (first `valid` real). Appending chunk by chunk in prompt order
    /// leaves the ring in exactly the state a monolithic
    /// [`SparseCache::load_prefill`] of the concatenated prompt would —
    /// including the write-cursor phase across ring wraps (the
    /// load-prefill/append equivalence is pinned by
    /// `sparse_prefill_ring_phase_matches_appends_across_wrap`).
    pub fn append_prefill_chunk(
        &mut self,
        pool: &mut KvPool,
        k: &HostTensor,
        v: &HostTensor,
        valid: usize,
    ) {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.shape.len(), 3);
        assert_eq!(k.shape[0], h);
        assert_eq!(k.shape[2], d);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        let hd = h * d;
        let mut kk = vec![0.0; hd];
        let mut vv = vec![0.0; hd];
        for t in 0..valid {
            for hh in 0..h {
                let src = (hh * s_in + t) * d;
                kk[hh * d..(hh + 1) * d].copy_from_slice(&k.data[src..src + d]);
                vv[hh * d..(hh + 1) * d].copy_from_slice(&v.data[src..src + d]);
            }
            self.append(pool, &kk, &vv);
        }
    }

    /// Snapshot the ring's full `(H, SA_BUF, D)` region into a fresh
    /// pool block, returning it with the two cursor counters needed to
    /// resume appends (`sink_len`, `total_seen`). The prefix cache
    /// stores these because ring state at token P is not
    /// reconstructible later — the window has already overwritten
    /// older tokens in place.
    pub fn snapshot(&self, pool: &mut KvPool) -> Result<(PageBlock, usize, usize)> {
        let block = pool.alloc(self.floats())?;
        pool.copy_region(self.block, block, self.floats());
        Ok((block, self.sink_len, self.total_seen))
    }

    /// Restore a snapshot taken by [`SparseCache::snapshot`] into this
    /// same-geometry ring, leaving it bit-identical (contents and
    /// write-cursor phase) to the ring the snapshot was taken from.
    pub fn restore_snapshot(
        &mut self,
        pool: &mut KvPool,
        src: PageBlock,
        sink_len: usize,
        total_seen: usize,
    ) {
        pool.copy_region(src, self.block, self.floats());
        self.sink_len = sink_len;
        self.total_seen = total_seen;
    }

    /// Check this ring against a snapshot taken by
    /// [`SparseCache::snapshot`]: cursors equal and the `(H, SA_BUF, D)`
    /// regions bitwise identical. Preempt-and-resume uses this as a
    /// runtime integrity check — the teacher-forced catch-up must
    /// rebuild exactly the ring state that was snapshotted at
    /// preemption (DESIGN.md §15).
    pub fn matches_snapshot(
        &self,
        pool: &KvPool,
        block: PageBlock,
        sink_len: usize,
        total_seen: usize,
    ) -> bool {
        if self.sink_len != sink_len || self.total_seen != total_seen {
            return false;
        }
        let n = self.floats();
        pool.k_of(self.block)[..n] == pool.k_of(block)[..n]
            && pool.v_of(self.block)[..n] == pool.v_of(block)[..n]
    }

    /// Append one decoded token, overwriting the oldest window slot in
    /// place once the ring is full. Never allocates — the ring's pages
    /// are fixed at construction (this is the bounded-KV property that
    /// makes sparse layers cheap to admit).
    pub fn append(&mut self, pool: &mut KvPool, k_new: &[f32], v_new: &[f32]) {
        let hd = self.n_heads * self.head_dim;
        assert_eq!(k_new.len(), hd);
        if self.sink_len < self.sink {
            let slot = self.sink_len;
            self.write_slot(pool, slot, k_new, v_new);
            self.sink_len += 1;
        } else if self.local > 0 {
            let wa = self.total_seen - self.sink_len; // window appends so far
            let slot = self.sink_len + wa % self.local;
            self.write_slot(pool, slot, k_new, v_new);
        }
        self.total_seen += 1;
    }

    /// Zero-copy view of the `(H, SA_BUF, D)` pool region + valid length
    /// for the sparse-decode executable. Always available — the region
    /// is maintained in executable layout.
    pub fn view<'a>(&'a self, pool: &'a KvPool) -> (TensorView<'a>, TensorView<'a>, usize) {
        let n = self.floats();
        (
            TensorView { shape: &self.shape, data: &pool.k_of(self.block)[..n] },
            TensorView { shape: &self.shape, data: &pool.v_of(self.block)[..n] },
            self.len(),
        )
    }

    /// Owned copy of the `(H, SA_BUF, D)` tensor pair + valid length
    /// (callers that must outlive the pool borrow; the decode hot path
    /// uses [`SparseCache::view`] instead).
    pub fn as_tensors(&self, pool: &KvPool) -> (HostTensor, HostTensor, usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        let n = self.floats();
        (
            HostTensor::new(vec![h, self.buf, d], pool.k_of(self.block)[..n].to_vec()),
            HostTensor::new(vec![h, self.buf, d], pool.v_of(self.block)[..n].to_vec()),
            self.len(),
        )
    }
}

/// Per-layer cache: the routing decision selects the layout.
#[derive(Debug)]
pub enum LayerCache {
    Full(FullCache),
    Sparse(SparseCache),
}

impl LayerCache {
    pub fn len(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.len(),
            LayerCache::Sparse(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.bytes(),
            LayerCache::Sparse(c) => c.bytes(),
        }
    }

    pub fn pages(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.pages(),
            LayerCache::Sparse(c) => c.pages(),
        }
    }

    /// Return the cache's pages to the pool (retirement path — the
    /// tentpole's "retirement frees pages, not monoliths").
    pub fn free(self, pool: &mut KvPool) {
        match self {
            LayerCache::Full(c) => c.free(pool),
            LayerCache::Sparse(c) => c.free(pool),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small-page pool for unit tests: page = 4 floats so the odd
    /// capacities below exercise page rounding.
    fn pool() -> KvPool {
        KvPool::new(4, 4096)
    }

    fn ht(h: usize, s: usize, d: usize, f: impl Fn(usize, usize, usize) -> f32) -> HostTensor {
        let mut data = vec![0.0; h * s * d];
        for hh in 0..h {
            for t in 0..s {
                for dd in 0..d {
                    data[(hh * s + t) * d + dd] = f(hh, t, dd);
                }
            }
        }
        HostTensor::new(vec![h, s, d], data)
    }

    #[test]
    fn full_cache_prefill_then_append() {
        let mut p = pool();
        let mut c = FullCache::new(&mut p, 2, 4, 8).unwrap();
        let k = ht(2, 8, 4, |h, t, d| (h * 100 + t * 10 + d) as f32);
        let v = ht(2, 8, 4, |h, t, d| -((h * 100 + t * 10 + d) as f32));
        c.load_prefill(&mut p, &k, &v, 5).unwrap();
        assert_eq!(c.len(), 5);
        c.append(&mut p, &[1.0; 8], &[2.0; 8]).unwrap();
        assert_eq!(c.len(), 6);
        let (kt, _vt) = c.as_tensors(&p, 8);
        // head 0, token 3, dim 2 == 32
        assert_eq!(kt.data[(0 * 8 + 3) * 4 + 2], 32.0);
        // appended token at slot 5
        assert_eq!(kt.data[(0 * 8 + 5) * 4], 1.0);
        // padding after valid
        assert_eq!(kt.data[(0 * 8 + 6) * 4], 0.0);
    }

    #[test]
    fn full_cache_grows_buckets() {
        let mut p = pool();
        let mut c = FullCache::new(&mut p, 1, 2, 4).unwrap();
        for i in 0..10 {
            c.append(&mut p, &[i as f32, 0.0], &[0.0, i as f32]).unwrap();
        }
        assert_eq!(c.len(), 10);
        assert!(c.capacity() >= 10);
        let (kt, vt) = c.as_tensors(&p, 16);
        for i in 0..10 {
            assert_eq!(kt.data[i * 2], i as f32);
            assert_eq!(vt.data[i * 2 + 1], i as f32);
        }
    }

    #[test]
    fn sparse_cache_keeps_sink_and_window_only() {
        let mut p = pool();
        let sink = 2;
        let local = 3;
        let mut c = SparseCache::new(&mut p, 1, 1, sink, local, 8).unwrap();
        let k = ht(1, 16, 1, |_, t, _| t as f32);
        let v = ht(1, 16, 1, |_, t, _| t as f32 + 0.5);
        c.load_prefill(&mut p, &k, &v, 10);
        // sink = tokens 0,1; window = tokens 7,8,9 (ring-ordered: token
        // t lands on slot sink + (t - sink) % local)
        assert_eq!(c.len(), 5);
        assert_eq!(c.total_seen(), 10);
        let (kt, _, valid) = c.as_tensors(&p);
        assert_eq!(valid, 5);
        assert_eq!(&kt.data[..5], &[0.0, 1.0, 8.0, 9.0, 7.0]);
    }

    #[test]
    fn sparse_cache_window_eviction() {
        let mut p = pool();
        let mut c = SparseCache::new(&mut p, 1, 1, 1, 2, 4).unwrap();
        for i in 0..6 {
            c.append(&mut p, &[i as f32], &[i as f32]);
        }
        // sink token 0; window = last two tokens {4, 5} in ring order
        // (5th window append overwrote slot 1 in place)
        assert_eq!(c.len(), 3);
        assert_eq!(c.total_seen(), 6);
        let (kt, _, valid) = c.as_tensors(&p);
        assert_eq!(valid, 3);
        assert_eq!(&kt.data[..3], &[0.0, 5.0, 4.0]);
    }

    #[test]
    fn sparse_cache_bounded_memory() {
        let mut p = KvPool::new(128, 4096);
        let mut c = SparseCache::new(&mut p, 4, 32, 16, 128, 192).unwrap();
        let bytes0 = c.bytes();
        let pages0 = p.pages_allocated();
        for _ in 0..1000 {
            c.append(&mut p, &vec![0.0; 128], &vec![0.0; 128]);
        }
        assert_eq!(c.bytes(), bytes0, "sparse cache must be O(1) memory");
        assert_eq!(p.pages_allocated(), pages0, "ring must never allocate pages");
        assert!(c.len() <= 16 + 128);
    }

    #[test]
    fn views_alias_owned_tensors_bitwise() {
        let mut p = pool();
        let mut c = FullCache::new(&mut p, 2, 4, 8).unwrap();
        for i in 0..5 {
            c.append(&mut p, &vec![i as f32; 8], &vec![-(i as f32); 8]).unwrap();
        }
        let (kt, vt) = c.as_tensors(&p, 8);
        let (kv, vv) = c.view(&p);
        assert_eq!(kv.shape, kt.shape.as_slice());
        assert_eq!(kv.data, kt.data.as_slice());
        assert_eq!(vv.data, vt.data.as_slice());

        let mut s = SparseCache::new(&mut p, 2, 4, 1, 2, 4).unwrap();
        for i in 0..7 {
            s.append(&mut p, &vec![i as f32; 8], &vec![i as f32; 8]);
        }
        let (kt, vt, valid) = s.as_tensors(&p);
        let (kv, vv, valid2) = s.view(&p);
        assert_eq!(valid, valid2);
        assert_eq!(kv.shape, kt.shape.as_slice());
        assert_eq!(kv.data, kt.data.as_slice());
        assert_eq!(vv.data, vt.data.as_slice());
    }

    #[test]
    fn sparse_prefill_ring_phase_matches_appends_across_wrap() {
        // prefill(valid) must leave the ring in the exact state that
        // `valid` individual appends would — including the write-cursor
        // phase, so subsequent appends overwrite the same slots
        let mut p = pool();
        for valid in [1usize, 3, 4, 5, 7, 9, 12] {
            let (sink, local, buf) = (2usize, 3usize, 8usize);
            let data: Vec<f32> = (0..16).map(|t| t as f32).collect();
            let kt = HostTensor::new(vec![1, 16, 1], data);
            let mut by_prefill = SparseCache::new(&mut p, 1, 1, sink, local, buf).unwrap();
            by_prefill.load_prefill(&mut p, &kt, &kt.clone(), valid);
            let mut by_append = SparseCache::new(&mut p, 1, 1, sink, local, buf).unwrap();
            for t in 0..valid {
                by_append.append(&mut p, &[t as f32], &[t as f32]);
            }
            // continue appending past the wrap point on both
            for extra in 0..4 {
                let x = (100 + extra) as f32;
                by_prefill.append(&mut p, &[x], &[x]);
                by_append.append(&mut p, &[x], &[x]);
            }
            let (va, vp) = (by_append.len(), by_prefill.len());
            assert_eq!(va, vp, "valid mismatch at prefill len {valid}");
            {
                let (a, _, _) = by_append.view(&p);
                let (pp, _, _) = by_prefill.view(&p);
                assert_eq!(a.data, pp.data, "ring state mismatch at prefill len {valid}");
            }
            by_prefill.free(&mut p);
            by_append.free(&mut p);
        }
    }

    /// Chunked priming parity: appending a prompt's k/v chunk by chunk
    /// must leave both cache kinds bit-identical to one monolithic
    /// `load_prefill` of the whole prompt — including the sparse ring's
    /// write-cursor phase across wraps.
    #[test]
    fn chunked_priming_matches_monolithic_load_prefill() {
        let mut p = pool();
        let (h, d) = (2usize, 4usize);
        let s = 16usize;
        let k = ht(h, s, d, |hh, t, dd| (hh * 1000 + t * 10 + dd) as f32);
        let v = ht(h, s, d, |hh, t, dd| -((hh * 1000 + t * 10 + dd) as f32));
        for valid in [5usize, 11, 16] {
            for chunk in [1usize, 3, 4, 16] {
                // slice tokens base..base+n out of the (H, S, D) source
                let slice = |src: &HostTensor, base: usize, n: usize| {
                    let mut out = vec![0.0; h * n * d];
                    for hh in 0..h {
                        for t in 0..n {
                            let so = (hh * s + base + t) * d;
                            let dst = (hh * n + t) * d;
                            out[dst..dst + d].copy_from_slice(&src.data[so..so + d]);
                        }
                    }
                    HostTensor::new(vec![h, n, d], out)
                };

                let mut full_mono = FullCache::new(&mut p, h, d, s).unwrap();
                full_mono.load_prefill(&mut p, &k, &v, valid).unwrap();
                let mut full_chunked = FullCache::new(&mut p, h, d, s).unwrap();
                let mut sparse_mono = SparseCache::new(&mut p, h, d, 2, 3, 8).unwrap();
                sparse_mono.load_prefill(&mut p, &k, &v, valid);
                let mut sparse_chunked = SparseCache::new(&mut p, h, d, 2, 3, 8).unwrap();

                let mut base = 0;
                while base < valid {
                    let n = chunk.min(valid - base);
                    let (kc, vc) = (slice(&k, base, n), slice(&v, base, n));
                    full_chunked.append_prefill_chunk(&mut p, &kc, &vc, n).unwrap();
                    sparse_chunked.append_prefill_chunk(&mut p, &kc, &vc, n);
                    base += n;
                }

                assert_eq!(full_chunked.len(), full_mono.len());
                {
                    let (km, vm) = full_mono.view(&p);
                    let (kc2, vc2) = full_chunked.view(&p);
                    assert_eq!(km.data, kc2.data, "full k diverged (valid {valid} chunk {chunk})");
                    assert_eq!(vm.data, vc2.data, "full v diverged (valid {valid} chunk {chunk})");
                }

                // ring phase must match too: keep appending past the wrap
                for extra in 0..4 {
                    let x = vec![(200 + extra) as f32; h * d];
                    sparse_mono.append(&mut p, &x, &x);
                    sparse_chunked.append(&mut p, &x, &x);
                }
                {
                    let (km2, _, len_m) = sparse_mono.view(&p);
                    let (kc3, _, len_c) = sparse_chunked.view(&p);
                    assert_eq!(len_m, len_c);
                    assert_eq!(km2.data, kc3.data, "ring k diverged (valid {valid} chunk {chunk})");
                }
                full_mono.free(&mut p);
                full_chunked.free(&mut p);
                sparse_mono.free(&mut p);
                sparse_chunked.free(&mut p);
            }
        }
        assert_eq!(p.pages_allocated(), 0, "every cache freed its pages");
    }

    #[test]
    fn sparse_prefill_shorter_than_sink() {
        let mut p = pool();
        let mut c = SparseCache::new(&mut p, 1, 1, 4, 4, 16).unwrap();
        let k = ht(1, 8, 1, |_, t, _| t as f32);
        c.load_prefill(&mut p, &k, &k.clone(), 3);
        assert_eq!(c.len(), 3);
        // appends continue filling the sink region first
        c.append(&mut p, &[99.0], &[99.0]);
        assert_eq!(c.len(), 4);
        let (kt, _, valid) = c.as_tensors(&p);
        assert_eq!(valid, 4);
        assert_eq!(&kt.data[..4], &[0.0, 1.0, 2.0, 99.0]);
    }

    // --- pool-specific behaviour -------------------------------------

    #[test]
    fn pool_alloc_free_coalesce_and_reuse() {
        let mut p = KvPool::new(4, 16);
        let a = p.alloc(16).unwrap(); // 4 pages
        let b = p.alloc(8).unwrap(); // 2 pages
        let c = p.alloc(4).unwrap(); // 1 page
        assert_eq!(p.pages_allocated(), 7);
        assert_eq!(p.pages_peak(), 7);
        // free the middle run, then the first: they must coalesce into
        // one 6-page run that a later 6-page allocation can reuse
        p.free(b);
        p.free(a);
        assert_eq!(p.pages_allocated(), 1);
        let d = p.alloc(24).unwrap(); // 6 pages — fits only if coalesced
        assert_eq!(d.start, 0);
        assert_eq!(p.pages_allocated(), 7);
        assert_eq!(p.pages_peak(), 7, "peak is a high-water mark");
        p.free(c);
        p.free(d);
        assert_eq!(p.pages_allocated(), 0);
        assert_eq!(p.pages_free(), 16);
    }

    #[test]
    fn pool_reused_pages_are_zeroed() {
        let mut p = KvPool::new(4, 8);
        let a = p.alloc(8).unwrap();
        {
            let (kb, vb) = p.kv_mut(a);
            kb.fill(7.0);
            vb.fill(-7.0);
        }
        p.free(a);
        let b = p.alloc(8).unwrap();
        assert!(p.k_of(b).iter().all(|&x| x == 0.0), "reused pages must be zeroed");
        assert!(p.v_of(b).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pool_exhaustion_is_typed_and_recoverable() {
        let mut p = KvPool::new(4, 4);
        let a = p.alloc(12).unwrap(); // 3 of 4 pages
        let err = p.alloc(8).unwrap_err(); // needs 2, only 1 left
        assert!(err.to_string().contains("kv pool exhausted"), "{err}");
        // the failed allocation must not corrupt accounting
        assert_eq!(p.pages_allocated(), 3);
        p.free(a);
        assert!(p.alloc(16).is_ok(), "full budget available after free");
    }

    #[test]
    fn full_cache_growth_failure_preserves_contents() {
        // pool sized so the cache fits but its doubling does not
        let mut p = KvPool::new(2, 3);
        let mut c = FullCache::new(&mut p, 1, 1, 4).unwrap(); // 2 pages
        for i in 0..4 {
            c.append(&mut p, &[i as f32], &[10.0 + i as f32]).unwrap();
        }
        let err = c.append(&mut p, &[99.0], &[99.0]).unwrap_err();
        assert!(err.to_string().contains("kv pool exhausted"), "{err}");
        // cache survives bit-identical: same len, capacity and contents
        assert_eq!(c.len(), 4);
        assert_eq!(c.capacity(), 4);
        let (kt, vt) = c.as_tensors(&p, 4);
        assert_eq!(&kt.data[..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&vt.data[..4], &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(p.pages_allocated(), 2, "no pages leaked by the failed growth");
    }

    #[test]
    fn pool_retain_makes_free_refcounted() {
        let mut p = KvPool::new(4, 16);
        let a = p.alloc(16).unwrap(); // 4 pages
        p.retain(a);
        assert!(!p.free(a), "freeing a shared block only drops the reference");
        assert_eq!(p.pages_allocated(), 4, "pages survive while a reference remains");
        assert!(p.drained().is_err(), "strict drain sees retained pages as allocated");
        p.drained_with_retained(4).expect("index-retained pages are not a leak");
        assert!(p.free(a), "last free really returns the pages");
        assert_eq!(p.pages_allocated(), 0);
        p.drained().unwrap();
    }

    #[test]
    fn drained_with_retained_reports_leaks() {
        let mut p = KvPool::new(4, 16);
        let a = p.alloc(8).unwrap(); // 2 pages
        let err = p.drained_with_retained(1).unwrap_err();
        assert!(err.contains("leaked"), "{err}");
        p.free(a);
        p.drained_with_retained(1).unwrap_err();
        p.drained().unwrap();
    }

    #[test]
    fn copy_rows_moves_rows_between_pool_regions() {
        let mut p = KvPool::new(4, 64);
        let (h, d) = (2usize, 2usize);
        let src_cap = 8usize;
        let dst_cap = 6usize;
        let src = p.alloc(h * src_cap * d).unwrap();
        let dst = p.alloc(h * dst_cap * d).unwrap();
        {
            let (kb, vb) = p.kv_mut(src);
            for (i, x) in kb.iter_mut().enumerate() {
                *x = i as f32;
            }
            for (i, x) in vb.iter_mut().enumerate() {
                *x = -(i as f32);
            }
        }
        // rows 2..5 of src -> rows 1..4 of dst, per head
        p.copy_rows(src, src_cap, 2, dst, dst_cap, 1, 3, h, d);
        let kd = p.k_of(dst);
        let vd = p.v_of(dst);
        for hh in 0..h {
            for t in 0..3 {
                for dd in 0..d {
                    let want = ((hh * src_cap + 2 + t) * d + dd) as f32;
                    let got = kd[(hh * dst_cap + 1 + t) * d + dd];
                    assert_eq!(got, want, "k head {hh} row {t} dim {dd}");
                    assert_eq!(vd[(hh * dst_cap + 1 + t) * d + dd], -want);
                }
            }
        }
        // untouched destination rows stay zero
        assert_eq!(kd[0], 0.0);
        p.free(src);
        p.free(dst);
        p.drained().unwrap();
    }

    #[test]
    fn sparse_snapshot_restore_roundtrip() {
        let mut p = pool();
        let mut c = SparseCache::new(&mut p, 1, 1, 2, 3, 8).unwrap();
        for i in 0..7 {
            c.append(&mut p, &[i as f32], &[i as f32 + 0.5]);
        }
        let (snap, sink_len, total_seen) = c.snapshot(&mut p).unwrap();
        let mut c2 = SparseCache::new(&mut p, 1, 1, 2, 3, 8).unwrap();
        c2.restore_snapshot(&mut p, snap, sink_len, total_seen);
        // the restored ring must track the original under further
        // appends — contents AND write-cursor phase
        for i in 7..12 {
            c.append(&mut p, &[i as f32], &[i as f32 + 0.5]);
            c2.append(&mut p, &[i as f32], &[i as f32 + 0.5]);
        }
        assert_eq!(c.len(), c2.len());
        {
            let (ka, va, _) = c.view(&p);
            let (kb, vb, _) = c2.view(&p);
            assert_eq!(ka.data, kb.data);
            assert_eq!(va.data, vb.data);
        }
        p.free(snap);
        c.free(&mut p);
        c2.free(&mut p);
        p.drained().unwrap();
    }

    #[test]
    fn full_cache_primes_from_pool_segment() {
        let mut p = pool();
        let (h, d) = (2usize, 4usize);
        // donor: a staged prefix laid out (H, 8, D) with 6 valid rows
        let mut donor = FullCache::new(&mut p, h, d, 8).unwrap();
        let k = ht(h, 8, d, |hh, t, dd| (hh * 100 + t * 10 + dd) as f32);
        let v = ht(h, 8, d, |hh, t, dd| -((hh * 100 + t * 10 + dd) as f32));
        donor.load_prefill(&mut p, &k, &v, 6).unwrap();
        let (src, src_cap) = (donor.block, donor.capacity());
        // recipient primes rows [0..4) then appends one token
        let mut c = FullCache::new(&mut p, h, d, 8).unwrap();
        c.prime_from_pool(&mut p, src, src_cap, 0, 4);
        assert_eq!(c.len(), 4);
        c.append(&mut p, &[7.0; 8], &[8.0; 8]).unwrap();
        let (kt, vt) = c.as_tensors(&p, 8);
        // head 0, token 3, dim 2 == 32 came through the prime copy
        assert_eq!(kt.data[3 * 4 + 2], 32.0, "primed row survived");
        assert_eq!(kt.data[4 * 4], 7.0, "append lands after the primed rows");
        assert_eq!(vt.data[(8 + 2) * 4 + 1], -121.0, "head-1 primed row");
        donor.free(&mut p);
        c.free(&mut p);
        p.drained().unwrap();
    }

    #[test]
    fn fa_and_sa_share_one_budget() {
        // 6 pages of 4 floats: a (1,1)-head SA ring of buf 8 takes 2
        // pages, leaving 4 — a full cache of capacity 17 (5 pages) must
        // be refused while the ring holds its pages and admitted after
        let mut p = KvPool::new(4, 6);
        let ring = SparseCache::new(&mut p, 1, 1, 2, 3, 8).unwrap();
        assert!(FullCache::new(&mut p, 1, 1, 17).is_err());
        ring.free(&mut p);
        assert!(FullCache::new(&mut p, 1, 1, 17).is_ok());
    }
}
