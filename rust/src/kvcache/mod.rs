//! KV-cache manager: per-layer full caches (bucketed growth) and sparse
//! sink+local ring buffers (the paper's sparse-decode configuration,
//! section 3.3).
//!
//! Layout contract with the AOT decode executables:
//!   * full cache  -> `(H, K_bucket, D)` row-major, `valid_len` slots
//!     filled from the front;
//!   * sparse cache -> `(H, SA_BUF, D)` with the sink tokens first and
//!     the local window following in temporal order. Attention is a
//!     set operation (RoPE was applied at append time), so buffer order
//!     only has to be consistent, not positional.

use crate::runtime::HostTensor;

/// Full-history KV cache for one layer (FA / retrieval layers).
#[derive(Debug, Clone)]
pub struct FullCache {
    n_heads: usize,
    head_dim: usize,
    capacity: usize, // current bucket
    len: usize,
    k: Vec<f32>, // (H, capacity, D)
    v: Vec<f32>,
}

impl FullCache {
    pub fn new(n_heads: usize, head_dim: usize, capacity: usize) -> Self {
        Self {
            n_heads,
            head_dim,
            capacity,
            len: 0,
            k: vec![0.0; n_heads * capacity * head_dim],
            v: vec![0.0; n_heads * capacity * head_dim],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// KV bytes currently held (memory accounting for Table 1 notes).
    pub fn bytes(&self) -> usize {
        2 * self.n_heads * self.capacity * self.head_dim * 4
    }

    /// Bulk-load prefill outputs `k`, `v` shaped `(H, S_bucket, D)` of
    /// which the first `valid` columns are real tokens.
    pub fn load_prefill(&mut self, k: &HostTensor, v: &HostTensor, valid: usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.shape.len(), 3);
        assert_eq!(k.shape[0], h);
        assert_eq!(k.shape[2], d);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        self.ensure_capacity(valid);
        for hh in 0..h {
            for t in 0..valid {
                let src = (hh * s_in + t) * d;
                let dst = (hh * self.capacity + t) * d;
                self.k[dst..dst + d].copy_from_slice(&k.data[src..src + d]);
                self.v[dst..dst + d].copy_from_slice(&v.data[src..src + d]);
            }
        }
        self.len = valid;
    }

    /// Append one token's `(H, D)` k/v.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32]) {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k_new.len(), h * d);
        self.ensure_capacity(self.len + 1);
        for hh in 0..h {
            let dst = (hh * self.capacity + self.len) * d;
            self.k[dst..dst + d].copy_from_slice(&k_new[hh * d..(hh + 1) * d]);
            self.v[dst..dst + d].copy_from_slice(&v_new[hh * d..(hh + 1) * d]);
        }
        self.len += 1;
    }

    fn ensure_capacity(&mut self, need: usize) {
        if need <= self.capacity {
            return;
        }
        let mut cap = self.capacity.max(1);
        while cap < need {
            cap *= 2;
        }
        let (h, d) = (self.n_heads, self.head_dim);
        let mut k = vec![0.0; h * cap * d];
        let mut v = vec![0.0; h * cap * d];
        for hh in 0..h {
            for t in 0..self.len {
                let src = (hh * self.capacity + t) * d;
                let dst = (hh * cap + t) * d;
                k[dst..dst + d].copy_from_slice(&self.k[src..src + d]);
                v[dst..dst + d].copy_from_slice(&self.v[src..src + d]);
            }
        }
        self.k = k;
        self.v = v;
        self.capacity = cap;
    }

    /// Re-bucket into `(H, bucket, D)` tensors for the decode executable.
    ///
    /// Fast path for the decode hot loop: when the cache's internal
    /// capacity already equals the requested bucket (the common case —
    /// both are published decode buckets grown in lockstep, and
    /// [`crate::config::MetaConfig::decode_attend_bucket`] prefers the
    /// capacity exactly for this reason), the internal `(H, capacity, D)`
    /// buffers are already in executable layout and are cloned wholesale
    /// instead of re-laid-out per head (see EXPERIMENTS.md §Perf).
    pub fn as_tensors(&self, bucket: usize) -> (HostTensor, HostTensor) {
        assert!(bucket >= self.len, "bucket {bucket} < len {}", self.len);
        let (h, d) = (self.n_heads, self.head_dim);
        if bucket == self.capacity {
            return (
                HostTensor::new(vec![h, bucket, d], self.k.clone()),
                HostTensor::new(vec![h, bucket, d], self.v.clone()),
            );
        }
        let mut k = vec![0.0; h * bucket * d];
        let mut v = vec![0.0; h * bucket * d];
        for hh in 0..h {
            let src0 = hh * self.capacity * d;
            let dst0 = hh * bucket * d;
            let n = self.len * d;
            k[dst0..dst0 + n].copy_from_slice(&self.k[src0..src0 + n]);
            v[dst0..dst0 + n].copy_from_slice(&self.v[src0..src0 + n]);
        }
        (
            HostTensor::new(vec![h, bucket, d], k),
            HostTensor::new(vec![h, bucket, d], v),
        )
    }
}

/// Sink + local-window ring cache for sparse-decode layers. Holds at
/// most `sink + local + 1` tokens; the full history is never retained —
/// this is the paper's KV-memory reduction.
#[derive(Debug, Clone)]
pub struct SparseCache {
    n_heads: usize,
    head_dim: usize,
    sink: usize,
    local: usize,
    buf: usize,
    /// tokens stored: first `sink_len` are sink slots, the rest is the
    /// window oldest->newest; each entry is an (H*D) k vec + v vec
    sink_k: Vec<f32>,
    sink_v: Vec<f32>,
    sink_len: usize,
    win_k: std::collections::VecDeque<Vec<f32>>,
    win_v: std::collections::VecDeque<Vec<f32>>,
    total_seen: usize,
}

impl SparseCache {
    pub fn new(n_heads: usize, head_dim: usize, sink: usize, local: usize, buf: usize) -> Self {
        assert!(buf >= sink + local + 1);
        Self {
            n_heads,
            head_dim,
            sink,
            local,
            buf,
            sink_k: vec![0.0; sink * n_heads * head_dim],
            sink_v: vec![0.0; sink * n_heads * head_dim],
            sink_len: 0,
            win_k: Default::default(),
            win_v: Default::default(),
            total_seen: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.sink_len + self.win_k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total_seen(&self) -> usize {
        self.total_seen
    }

    pub fn bytes(&self) -> usize {
        2 * self.buf * self.n_heads * self.head_dim * 4
    }

    /// Load from prefill outputs, keeping only sink + trailing window —
    /// the "fully bypassing full historical KV storage" step.
    pub fn load_prefill(&mut self, k: &HostTensor, v: &HostTensor, valid: usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        let hd = h * d;
        let grab = |src: &HostTensor, t: usize| -> Vec<f32> {
            let mut out = vec![0.0; hd];
            for hh in 0..h {
                let s0 = (hh * s_in + t) * d;
                out[hh * d..(hh + 1) * d].copy_from_slice(&src.data[s0..s0 + d]);
            }
            out
        };
        self.sink_len = valid.min(self.sink);
        for t in 0..self.sink_len {
            let kk = grab(k, t);
            let vv = grab(v, t);
            self.sink_k[t * hd..(t + 1) * hd].copy_from_slice(&kk);
            self.sink_v[t * hd..(t + 1) * hd].copy_from_slice(&vv);
        }
        self.win_k.clear();
        self.win_v.clear();
        let win_start = valid.saturating_sub(self.local).max(self.sink_len);
        for t in win_start..valid {
            self.win_k.push_back(grab(k, t));
            self.win_v.push_back(grab(v, t));
        }
        self.total_seen = valid;
    }

    /// Append one decoded token, evicting the oldest window entry when
    /// the window exceeds `local`.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32]) {
        let hd = self.n_heads * self.head_dim;
        assert_eq!(k_new.len(), hd);
        if self.sink_len < self.sink {
            let t = self.sink_len;
            self.sink_k[t * hd..(t + 1) * hd].copy_from_slice(k_new);
            self.sink_v[t * hd..(t + 1) * hd].copy_from_slice(v_new);
            self.sink_len += 1;
        } else {
            self.win_k.push_back(k_new.to_vec());
            self.win_v.push_back(v_new.to_vec());
            if self.win_k.len() > self.local {
                self.win_k.pop_front();
                self.win_v.pop_front();
            }
        }
        self.total_seen += 1;
    }

    /// Compact into the `(H, SA_BUF, D)` tensor pair + valid length for
    /// the sparse-decode executable.
    pub fn as_tensors(&self) -> (HostTensor, HostTensor, usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        let hd = h * d;
        let valid = self.len();
        let mut k = vec![0.0; h * self.buf * d];
        let mut v = vec![0.0; h * self.buf * d];
        let write = |slot: usize, kk: &[f32], vv: &[f32], k: &mut [f32], v: &mut [f32]| {
            for hh in 0..h {
                let dst = (hh * self.buf + slot) * d;
                k[dst..dst + d].copy_from_slice(&kk[hh * d..(hh + 1) * d]);
                v[dst..dst + d].copy_from_slice(&vv[hh * d..(hh + 1) * d]);
            }
        };
        for t in 0..self.sink_len {
            let kk = &self.sink_k[t * hd..(t + 1) * hd];
            let vv = &self.sink_v[t * hd..(t + 1) * hd];
            write(t, kk, vv, &mut k, &mut v);
        }
        for (i, (kk, vv)) in self.win_k.iter().zip(&self.win_v).enumerate() {
            write(self.sink_len + i, kk, vv, &mut k, &mut v);
        }
        (
            HostTensor::new(vec![h, self.buf, d], k),
            HostTensor::new(vec![h, self.buf, d], v),
            valid,
        )
    }
}

/// Per-layer cache: the routing decision selects the layout.
#[derive(Debug, Clone)]
pub enum LayerCache {
    Full(FullCache),
    Sparse(SparseCache),
}

impl LayerCache {
    pub fn len(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.len(),
            LayerCache::Sparse(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.bytes(),
            LayerCache::Sparse(c) => c.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ht(h: usize, s: usize, d: usize, f: impl Fn(usize, usize, usize) -> f32) -> HostTensor {
        let mut data = vec![0.0; h * s * d];
        for hh in 0..h {
            for t in 0..s {
                for dd in 0..d {
                    data[(hh * s + t) * d + dd] = f(hh, t, dd);
                }
            }
        }
        HostTensor::new(vec![h, s, d], data)
    }

    #[test]
    fn full_cache_prefill_then_append() {
        let mut c = FullCache::new(2, 4, 8);
        let k = ht(2, 8, 4, |h, t, d| (h * 100 + t * 10 + d) as f32);
        let v = ht(2, 8, 4, |h, t, d| -((h * 100 + t * 10 + d) as f32));
        c.load_prefill(&k, &v, 5);
        assert_eq!(c.len(), 5);
        c.append(&[1.0; 8], &[2.0; 8]);
        assert_eq!(c.len(), 6);
        let (kt, _vt) = c.as_tensors(8);
        // head 0, token 3, dim 2 == 32
        assert_eq!(kt.data[(0 * 8 + 3) * 4 + 2], 32.0);
        // appended token at slot 5
        assert_eq!(kt.data[(0 * 8 + 5) * 4], 1.0);
        // padding after valid
        assert_eq!(kt.data[(0 * 8 + 6) * 4], 0.0);
    }

    #[test]
    fn full_cache_grows_buckets() {
        let mut c = FullCache::new(1, 2, 4);
        for i in 0..10 {
            c.append(&[i as f32, 0.0], &[0.0, i as f32]);
        }
        assert_eq!(c.len(), 10);
        assert!(c.capacity() >= 10);
        let (kt, vt) = c.as_tensors(16);
        for i in 0..10 {
            assert_eq!(kt.data[i * 2], i as f32);
            assert_eq!(vt.data[i * 2 + 1], i as f32);
        }
    }

    #[test]
    fn sparse_cache_keeps_sink_and_window_only() {
        let sink = 2;
        let local = 3;
        let mut c = SparseCache::new(1, 1, sink, local, 8);
        let k = ht(1, 16, 1, |_, t, _| t as f32);
        let v = ht(1, 16, 1, |_, t, _| t as f32 + 0.5);
        c.load_prefill(&k, &v, 10);
        // sink = tokens 0,1; window = tokens 7,8,9
        assert_eq!(c.len(), 5);
        assert_eq!(c.total_seen(), 10);
        let (kt, _, valid) = c.as_tensors();
        assert_eq!(valid, 5);
        assert_eq!(&kt.data[..5], &[0.0, 1.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn sparse_cache_window_eviction() {
        let mut c = SparseCache::new(1, 1, 1, 2, 4);
        for i in 0..6 {
            c.append(&[i as f32], &[i as f32]);
        }
        // sink token 0; window = last two tokens (4, 5)
        assert_eq!(c.len(), 3);
        assert_eq!(c.total_seen(), 6);
        let (kt, _, valid) = c.as_tensors();
        assert_eq!(valid, 3);
        assert_eq!(&kt.data[..3], &[0.0, 4.0, 5.0]);
    }

    #[test]
    fn sparse_cache_bounded_memory() {
        let mut c = SparseCache::new(4, 32, 16, 128, 192);
        let bytes0 = c.bytes();
        for _ in 0..1000 {
            c.append(&vec![0.0; 128], &vec![0.0; 128]);
        }
        assert_eq!(c.bytes(), bytes0, "sparse cache must be O(1) memory");
        assert!(c.len() <= 16 + 128);
    }

    #[test]
    fn sparse_prefill_shorter_than_sink() {
        let mut c = SparseCache::new(1, 1, 4, 4, 16);
        let k = ht(1, 8, 1, |_, t, _| t as f32);
        c.load_prefill(&k, &k.clone(), 3);
        assert_eq!(c.len(), 3);
        // appends continue filling the sink region first
        c.append(&[99.0], &[99.0]);
        assert_eq!(c.len(), 4);
        let (kt, _, valid) = c.as_tensors();
        assert_eq!(valid, 4);
        assert_eq!(&kt.data[..4], &[0.0, 1.0, 2.0, 99.0]);
    }
}
