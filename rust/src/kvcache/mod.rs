//! KV-cache manager: per-layer full caches (bucketed growth) and sparse
//! sink+local ring buffers (the paper's sparse-decode configuration,
//! section 3.3).
//!
//! Layout contract with the AOT decode executables:
//!   * full cache  -> `(H, K_bucket, D)` row-major, `valid_len` slots
//!     filled from the front;
//!   * sparse cache -> `(H, SA_BUF, D)` with the sink tokens first and
//!     the local window following as a ring (oldest entry overwritten in
//!     place). Attention is a set operation (RoPE was applied at append
//!     time), so buffer order only has to be consistent, not positional.
//!
//! Both caches keep their internal buffers *in executable layout* and
//! hand out zero-copy [`TensorView`]s for the decode hot path: a decode
//! step stages its KV arguments without cloning whenever the full
//! cache's capacity is a published bucket (the common case — capacities
//! and buckets grow in lockstep), and always for the sparse ring.
//!
//! Because every request owns its own cache objects, a batched decode
//! round (DESIGN.md §9) stages many requests' views into ONE
//! `attend_batch_{fa,sa}` call simultaneously — the borrows are
//! per-cache, so multi-request staging needs no copying or locking, and
//! per-request bucket sizes may differ within the same call (the view's
//! shape carries the bucket).

use crate::runtime::{HostTensor, TensorView};

/// Full-history KV cache for one layer (FA / retrieval layers).
#[derive(Debug, Clone)]
pub struct FullCache {
    n_heads: usize,
    head_dim: usize,
    capacity: usize, // current bucket
    len: usize,
    /// executable-layout shape `[H, capacity, D]`, kept in sync with
    /// `capacity` so [`FullCache::view`] can borrow it
    shape: [usize; 3],
    k: Vec<f32>, // (H, capacity, D)
    v: Vec<f32>,
}

impl FullCache {
    pub fn new(n_heads: usize, head_dim: usize, capacity: usize) -> Self {
        Self {
            n_heads,
            head_dim,
            capacity,
            len: 0,
            shape: [n_heads, capacity, head_dim],
            k: vec![0.0; n_heads * capacity * head_dim],
            v: vec![0.0; n_heads * capacity * head_dim],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// KV bytes currently held (memory accounting for Table 1 notes).
    pub fn bytes(&self) -> usize {
        2 * self.n_heads * self.capacity * self.head_dim * 4
    }

    /// Bulk-load prefill outputs `k`, `v` shaped `(H, S_bucket, D)` of
    /// which the first `valid` columns are real tokens — exactly one
    /// whole-prompt [`FullCache::append_prefill_chunk`] from empty.
    pub fn load_prefill(&mut self, k: &HostTensor, v: &HostTensor, valid: usize) {
        self.len = 0;
        self.append_prefill_chunk(k, v, valid);
    }

    /// Append one token's `(H, D)` k/v.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32]) {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k_new.len(), h * d);
        self.ensure_capacity(self.len + 1);
        for hh in 0..h {
            let dst = (hh * self.capacity + self.len) * d;
            self.k[dst..dst + d].copy_from_slice(&k_new[hh * d..(hh + 1) * d]);
            self.v[dst..dst + d].copy_from_slice(&v_new[hh * d..(hh + 1) * d]);
        }
        self.len += 1;
    }

    /// Append-at-offset priming for chunked prefill (DESIGN.md §10):
    /// bulk-append a chunk's `(H, S_chunk, D)` k/v outputs (first
    /// `valid` rows real) at the current length, leaving the buffer
    /// bit-identical to a monolithic [`FullCache::load_prefill`] of the
    /// concatenated prompt — the staged prefix later chunks attend over
    /// through [`FullCache::view`] with zero copies.
    pub fn append_prefill_chunk(&mut self, k: &HostTensor, v: &HostTensor, valid: usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.shape.len(), 3);
        assert_eq!(k.shape[0], h);
        assert_eq!(k.shape[2], d);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        self.ensure_capacity(self.len + valid);
        for hh in 0..h {
            for t in 0..valid {
                let src = (hh * s_in + t) * d;
                let dst = (hh * self.capacity + self.len + t) * d;
                self.k[dst..dst + d].copy_from_slice(&k.data[src..src + d]);
                self.v[dst..dst + d].copy_from_slice(&v.data[src..src + d]);
            }
        }
        self.len += valid;
    }

    fn ensure_capacity(&mut self, need: usize) {
        if need <= self.capacity {
            return;
        }
        let mut cap = self.capacity.max(1);
        while cap < need {
            cap *= 2;
        }
        let (h, d) = (self.n_heads, self.head_dim);
        let mut k = vec![0.0; h * cap * d];
        let mut v = vec![0.0; h * cap * d];
        for hh in 0..h {
            for t in 0..self.len {
                let src = (hh * self.capacity + t) * d;
                let dst = (hh * cap + t) * d;
                k[dst..dst + d].copy_from_slice(&self.k[src..src + d]);
                v[dst..dst + d].copy_from_slice(&self.v[src..src + d]);
            }
        }
        self.k = k;
        self.v = v;
        self.capacity = cap;
        self.shape = [h, cap, d];
    }

    /// Zero-copy view of the internal `(H, capacity, D)` buffers. Valid
    /// as decode-executable arguments only when the capacity equals the
    /// selected bucket — [`crate::config::MetaConfig::decode_attend_bucket`]
    /// prefers the capacity exactly so this is the decode fast path.
    pub fn view(&self) -> (TensorView<'_>, TensorView<'_>) {
        (
            TensorView { shape: &self.shape, data: &self.k },
            TensorView { shape: &self.shape, data: &self.v },
        )
    }

    /// Re-bucket into `(H, bucket, D)` tensors for the decode executable.
    ///
    /// Fast path for the decode hot loop: when the cache's internal
    /// capacity already equals the requested bucket (the common case —
    /// both are published decode buckets grown in lockstep, and
    /// [`crate::config::MetaConfig::decode_attend_bucket`] prefers the
    /// capacity exactly for this reason), the internal `(H, capacity, D)`
    /// buffers are already in executable layout and are cloned wholesale
    /// instead of re-laid-out per head (see EXPERIMENTS.md §Perf).
    pub fn as_tensors(&self, bucket: usize) -> (HostTensor, HostTensor) {
        assert!(bucket >= self.len, "bucket {bucket} < len {}", self.len);
        let (h, d) = (self.n_heads, self.head_dim);
        if bucket == self.capacity {
            return (
                HostTensor::new(vec![h, bucket, d], self.k.clone()),
                HostTensor::new(vec![h, bucket, d], self.v.clone()),
            );
        }
        let mut k = vec![0.0; h * bucket * d];
        let mut v = vec![0.0; h * bucket * d];
        for hh in 0..h {
            let src0 = hh * self.capacity * d;
            let dst0 = hh * bucket * d;
            let n = self.len * d;
            k[dst0..dst0 + n].copy_from_slice(&self.k[src0..src0 + n]);
            v[dst0..dst0 + n].copy_from_slice(&self.v[src0..src0 + n]);
        }
        (
            HostTensor::new(vec![h, bucket, d], k),
            HostTensor::new(vec![h, bucket, d], v),
        )
    }
}

/// Sink + local-window ring cache for sparse-decode layers. Holds at
/// most `sink + local` live tokens; the full history is never retained —
/// this is the paper's KV-memory reduction.
///
/// The backing store IS the executable layout: one `(H, SA_BUF, D)`
/// buffer pair, incrementally maintained on `append` (the window region
/// is a true ring — the oldest entry is overwritten in place, O(H·D)
/// per token instead of the old O(H·SA_BUF·D) re-assembly), so decode
/// reads it through [`SparseCache::view`] with zero copies. Slot layout:
/// sink tokens occupy slots `0..sink_len`; the window occupies slots
/// `sink_len..sink_len+win_len` with the write cursor cycling through
/// them. Ring order is deterministic in the append history, and the
/// attention executable treats the buffer as a set, so this is exact.
#[derive(Debug, Clone)]
pub struct SparseCache {
    n_heads: usize,
    head_dim: usize,
    sink: usize,
    local: usize,
    buf: usize,
    /// executable-layout shape `[H, SA_BUF, D]` (borrowed by `view`)
    shape: [usize; 3],
    sink_len: usize,
    total_seen: usize,
    k: Vec<f32>, // (H, buf, D)
    v: Vec<f32>,
}

impl SparseCache {
    pub fn new(n_heads: usize, head_dim: usize, sink: usize, local: usize, buf: usize) -> Self {
        assert!(buf >= sink + local + 1);
        Self {
            n_heads,
            head_dim,
            sink,
            local,
            buf,
            shape: [n_heads, buf, head_dim],
            sink_len: 0,
            total_seen: 0,
            k: vec![0.0; n_heads * buf * head_dim],
            v: vec![0.0; n_heads * buf * head_dim],
        }
    }

    /// Window entries currently live (tokens appended past the sink,
    /// capped by the ring size).
    fn win_len(&self) -> usize {
        (self.total_seen - self.sink_len).min(self.local)
    }

    pub fn len(&self) -> usize {
        self.sink_len + self.win_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total_seen(&self) -> usize {
        self.total_seen
    }

    pub fn bytes(&self) -> usize {
        2 * self.buf * self.n_heads * self.head_dim * 4
    }

    /// Scatter one token's `(H*D)` k/v into buffer slot `slot`.
    fn write_slot(&mut self, slot: usize, k_new: &[f32], v_new: &[f32]) {
        let (h, d) = (self.n_heads, self.head_dim);
        for hh in 0..h {
            let dst = (hh * self.buf + slot) * d;
            self.k[dst..dst + d].copy_from_slice(&k_new[hh * d..(hh + 1) * d]);
            self.v[dst..dst + d].copy_from_slice(&v_new[hh * d..(hh + 1) * d]);
        }
    }

    /// Load from prefill outputs, keeping only sink + trailing window —
    /// the "fully bypassing full historical KV storage" step. Ring
    /// phases are primed exactly as if every prefill token had been
    /// appended one by one, so prefill+decode and pure-append histories
    /// produce identical buffers.
    pub fn load_prefill(&mut self, k: &HostTensor, v: &HostTensor, valid: usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        let hd = h * d;
        let grab = |src: &HostTensor, t: usize| -> Vec<f32> {
            let mut out = vec![0.0; hd];
            for hh in 0..h {
                let s0 = (hh * s_in + t) * d;
                out[hh * d..(hh + 1) * d].copy_from_slice(&src.data[s0..s0 + d]);
            }
            out
        };
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.sink_len = valid.min(self.sink);
        self.total_seen = valid;
        for t in 0..self.sink_len {
            let (kk, vv) = (grab(k, t), grab(v, t));
            self.write_slot(t, &kk, &vv);
        }
        // trailing window: token t (t >= sink_len) is the
        // (t - sink_len)-th window append, so it lands on ring slot
        // sink_len + (t - sink_len) % local — same phase as append()
        let win_len = self.win_len();
        for t in (valid - win_len)..valid {
            let slot = self.sink_len + (t - self.sink_len) % self.local.max(1);
            let (kk, vv) = (grab(k, t), grab(v, t));
            self.write_slot(slot, &kk, &vv);
        }
    }

    /// Ring-prime one prefill chunk (DESIGN.md §10): sequentially
    /// [`SparseCache::append`] the chunk's `(H, S_chunk, D)` k/v rows
    /// (first `valid` real). Appending chunk by chunk in prompt order
    /// leaves the ring in exactly the state a monolithic
    /// [`SparseCache::load_prefill`] of the concatenated prompt would —
    /// including the write-cursor phase across ring wraps (the
    /// load-prefill/append equivalence is pinned by
    /// `sparse_prefill_ring_phase_matches_appends_across_wrap`).
    pub fn append_prefill_chunk(&mut self, k: &HostTensor, v: &HostTensor, valid: usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.shape.len(), 3);
        assert_eq!(k.shape[0], h);
        assert_eq!(k.shape[2], d);
        let s_in = k.shape[1];
        assert!(valid <= s_in);
        let hd = h * d;
        let mut kk = vec![0.0; hd];
        let mut vv = vec![0.0; hd];
        for t in 0..valid {
            for hh in 0..h {
                let src = (hh * s_in + t) * d;
                kk[hh * d..(hh + 1) * d].copy_from_slice(&k.data[src..src + d]);
                vv[hh * d..(hh + 1) * d].copy_from_slice(&v.data[src..src + d]);
            }
            self.append(&kk, &vv);
        }
    }

    /// Append one decoded token, overwriting the oldest window slot in
    /// place once the ring is full.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32]) {
        let hd = self.n_heads * self.head_dim;
        assert_eq!(k_new.len(), hd);
        if self.sink_len < self.sink {
            let slot = self.sink_len;
            self.write_slot(slot, k_new, v_new);
            self.sink_len += 1;
        } else if self.local > 0 {
            let wa = self.total_seen - self.sink_len; // window appends so far
            let slot = self.sink_len + wa % self.local;
            self.write_slot(slot, k_new, v_new);
        }
        self.total_seen += 1;
    }

    /// Zero-copy view of the `(H, SA_BUF, D)` buffers + valid length for
    /// the sparse-decode executable. Always available — the internal
    /// buffer is maintained in executable layout.
    pub fn view(&self) -> (TensorView<'_>, TensorView<'_>, usize) {
        (
            TensorView { shape: &self.shape, data: &self.k },
            TensorView { shape: &self.shape, data: &self.v },
            self.len(),
        )
    }

    /// Owned copy of the `(H, SA_BUF, D)` tensor pair + valid length
    /// (callers that must outlive the cache borrow; the decode hot path
    /// uses [`SparseCache::view`] instead).
    pub fn as_tensors(&self) -> (HostTensor, HostTensor, usize) {
        let (h, d) = (self.n_heads, self.head_dim);
        (
            HostTensor::new(vec![h, self.buf, d], self.k.clone()),
            HostTensor::new(vec![h, self.buf, d], self.v.clone()),
            self.len(),
        )
    }
}

/// Per-layer cache: the routing decision selects the layout.
#[derive(Debug, Clone)]
pub enum LayerCache {
    Full(FullCache),
    Sparse(SparseCache),
}

impl LayerCache {
    pub fn len(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.len(),
            LayerCache::Sparse(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        match self {
            LayerCache::Full(c) => c.bytes(),
            LayerCache::Sparse(c) => c.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ht(h: usize, s: usize, d: usize, f: impl Fn(usize, usize, usize) -> f32) -> HostTensor {
        let mut data = vec![0.0; h * s * d];
        for hh in 0..h {
            for t in 0..s {
                for dd in 0..d {
                    data[(hh * s + t) * d + dd] = f(hh, t, dd);
                }
            }
        }
        HostTensor::new(vec![h, s, d], data)
    }

    #[test]
    fn full_cache_prefill_then_append() {
        let mut c = FullCache::new(2, 4, 8);
        let k = ht(2, 8, 4, |h, t, d| (h * 100 + t * 10 + d) as f32);
        let v = ht(2, 8, 4, |h, t, d| -((h * 100 + t * 10 + d) as f32));
        c.load_prefill(&k, &v, 5);
        assert_eq!(c.len(), 5);
        c.append(&[1.0; 8], &[2.0; 8]);
        assert_eq!(c.len(), 6);
        let (kt, _vt) = c.as_tensors(8);
        // head 0, token 3, dim 2 == 32
        assert_eq!(kt.data[(0 * 8 + 3) * 4 + 2], 32.0);
        // appended token at slot 5
        assert_eq!(kt.data[(0 * 8 + 5) * 4], 1.0);
        // padding after valid
        assert_eq!(kt.data[(0 * 8 + 6) * 4], 0.0);
    }

    #[test]
    fn full_cache_grows_buckets() {
        let mut c = FullCache::new(1, 2, 4);
        for i in 0..10 {
            c.append(&[i as f32, 0.0], &[0.0, i as f32]);
        }
        assert_eq!(c.len(), 10);
        assert!(c.capacity() >= 10);
        let (kt, vt) = c.as_tensors(16);
        for i in 0..10 {
            assert_eq!(kt.data[i * 2], i as f32);
            assert_eq!(vt.data[i * 2 + 1], i as f32);
        }
    }

    #[test]
    fn sparse_cache_keeps_sink_and_window_only() {
        let sink = 2;
        let local = 3;
        let mut c = SparseCache::new(1, 1, sink, local, 8);
        let k = ht(1, 16, 1, |_, t, _| t as f32);
        let v = ht(1, 16, 1, |_, t, _| t as f32 + 0.5);
        c.load_prefill(&k, &v, 10);
        // sink = tokens 0,1; window = tokens 7,8,9 (ring-ordered: token
        // t lands on slot sink + (t - sink) % local)
        assert_eq!(c.len(), 5);
        assert_eq!(c.total_seen(), 10);
        let (kt, _, valid) = c.as_tensors();
        assert_eq!(valid, 5);
        assert_eq!(&kt.data[..5], &[0.0, 1.0, 8.0, 9.0, 7.0]);
    }

    #[test]
    fn sparse_cache_window_eviction() {
        let mut c = SparseCache::new(1, 1, 1, 2, 4);
        for i in 0..6 {
            c.append(&[i as f32], &[i as f32]);
        }
        // sink token 0; window = last two tokens {4, 5} in ring order
        // (5th window append overwrote slot 1 in place)
        assert_eq!(c.len(), 3);
        assert_eq!(c.total_seen(), 6);
        let (kt, _, valid) = c.as_tensors();
        assert_eq!(valid, 3);
        assert_eq!(&kt.data[..3], &[0.0, 5.0, 4.0]);
    }

    #[test]
    fn sparse_cache_bounded_memory() {
        let mut c = SparseCache::new(4, 32, 16, 128, 192);
        let bytes0 = c.bytes();
        for _ in 0..1000 {
            c.append(&vec![0.0; 128], &vec![0.0; 128]);
        }
        assert_eq!(c.bytes(), bytes0, "sparse cache must be O(1) memory");
        assert!(c.len() <= 16 + 128);
    }

    #[test]
    fn views_alias_owned_tensors_bitwise() {
        let mut c = FullCache::new(2, 4, 8);
        for i in 0..5 {
            c.append(&vec![i as f32; 8], &vec![-(i as f32); 8]);
        }
        let (kt, vt) = c.as_tensors(8);
        let (kv, vv) = c.view();
        assert_eq!(kv.shape, kt.shape.as_slice());
        assert_eq!(kv.data, kt.data.as_slice());
        assert_eq!(vv.data, vt.data.as_slice());

        let mut s = SparseCache::new(2, 4, 1, 2, 4);
        for i in 0..7 {
            s.append(&vec![i as f32; 8], &vec![i as f32; 8]);
        }
        let (kt, vt, valid) = s.as_tensors();
        let (kv, vv, valid2) = s.view();
        assert_eq!(valid, valid2);
        assert_eq!(kv.shape, kt.shape.as_slice());
        assert_eq!(kv.data, kt.data.as_slice());
        assert_eq!(vv.data, vt.data.as_slice());
    }

    #[test]
    fn sparse_prefill_ring_phase_matches_appends_across_wrap() {
        // prefill(valid) must leave the ring in the exact state that
        // `valid` individual appends would — including the write-cursor
        // phase, so subsequent appends overwrite the same slots
        for valid in [1usize, 3, 4, 5, 7, 9, 12] {
            let (sink, local, buf) = (2usize, 3usize, 8usize);
            let data: Vec<f32> = (0..16).map(|t| t as f32).collect();
            let kt = HostTensor::new(vec![1, 16, 1], data);
            let mut by_prefill = SparseCache::new(1, 1, sink, local, buf);
            by_prefill.load_prefill(&kt, &kt.clone(), valid);
            let mut by_append = SparseCache::new(1, 1, sink, local, buf);
            for t in 0..valid {
                by_append.append(&[t as f32], &[t as f32]);
            }
            // continue appending past the wrap point on both
            for extra in 0..4 {
                let x = (100 + extra) as f32;
                by_prefill.append(&[x], &[x]);
                by_append.append(&[x], &[x]);
            }
            let (a, _, va) = by_append.view();
            let (p, _, vp) = by_prefill.view();
            assert_eq!(va, vp, "valid mismatch at prefill len {valid}");
            assert_eq!(a.data, p.data, "ring state mismatch at prefill len {valid}");
        }
    }

    /// Chunked priming parity: appending a prompt's k/v chunk by chunk
    /// must leave both cache kinds bit-identical to one monolithic
    /// `load_prefill` of the whole prompt — including the sparse ring's
    /// write-cursor phase across wraps.
    #[test]
    fn chunked_priming_matches_monolithic_load_prefill() {
        let (h, d) = (2usize, 4usize);
        let s = 16usize;
        let k = ht(h, s, d, |hh, t, dd| (hh * 1000 + t * 10 + dd) as f32);
        let v = ht(h, s, d, |hh, t, dd| -((hh * 1000 + t * 10 + dd) as f32));
        for valid in [5usize, 11, 16] {
            for chunk in [1usize, 3, 4, 16] {
                // slice tokens base..base+n out of the (H, S, D) source
                let slice = |src: &HostTensor, base: usize, n: usize| {
                    let mut out = vec![0.0; h * n * d];
                    for hh in 0..h {
                        for t in 0..n {
                            let so = (hh * s + base + t) * d;
                            let dst = (hh * n + t) * d;
                            out[dst..dst + d].copy_from_slice(&src.data[so..so + d]);
                        }
                    }
                    HostTensor::new(vec![h, n, d], out)
                };

                let mut full_mono = FullCache::new(h, d, s);
                full_mono.load_prefill(&k, &v, valid);
                let mut full_chunked = FullCache::new(h, d, s);
                let mut sparse_mono = SparseCache::new(h, d, 2, 3, 8);
                sparse_mono.load_prefill(&k, &v, valid);
                let mut sparse_chunked = SparseCache::new(h, d, 2, 3, 8);

                let mut base = 0;
                while base < valid {
                    let n = chunk.min(valid - base);
                    let (kc, vc) = (slice(&k, base, n), slice(&v, base, n));
                    full_chunked.append_prefill_chunk(&kc, &vc, n);
                    sparse_chunked.append_prefill_chunk(&kc, &vc, n);
                    base += n;
                }

                assert_eq!(full_chunked.len(), full_mono.len());
                let (km, vm) = full_mono.view();
                let (kc2, vc2) = full_chunked.view();
                assert_eq!(km.data, kc2.data, "full k diverged (valid {valid} chunk {chunk})");
                assert_eq!(vm.data, vc2.data, "full v diverged (valid {valid} chunk {chunk})");

                // ring phase must match too: keep appending past the wrap
                for extra in 0..4 {
                    let x = vec![(200 + extra) as f32; h * d];
                    sparse_mono.append(&x, &x);
                    sparse_chunked.append(&x, &x);
                }
                let (km2, vm2, len_m) = sparse_mono.view();
                let (kc3, vc3, len_c) = sparse_chunked.view();
                assert_eq!(len_m, len_c);
                assert_eq!(km2.data, kc3.data, "ring k diverged (valid {valid} chunk {chunk})");
                assert_eq!(vm2.data, vc3.data, "ring v diverged (valid {valid} chunk {chunk})");
            }
        }
    }

    #[test]
    fn sparse_prefill_shorter_than_sink() {
        let mut c = SparseCache::new(1, 1, 4, 4, 16);
        let k = ht(1, 8, 1, |_, t, _| t as f32);
        c.load_prefill(&k, &k.clone(), 3);
        assert_eq!(c.len(), 3);
        // appends continue filling the sink region first
        c.append(&[99.0], &[99.0]);
        assert_eq!(c.len(), 4);
        let (kt, _, valid) = c.as_tensors();
        assert_eq!(valid, 4);
        assert_eq!(&kt.data[..4], &[0.0, 1.0, 2.0, 99.0]);
    }
}
