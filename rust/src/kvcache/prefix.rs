//! Cross-request radix prefix cache over the paged [`KvPool`]
//! (DESIGN.md §13, ROADMAP item 2).
//!
//! A radix tree keyed on token-id sequences: each node owns page-aligned
//! runs of pool pages holding the KV rows its edge contributed, one
//! [`Seg`] per layer, plus the per-layer route the rows were computed
//! under. Admission matches the longest cached prefix ([`PrefixCache::
//! acquire`]), pins the endpoint and primes request staging from the
//! path's segments; retirement inserts the completed page-aligned
//! prompt prefix ([`PrefixCache::insert`]), splitting nodes at page
//! boundaries so divergent prompts share the common run via
//! [`KvPool::retain`] refcounts.
//!
//! ## The Flux wrinkle: routes are part of the identity
//!
//! The Layer Router's FA/SA decision is context-dependent, so cached KV
//! is only reusable under the route it was computed with. Two guards
//! enforce that:
//!
//!   * trees are partitioned by [`context_key`] (policy label + router
//!     name, with explicit per-layer modes for `Static` policies whose
//!     label alone is ambiguous);
//!   * within a tree, insert only descends into — and only splits —
//!     nodes whose stored route and decode mode equal the incoming
//!     request's, so every root→leaf path is route-homogeneous and a
//!     hit can pin the endpoint's route for the whole prefix.
//!
//! Sparse-decode routes additionally need the SA ring state at the
//! prefix boundary, which is *not* reconstructible later (the window
//! overwrites in place): nodes store an optional whole-ring
//! [`RingSnap`] per layer, captured by the engine exactly when chunked
//! prefill crosses the page-aligned snapshot point. A node missing a
//! needed ring is a *waypoint* — it still shares its pages with deeper
//! nodes but cannot itself be a hit endpoint.
//!
//! ## Lifecycle and accounting
//!
//! `retained_pages` is the ledger of pool pages the index holds on
//! behalf of future requests; [`KvPool::drained_with_retained`] checks
//! the pool against it so leaks stay distinguishable from deliberate
//! retention. Eviction is LRU over unpinned leaves (interior nodes are
//! protected structurally — they have children; pinned endpoints
//! protect themselves), cascading through childless waypoints, and
//! runs both against the index's own `capacity_pages` budget
//! ([`PrefixCache::insert`]) and under engine pool pressure
//! ([`PrefixCache::evict_for`]) so `pool_pressure` admission semantics
//! keep working with the cache enabled. `clear` detaches pinned nodes
//! as zombies (freed on last unpin) so in-flight requests never see
//! their node id reused.

use std::collections::HashMap;

use super::{FullCache, KvPool, PageBlock};
use crate::router::{AttnMode, DecodeMode, Policy};

/// Context key partitioning the radix forest: cached KV is only
/// comparable between requests with the same policy and router. The
/// `Static` label alone ("static-1of2") collides across different mode
/// vectors, so per-layer mode initials are appended (the four mode
/// names `fa/ssa/ta/xa` have distinct first characters).
pub fn context_key(policy: &Policy, router_name: &str) -> String {
    match policy {
        Policy::Static { modes, .. } => {
            let initials: String =
                modes.iter().map(|m| m.name().chars().next().unwrap_or('?')).collect();
            format!("{}:{}|{}", policy.label(), initials, router_name)
        }
        _ => format!("{}|{}", policy.label(), router_name),
    }
}

/// One layer's window into a pool block: `rows` token rows starting at
/// `row_off` of an `(H, cap, D)` region. Splits leave parent and child
/// windowing the SAME block with disjoint row ranges — the block is
/// then refcounted via [`KvPool::retain`].
#[derive(Debug, Clone, Copy)]
pub struct Seg {
    pub block: PageBlock,
    /// row capacity the block was laid out with (`(H, cap, D)`)
    pub cap: usize,
    pub row_off: usize,
    pub rows: usize,
}

/// Whole-ring SA snapshot at a node's depth: the `(H, SA_BUF, D)`
/// region copied into its own block plus the two cursor counters
/// [`super::SparseCache::restore_snapshot`] needs.
#[derive(Debug, Clone, Copy)]
pub struct RingSnap {
    pub block: PageBlock,
    pub sink_len: usize,
    pub total_seen: usize,
}

#[derive(Debug)]
struct Node {
    parent: Option<usize>,
    children: Vec<usize>,
    /// token ids this node contributes past its parent (always a
    /// multiple of `page_tokens` long)
    edge: Vec<u32>,
    /// total prefix length at this node (parent depth + edge len)
    depth: usize,
    /// one per layer: the KV rows for `edge`
    segs: Vec<Seg>,
    /// one per layer: ring state at `depth` for sparse-decode layers
    /// (all `None` on waypoints)
    rings: Vec<Option<RingSnap>>,
    route: Vec<AttnMode>,
    decode_mode: DecodeMode,
    /// in-flight requests holding this node as their hit endpoint
    pins: u32,
    last_use: u64,
    /// detached by `clear` while pinned; freed on last unpin
    zombie: bool,
    key: String,
}

/// A successful prefix match: the pinned endpoint (`node` must be
/// released via [`PrefixCache::unpin`]), the covered token count, the
/// route to pin, and the per-layer path segments (root→endpoint order)
/// plus endpoint ring snapshots to prime request caches from.
#[derive(Debug)]
pub struct Hit {
    pub node: usize,
    pub depth: usize,
    pub route: Vec<AttnMode>,
    pub decode_mode: DecodeMode,
    /// `segs[layer]` = the path's row windows in prefix order
    pub segs: Vec<Vec<Seg>>,
    pub rings: Vec<Option<RingSnap>>,
}

/// Counter snapshot for metrics and the bench harness.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub hits: u64,
    pub misses: u64,
    pub tokens_reused: u64,
    pub evictions: u64,
    pub inserts: u64,
    /// live non-zombie nodes
    pub nodes: usize,
    pub retained_pages: usize,
}

/// The radix prefix index. Single-threaded like the pool — it lives
/// inside the engine on the executor thread.
#[derive(Debug)]
pub struct PrefixCache {
    enabled: bool,
    /// index-retained page budget; eviction keeps `retained_pages`
    /// under it
    capacity_pages: usize,
    page_tokens: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    nodes: Vec<Option<Node>>,
    free_ids: Vec<usize>,
    /// root children per context key
    roots: HashMap<String, Vec<usize>>,
    /// LRU clock (bumped per acquire/insert)
    clock: u64,
    retained_pages: usize,
    hits: u64,
    misses: u64,
    tokens_reused: u64,
    evictions: u64,
    inserts: u64,
}

/// Whether a layer in `mode` under `decode` needs ring state to resume
/// decode from a cached prefix (FA layers replay from the full cache;
/// dense decode never touches the ring).
fn needs_ring(mode: AttnMode, decode: DecodeMode) -> bool {
    decode == DecodeMode::Sparse && mode != AttnMode::Fa
}

/// A node is a valid hit endpoint only when every layer that needs
/// ring state has a snapshot. Waypoints (split midpoints) fail this
/// for sparse-decode routes.
fn node_usable(n: &Node) -> bool {
    n.route
        .iter()
        .enumerate()
        .all(|(l, &m)| !needs_ring(m, n.decode_mode) || n.rings.get(l).is_some_and(Option::is_some))
}

fn common_prefix_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Return incoming ring-snapshot blocks that were never adopted into
/// the index (so they were never part of `retained_pages`).
fn free_rings(pool: &mut KvPool, rings: Vec<Option<RingSnap>>) {
    for r in rings.into_iter().flatten() {
        pool.free(r.block);
    }
}

impl PrefixCache {
    /// Starts disabled with a zero budget; [`PrefixCache::configure`]
    /// turns it on.
    pub fn new(page_tokens: usize, n_layers: usize, n_heads: usize, head_dim: usize) -> Self {
        Self {
            enabled: false,
            capacity_pages: 0,
            page_tokens: page_tokens.max(1),
            n_layers,
            n_heads,
            head_dim,
            nodes: Vec::new(),
            free_ids: Vec::new(),
            roots: HashMap::new(),
            clock: 0,
            retained_pages: 0,
            hits: 0,
            misses: 0,
            tokens_reused: 0,
            evictions: 0,
            inserts: 0,
        }
    }

    /// Reset the index (freeing everything unpinned) and set the
    /// enabled flag + retained-page budget.
    pub fn configure(&mut self, pool: &mut KvPool, enabled: bool, capacity_pages: usize) {
        self.clear(pool);
        self.enabled = enabled;
        self.capacity_pages = if enabled { capacity_pages.max(1) } else { 0 };
        self.hits = 0;
        self.misses = 0;
        self.tokens_reused = 0;
        self.evictions = 0;
        self.inserts = 0;
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Pool pages deliberately held by the index (including zombie
    /// nodes awaiting their last unpin) — feed this to
    /// [`KvPool::drained_with_retained`].
    pub fn retained_pages(&self) -> usize {
        self.retained_pages
    }

    pub fn stats(&self) -> PrefixStats {
        let nodes =
            self.nodes.iter().flatten().filter(|n| !n.zombie).count();
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            tokens_reused: self.tokens_reused,
            evictions: self.evictions,
            inserts: self.inserts,
            nodes,
            retained_pages: self.retained_pages,
        }
    }

    /// Longest-prefix match for `tokens` under `key`. Returns the
    /// deepest usable node covering a STRICT prefix (the engine must
    /// still prefill at least one token to produce router inputs and
    /// the first output logits) and pins it; the caller owns an unpin.
    pub fn acquire(&mut self, key: &str, tokens: &[u32]) -> Option<Hit> {
        if !self.enabled || tokens.is_empty() {
            return None;
        }
        self.clock += 1;
        let mut depth = 0usize;
        let mut candidates: Vec<usize> = self.roots.get(key).cloned().unwrap_or_default();
        let mut best: Option<usize> = None;
        loop {
            let mut advanced = None;
            for &cid in &candidates {
                let n = self.nodes[cid].as_ref().expect("linked child is live");
                if n.edge.len() <= tokens.len() - depth
                    && tokens[depth..depth + n.edge.len()] == n.edge[..]
                {
                    advanced = Some(cid);
                    break;
                }
            }
            let Some(cid) = advanced else { break };
            let clock = self.clock;
            let n = self.nodes[cid].as_mut().expect("linked child is live");
            n.last_use = clock;
            depth += n.edge.len();
            let n = self.nodes[cid].as_ref().expect("linked child is live");
            if depth < tokens.len() && node_usable(n) {
                best = Some(cid);
            }
            candidates = n.children.clone();
        }
        let Some(id) = best else {
            self.misses += 1;
            return None;
        };
        // collect the root→endpoint path to lay segments out in
        // prefix order
        let mut path = vec![id];
        while let Some(p) = self.nodes[*path.last().expect("non-empty")]
            .as_ref()
            .expect("path node is live")
            .parent
        {
            path.push(p);
        }
        path.reverse();
        let mut segs = vec![Vec::new(); self.n_layers];
        for &nid in &path {
            let n = self.nodes[nid].as_ref().expect("path node is live");
            for (l, s) in n.segs.iter().enumerate() {
                segs[l].push(*s);
            }
        }
        let endpoint = self.nodes[id].as_mut().expect("endpoint is live");
        endpoint.pins += 1;
        let hit = Hit {
            node: id,
            depth: endpoint.depth,
            route: endpoint.route.clone(),
            decode_mode: endpoint.decode_mode,
            segs,
            rings: endpoint.rings.clone(),
        };
        self.hits += 1;
        self.tokens_reused += hit.depth as u64;
        Some(hit)
    }

    /// Release a hit endpoint (or a zombie left by `clear`, which is
    /// freed here on its last pin).
    pub fn unpin(&mut self, pool: &mut KvPool, id: usize) {
        let (pins, zombie) = {
            let Some(n) = self.nodes.get_mut(id).and_then(Option::as_mut) else {
                return;
            };
            n.pins = n.pins.saturating_sub(1);
            (n.pins, n.zombie)
        };
        if pins == 0 && zombie {
            self.free_node_storage(pool, id);
            self.nodes[id] = None;
            self.free_ids.push(id);
        }
    }

    /// Insert the completed page-aligned prompt prefix `tokens`
    /// (length must be a `page_tokens` multiple), copying its KV rows
    /// out of the request's `staging` caches. `rings` are adopted into
    /// the endpoint when the route needs them; un-adopted blocks are
    /// freed here either way, so the caller unconditionally hands them
    /// over.
    pub fn insert(
        &mut self,
        pool: &mut KvPool,
        key: &str,
        tokens: &[u32],
        route: &[AttnMode],
        decode_mode: DecodeMode,
        staging: &[FullCache],
        rings: Vec<Option<RingSnap>>,
    ) {
        if !self.enabled
            || tokens.is_empty()
            || tokens.len() % self.page_tokens != 0
            || route.len() != self.n_layers
            || staging.len() != self.n_layers
        {
            free_rings(pool, rings);
            return;
        }
        self.clock += 1;
        let plen = tokens.len();
        let mut depth = 0usize;
        let mut parent: Option<usize> = None;
        let mut protect: Vec<usize> = Vec::new();
        loop {
            let children: Vec<usize> = match parent {
                Some(p) => self.nodes[p].as_ref().expect("parent is live").children.clone(),
                None => self.roots.get(key).cloned().unwrap_or_default(),
            };
            // descend only into route-homogeneous full-edge matches —
            // KV under a different route is a different prefix
            let mut full = None;
            for &cid in &children {
                let n = self.nodes[cid].as_ref().expect("linked child is live");
                if n.route.as_slice() == route
                    && n.decode_mode == decode_mode
                    && n.edge.len() <= plen - depth
                    && tokens[depth..depth + n.edge.len()] == n.edge[..]
                {
                    full = Some(cid);
                    break;
                }
            }
            if let Some(cid) = full {
                let clock = self.clock;
                let n = self.nodes[cid].as_mut().expect("linked child is live");
                n.last_use = clock;
                depth += n.edge.len();
                parent = Some(cid);
                protect.push(cid);
                if depth == plen {
                    self.upgrade_endpoint(pool, cid, rings);
                    return;
                }
                continue;
            }
            // page-aligned partial match → split so the common run is
            // shared (refcounted), when the routes agree
            let mut split_at = None;
            for &cid in &children {
                let n = self.nodes[cid].as_ref().expect("linked child is live");
                let q = common_prefix_len(&tokens[depth..], &n.edge);
                let s = (q / self.page_tokens) * self.page_tokens;
                if s > 0 && n.route.as_slice() == route && n.decode_mode == decode_mode {
                    split_at = Some((cid, s));
                    break;
                }
            }
            if let Some((cid, s)) = split_at {
                let mid = self.split(pool, key, cid, s);
                depth += s;
                parent = Some(mid);
                protect.push(mid);
                if depth == plen {
                    self.upgrade_endpoint(pool, mid, rings);
                    return;
                }
                // anything below the aligned split point shares less
                // than a page — the remainder becomes a fresh leaf
            }
            break;
        }
        // new leaf owning rows [depth, plen)
        let rows = plen - depth;
        let seg_pages = pool.pages_for(self.n_heads * rows * self.head_dim);
        let ring_pages: usize = rings.iter().flatten().map(|r| r.block.pages).sum();
        if !self.ensure_room(pool, seg_pages * self.n_layers + ring_pages, &protect) {
            free_rings(pool, rings);
            return;
        }
        let mut segs: Vec<Seg> = Vec::with_capacity(self.n_layers);
        for st in staging {
            let block = match pool.alloc(self.n_heads * rows * self.head_dim) {
                Ok(b) => b,
                Err(_) => {
                    // partial failure: give back what this insert took
                    for s in segs.drain(..) {
                        if pool.free(s.block) {
                            self.retained_pages -= s.block.pages;
                        }
                    }
                    free_rings(pool, rings);
                    return;
                }
            };
            pool.copy_rows(
                st.block,
                st.capacity,
                depth,
                block,
                rows,
                0,
                rows,
                self.n_heads,
                self.head_dim,
            );
            self.retained_pages += block.pages;
            segs.push(Seg { block, cap: rows, row_off: 0, rows });
        }
        let node_rings = if rings.len() == self.n_layers && rings.iter().any(Option::is_some) {
            for r in rings.iter().flatten() {
                self.retained_pages += r.block.pages;
            }
            rings
        } else {
            free_rings(pool, rings);
            vec![None; self.n_layers]
        };
        let node = Node {
            parent,
            children: Vec::new(),
            edge: tokens[depth..].to_vec(),
            depth: plen,
            segs,
            rings: node_rings,
            route: route.to_vec(),
            decode_mode,
            pins: 0,
            last_use: self.clock,
            zombie: false,
            key: key.to_string(),
        };
        let id = self.alloc_node(node);
        match parent {
            Some(p) => self.nodes[p].as_mut().expect("parent is live").children.push(id),
            None => self.roots.entry(key.to_string()).or_default().push(id),
        }
        self.inserts += 1;
    }

    /// The insert walk ended exactly on an existing node: adopt the
    /// incoming ring snapshots if they turn a waypoint into a usable
    /// endpoint, otherwise drop them. (Routes already matched during
    /// the walk.)
    fn upgrade_endpoint(&mut self, pool: &mut KvPool, id: usize, rings: Vec<Option<RingSnap>>) {
        let already_usable = node_usable(self.nodes[id].as_ref().expect("endpoint is live"));
        if already_usable || rings.len() != self.n_layers || !rings.iter().any(Option::is_some) {
            free_rings(pool, rings);
            return;
        }
        let add: usize = rings.iter().flatten().map(|r| r.block.pages).sum();
        if !self.ensure_room(pool, add, &[id]) {
            free_rings(pool, rings);
            return;
        }
        let n = self.nodes[id].as_mut().expect("endpoint is live");
        let old = std::mem::replace(&mut n.rings, rings);
        self.retained_pages += add;
        for r in old.into_iter().flatten() {
            if pool.free(r.block) {
                self.retained_pages -= r.block.pages;
            }
        }
        self.inserts += 1;
    }

    /// Split `cid`'s edge at page-aligned offset `s`, interposing a
    /// midpoint that WINDOWS into the same blocks (refcounted). The
    /// midpoint starts as a waypoint: it has the rows but no ring
    /// state at its depth.
    fn split(&mut self, pool: &mut KvPool, key: &str, cid: usize, s: usize) -> usize {
        let (old_parent, old_edge, child_depth, child_segs, route, decode_mode, last_use) = {
            let c = self.nodes[cid].as_ref().expect("split child is live");
            (
                c.parent,
                c.edge.clone(),
                c.depth,
                c.segs.clone(),
                c.route.clone(),
                c.decode_mode,
                c.last_use,
            )
        };
        debug_assert!(s > 0 && s < old_edge.len() && s % self.page_tokens == 0);
        for sg in &child_segs {
            pool.retain(sg.block);
        }
        let mid_segs: Vec<Seg> = child_segs.iter().map(|sg| Seg { rows: s, ..*sg }).collect();
        let mid = Node {
            parent: old_parent,
            children: vec![cid],
            edge: old_edge[..s].to_vec(),
            depth: child_depth - old_edge.len() + s,
            segs: mid_segs,
            rings: vec![None; self.n_layers],
            route,
            decode_mode,
            pins: 0,
            last_use,
            zombie: false,
            key: key.to_string(),
        };
        let mid_id = self.alloc_node(mid);
        match old_parent {
            Some(p) => {
                for c in self.nodes[p].as_mut().expect("parent is live").children.iter_mut() {
                    if *c == cid {
                        *c = mid_id;
                    }
                }
            }
            None => {
                if let Some(v) = self.roots.get_mut(key) {
                    for c in v.iter_mut() {
                        if *c == cid {
                            *c = mid_id;
                        }
                    }
                }
            }
        }
        let c = self.nodes[cid].as_mut().expect("split child is live");
        c.parent = Some(mid_id);
        c.edge = old_edge[s..].to_vec();
        for sg in c.segs.iter_mut() {
            sg.row_off += s;
            sg.rows -= s;
        }
        mid_id
    }

    /// Make room for `need` more retained pages under the index
    /// budget, evicting LRU leaves (never the `protect` path). False
    /// means the insert must be skipped.
    fn ensure_room(&mut self, pool: &mut KvPool, need: usize, protect: &[usize]) -> bool {
        if need > self.capacity_pages {
            return false;
        }
        while self.retained_pages + need > self.capacity_pages {
            if !self.evict_one(pool, protect) {
                return false;
            }
        }
        true
    }

    /// Engine pool-pressure hook: evict until the pool has
    /// `need_pages` free (or nothing evictable remains). Returns
    /// whether the pool can now cover the request — callers retry the
    /// failed allocation on `true`.
    pub fn evict_for(&mut self, pool: &mut KvPool, need_pages: usize) -> bool {
        while pool.pages_free() < need_pages {
            if !self.evict_one(pool, &[]) {
                return pool.pages_free() >= need_pages;
            }
        }
        true
    }

    /// Evict the least-recently-used unpinned, non-zombie leaf.
    /// Interior nodes are never candidates (they have children), so a
    /// pinned endpoint structurally protects its whole prefix path.
    fn evict_one(&mut self, pool: &mut KvPool, protect: &[usize]) -> bool {
        let mut victim: Option<(u64, usize)> = None;
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.pins > 0 || n.zombie || !n.children.is_empty() || protect.contains(&id) {
                continue;
            }
            let better = match victim {
                None => true,
                Some((lu, _)) => n.last_use < lu,
            };
            if better {
                victim = Some((n.last_use, id));
            }
        }
        let Some((_, id)) = victim else { return false };
        self.remove_leaf(pool, id, protect);
        true
    }

    /// Remove a leaf and cascade through ancestors left as childless
    /// unpinned waypoints (a usable ancestor stays — it is a valid
    /// endpoint in its own right).
    fn remove_leaf(&mut self, pool: &mut KvPool, id: usize, protect: &[usize]) {
        let (parent, key) = {
            let n = self.nodes[id].as_ref().expect("leaf is live");
            (n.parent, n.key.clone())
        };
        self.free_node_storage(pool, id);
        self.nodes[id] = None;
        self.free_ids.push(id);
        self.evictions += 1;
        match parent {
            Some(p) => {
                self.nodes[p].as_mut().expect("parent is live").children.retain(|&c| c != id);
                let pn = self.nodes[p].as_ref().expect("parent is live");
                let cascade = pn.children.is_empty()
                    && pn.pins == 0
                    && !pn.zombie
                    && !node_usable(pn)
                    && !protect.contains(&p);
                if cascade {
                    self.remove_leaf(pool, p, protect);
                }
            }
            None => {
                if let Some(v) = self.roots.get_mut(&key) {
                    v.retain(|&c| c != id);
                    if v.is_empty() {
                        self.roots.remove(&key);
                    }
                }
            }
        }
    }

    fn free_node_storage(&mut self, pool: &mut KvPool, id: usize) {
        let (segs, rings) = {
            let n = self.nodes[id].as_mut().expect("node is live");
            (std::mem::take(&mut n.segs), std::mem::take(&mut n.rings))
        };
        for s in segs {
            if pool.free(s.block) {
                self.retained_pages -= s.block.pages;
            }
        }
        for r in rings.into_iter().flatten() {
            if pool.free(r.block) {
                self.retained_pages -= r.block.pages;
            }
        }
    }

    /// Drop the whole index. Unpinned nodes free immediately; pinned
    /// ones detach as zombies (their storage stays on the
    /// `retained_pages` ledger) and free on their last
    /// [`PrefixCache::unpin`] — an in-flight hit's node id must never
    /// be reused under it.
    pub fn clear(&mut self, pool: &mut KvPool) {
        for id in 0..self.nodes.len() {
            let pinned = match &self.nodes[id] {
                Some(n) => n.pins > 0,
                None => continue,
            };
            if pinned {
                let n = self.nodes[id].as_mut().expect("node is live");
                n.zombie = true;
                n.parent = None;
                n.children.clear();
            } else {
                self.free_node_storage(pool, id);
                self.nodes[id] = None;
                self.free_ids.push(id);
            }
        }
        self.roots.clear();
    }

    fn alloc_node(&mut self, node: Node) -> usize {
        match self.free_ids.pop() {
            Some(id) => {
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    const PAGE: usize = 4; // tokens per page; h=1, d=1 → 4 floats
    const LAYERS: usize = 2;

    fn pool() -> KvPool {
        KvPool::new(PAGE, 64)
    }

    fn cache() -> PrefixCache {
        let mut c = PrefixCache::new(PAGE, LAYERS, 1, 1);
        // configure against a throwaway pool (nothing to clear yet)
        let mut p = KvPool::new(PAGE, 1);
        c.configure(&mut p, true, 32);
        c
    }

    /// Build per-layer staging caches holding `tokens.len()` rows of
    /// deterministic per-layer KV (`k = layer*1000 + token_id`).
    fn staging(pool: &mut KvPool, tokens: &[u32]) -> Vec<FullCache> {
        let s = tokens.len();
        (0..LAYERS)
            .map(|l| {
                let mut c = FullCache::new(pool, 1, 1, s).unwrap();
                let data: Vec<f32> =
                    tokens.iter().map(|&t| (l * 1000) as f32 + t as f32).collect();
                let k = HostTensor::new(vec![1, s, 1], data.clone());
                let v = HostTensor::new(vec![1, s, 1], data.iter().map(|x| -x).collect());
                c.load_prefill(pool, &k, &v, s).unwrap();
                c
            })
            .collect()
    }

    fn fa_route() -> Vec<AttnMode> {
        vec![AttnMode::Fa; LAYERS]
    }

    fn insert_prompt(c: &mut PrefixCache, p: &mut KvPool, tokens: &[u32]) {
        let st = staging(p, tokens);
        c.insert(p, "k", tokens, &fa_route(), DecodeMode::Dense, &st, Vec::new());
        for s in st {
            s.free(p);
        }
    }

    /// Read the hit's primed rows for one layer back out of the pool.
    fn rows_of(p: &KvPool, segs: &[Seg]) -> Vec<f32> {
        let mut out = Vec::new();
        for sg in segs {
            let ks = p.k_of(sg.block);
            out.extend_from_slice(&ks[sg.row_off..sg.row_off + sg.rows]);
        }
        out
    }

    #[test]
    fn insert_then_acquire_roundtrip() {
        let mut p = pool();
        let mut c = cache();
        let prompt: Vec<u32> = (10..18).collect(); // 8 tokens = 2 pages
        insert_prompt(&mut c, &mut p, &prompt);
        assert_eq!(c.stats().inserts, 1);
        assert_eq!(c.stats().nodes, 1);
        // exact-length query misses: a hit must leave ≥1 token to run
        assert!(c.acquire("k", &prompt).is_none());
        // a longer prompt sharing the prefix hits at depth 8
        let mut longer = prompt.clone();
        longer.extend([99, 98]);
        let hit = c.acquire("k", &longer).expect("prefix hit");
        assert_eq!(hit.depth, 8);
        assert_eq!(hit.route, fa_route());
        let want: Vec<f32> = prompt.iter().map(|&t| 1000.0 + t as f32).collect();
        assert_eq!(rows_of(&p, &hit.segs[1]), want, "layer-1 rows primed from the cache");
        // wrong context key misses
        assert!(c.acquire("other", &longer).is_none());
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.tokens_reused), (1, 2, 8));
        c.unpin(&mut p, hit.node);
        c.clear(&mut p);
        p.drained().unwrap();
    }

    #[test]
    fn split_shares_pages_with_refcount() {
        let mut p = pool();
        let mut c = cache();
        let a: Vec<u32> = (0..8).collect();
        let mut b: Vec<u32> = (0..4).collect();
        b.extend([90, 91, 92, 93]);
        insert_prompt(&mut c, &mut p, &a);
        let pages_after_a = p.pages_allocated();
        assert_eq!(pages_after_a, 2 * LAYERS, "2 pages per layer for 8 rows");
        insert_prompt(&mut c, &mut p, &b);
        // split at 4: midpoint shares a's blocks, only b's 4-row tail
        // allocates — 1 page per layer
        assert_eq!(p.pages_allocated(), pages_after_a + LAYERS, "shared run not duplicated");
        assert_eq!(c.stats().nodes, 3, "mid + two leaves");
        assert_eq!(c.retained_pages(), p.pages_allocated());
        p.drained_with_retained(c.retained_pages()).unwrap();
        // both full prompts are now reachable prefixes
        let mut qa = a.clone();
        qa.push(7);
        let mut qb = b.clone();
        qb.push(7);
        let ha = c.acquire("k", &qa).expect("a hit");
        assert_eq!(ha.depth, 8);
        assert_eq!(
            rows_of(&p, &ha.segs[0]),
            a.iter().map(|&t| t as f32).collect::<Vec<_>>()
        );
        let hb = c.acquire("k", &qb).expect("b hit");
        assert_eq!(hb.depth, 8);
        assert_eq!(
            rows_of(&p, &hb.segs[0]),
            b.iter().map(|&t| t as f32).collect::<Vec<_>>()
        );
        c.unpin(&mut p, ha.node);
        c.unpin(&mut p, hb.node);
        c.clear(&mut p);
        p.drained().unwrap();
    }

    #[test]
    fn eviction_is_lru_and_never_takes_pinned_nodes() {
        let mut p = pool();
        let mut c = cache();
        let a: Vec<u32> = (0..4).collect();
        let b: Vec<u32> = (100..104).collect();
        insert_prompt(&mut c, &mut p, &a); // older
        insert_prompt(&mut c, &mut p, &b); // newer
        let hit = c.acquire("k", &[0, 1, 2, 3, 7]).expect("pin a");
        // force pool pressure: ask for every remaining page + what the
        // two cached prompts hold
        let free0 = p.pages_free();
        assert!(!c.evict_for(&mut p, free0 + 2 * LAYERS + 1), "pinned pages can't be freed");
        assert_eq!(c.stats().evictions, 1, "the one unpinned leaf was evicted");
        assert!(c.evict_for(&mut p, free0 + LAYERS), "freed pages now cover the need");
        // the pinned node survived eviction pressure; the unpinned
        // (even though more recently used) node was the only candidate
        let hit2 = c.acquire("k", &[0, 1, 2, 3, 7]).expect("a still cached");
        assert!(c.acquire("k", &[100, 101, 102, 103, 7]).is_none(), "b evicted");
        c.unpin(&mut p, hit.node);
        c.unpin(&mut p, hit2.node);
        c.clear(&mut p);
        p.drained().unwrap();
    }

    #[test]
    fn clear_with_pinned_hit_defers_free_until_unpin() {
        let mut p = pool();
        let mut c = cache();
        let a: Vec<u32> = (0..4).collect();
        insert_prompt(&mut c, &mut p, &a);
        let hit = c.acquire("k", &[0, 1, 2, 3, 9]).expect("hit");
        c.clear(&mut p);
        assert_eq!(c.stats().nodes, 0, "zombies are not live nodes");
        assert!(c.retained_pages() > 0, "zombie storage stays on the ledger");
        p.drained_with_retained(c.retained_pages()).unwrap();
        // the detached zombie is unreachable for new requests
        assert!(c.acquire("k", &[0, 1, 2, 3, 9]).is_none());
        c.unpin(&mut p, hit.node);
        assert_eq!(c.retained_pages(), 0);
        p.drained().unwrap();
    }

    #[test]
    fn capacity_budget_skips_oversized_inserts() {
        let mut p = pool();
        let mut c = PrefixCache::new(PAGE, LAYERS, 1, 1);
        c.configure(&mut p, true, 1); // 1-page budget < 2 pages needed
        let a: Vec<u32> = (0..4).collect();
        insert_prompt(&mut c, &mut p, &a);
        assert_eq!(c.stats().inserts, 0, "insert over budget is a no-op");
        assert_eq!(c.retained_pages(), 0);
        p.drained().unwrap();
        // unaligned lengths are skipped too
        let mut c2 = cache();
        let odd: Vec<u32> = (0..6).collect();
        insert_prompt(&mut c2, &mut p, &odd);
        assert_eq!(c2.stats().inserts, 0, "non-page-aligned insert skipped");
        p.drained().unwrap();
    }

    #[test]
    fn context_key_distinguishes_static_mode_vectors() {
        let s1 = Policy::Static {
            modes: vec![AttnMode::Fa, AttnMode::Ssa],
            decode: DecodeMode::Dense,
        };
        let s2 = Policy::Static {
            modes: vec![AttnMode::Ssa, AttnMode::Fa],
            decode: DecodeMode::Dense,
        };
        assert_eq!(s1.label(), s2.label(), "labels collide by construction");
        assert_ne!(context_key(&s1, "r"), context_key(&s2, "r"), "keys must not");
        assert_ne!(
            context_key(&Policy::Backbone, "a"),
            context_key(&Policy::Backbone, "b"),
            "router name partitions trees"
        );
    }

    #[test]
    fn waypoint_nodes_are_not_endpoints_for_sparse_decode() {
        // a sparse-decode route with no ring snapshot is unusable as a
        // hit endpoint, but still shares pages once rings arrive via a
        // deeper node — here we just pin the visibility rule
        let n = Node {
            parent: None,
            children: Vec::new(),
            edge: vec![0; PAGE],
            depth: PAGE,
            segs: Vec::new(),
            rings: vec![None; LAYERS],
            route: vec![AttnMode::Fa, AttnMode::Ssa],
            decode_mode: DecodeMode::Sparse,
            pins: 0,
            last_use: 0,
            zombie: false,
            key: "k".into(),
        };
        assert!(!node_usable(&n), "missing ring on an SSA layer");
        let mut ok = n;
        ok.rings[1] = Some(RingSnap {
            block: PageBlock { start: 0, pages: 1 },
            sink_len: 0,
            total_seen: PAGE,
        });
        assert!(node_usable(&ok), "FA layers never need rings");
    }
}
