//! Deterministic word-level tokenizer over the synthetic vocabulary.
//!
//! The serving stack operates on the same 512-symbol vocabulary the
//! backbone was pretrained on (python/compile/data.py). Symbols render
//! as short words (`w17`, control tokens as `<bos>` etc.) so transcripts
//! in the error-analysis experiment (paper Figs 11-13) are readable.

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const QUERY: u32 = 4;
pub const ANSWER: u32 = 5;
pub const TAG_BASE: u32 = 6;
pub const CONTENT: u32 = 32;
pub const VOCAB: u32 = 512;

#[derive(Debug, Clone)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Self
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB as usize
    }

    pub fn decode_token(&self, id: u32) -> String {
        match id {
            PAD => "<pad>".into(),
            BOS => "<bos>".into(),
            EOS => "<eos>".into(),
            SEP => "<sep>".into(),
            QUERY => "<query>".into(),
            ANSWER => "<answer>".into(),
            t if t < CONTENT => format!("<tag{}>", t - TAG_BASE),
            t if t < VOCAB => format!("w{}", t - CONTENT),
            t => format!("<invalid{t}>"),
        }
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.decode_token(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn encode_token(&self, word: &str) -> Option<u32> {
        match word {
            "<pad>" => Some(PAD),
            "<bos>" => Some(BOS),
            "<eos>" => Some(EOS),
            "<sep>" => Some(SEP),
            "<query>" => Some(QUERY),
            "<answer>" => Some(ANSWER),
            w => {
                if let Some(n) = w.strip_prefix("<tag").and_then(|s| {
                    s.strip_suffix('>').and_then(|s| s.parse::<u32>().ok())
                }) {
                    let id = TAG_BASE + n;
                    (id < CONTENT).then_some(id)
                } else if let Some(n) =
                    w.strip_prefix('w').and_then(|s| s.parse::<u32>().ok())
                {
                    let id = CONTENT + n;
                    (id < VOCAB).then_some(id)
                } else {
                    None
                }
            }
        }
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .filter_map(|w| self.encode_token(w))
            .collect()
    }
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_token() {
        let t = Tokenizer::new();
        for id in 0..VOCAB {
            let s = t.decode_token(id);
            assert_eq!(t.encode_token(&s), Some(id), "token {id} ({s})");
        }
    }

    #[test]
    fn roundtrip_sequence() {
        let t = Tokenizer::new();
        let ids = vec![BOS, TAG_BASE + 3, CONTENT + 7, SEP, CONTENT + 400, EOS];
        let text = t.decode(&ids);
        assert_eq!(t.encode(&text), ids);
    }

    #[test]
    fn invalid_words_are_skipped() {
        let t = Tokenizer::new();
        assert!(t.encode("hello world w9999 <tag99>").is_empty());
    }
}
