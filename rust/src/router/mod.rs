//! Layer-Router runtime + attention-allocation policies.
//!
//! The paper's inference-time contract (section 3.3): the router runs
//! **once per layer during prefill**, producing a hard FA/SA decision
//! from a pooled boundary descriptor of that layer's input; the decision
//! is cached for the whole request and reused by every decode step.

use anyhow::Result;

use crate::runtime::{Arg, Backend, HostTensor, WeightStore};

/// Attention mode of one layer (prefill kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttnMode {
    /// full causal attention (retrieval layers)
    Fa,
    /// streaming sparse: sink + local window
    Ssa,
    /// triangle: streaming + dense last-q rows
    Ta,
    /// x-attention: antidiagonal-scored block sparse
    Xa,
}

impl AttnMode {
    pub fn exe_prefix(&self) -> &'static str {
        match self {
            AttnMode::Fa => "layer_fa_prefill",
            AttnMode::Ssa => "layer_ssa_prefill",
            AttnMode::Ta => "layer_ta_prefill",
            AttnMode::Xa => "layer_xa_prefill",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fa" => AttnMode::Fa,
            "ssa" => AttnMode::Ssa,
            "ta" => AttnMode::Ta,
            "xa" => AttnMode::Xa,
            other => anyhow::bail!("unknown attention mode {other}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttnMode::Fa => "fa",
            AttnMode::Ssa => "ssa",
            AttnMode::Ta => "ta",
            AttnMode::Xa => "xa",
        }
    }
}

/// Decode-phase cache policy (paper Table 1 shaded rows = `Sparse`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// every layer keeps the full KV cache; decode is always dense
    Dense,
    /// SA-routed layers keep only the sink+local ring buffer
    Sparse,
}

/// Attention-allocation policy for a request.
#[derive(Debug, Clone)]
pub enum Policy {
    /// the unmodified backbone: FA everywhere
    Backbone,
    /// FluxAttention: dynamic layer-level routing; `sa_mode` is the
    /// sparse kernel ("FA-SSA", "FA-XA", "FA-TA" configurations)
    Flux { sa_mode: AttnMode, decode: DecodeMode },
    /// static per-layer allocation (baselines: DuoAttention-/PruLong-
    /// like layerised variants, TriangleMix, entropy-ranked)
    Static { modes: Vec<AttnMode>, decode: DecodeMode },
}

impl Policy {
    pub fn label(&self) -> String {
        match self {
            Policy::Backbone => "backbone".into(),
            Policy::Flux { sa_mode, decode } => format!(
                "flux-fa-{}{}",
                sa_mode.name(),
                if *decode == DecodeMode::Sparse { "-sd" } else { "" }
            ),
            Policy::Static { modes, decode } => {
                let n_sa = modes.iter().filter(|m| **m != AttnMode::Fa).count();
                format!(
                    "static-{}of{}{}",
                    n_sa,
                    modes.len(),
                    if *decode == DecodeMode::Sparse { "-sd" } else { "" }
                )
            }
        }
    }

    pub fn decode_mode(&self) -> DecodeMode {
        match self {
            Policy::Backbone => DecodeMode::Dense,
            Policy::Flux { decode, .. } | Policy::Static { decode, .. } => *decode,
        }
    }
}

/// Prefill-Suffix Pooling on the host: mean of the first and last
/// `pool` valid rows of `(S, d)` hidden states -> `(2d,)` descriptor.
/// O(pool * d) regardless of sequence length — the paper's Fig 9
/// length-invariance comes from exactly this.
pub fn pool_descriptor(hidden: &HostTensor, valid: usize, pool: usize) -> HostTensor {
    let d = hidden.shape[1];
    let p = pool.min(valid).max(1);
    let mut desc = vec![0.0f32; 2 * d];
    for t in 0..p {
        let row = &hidden.data[t * d..(t + 1) * d];
        for (o, x) in desc[..d].iter_mut().zip(row) {
            *o += x;
        }
    }
    for t in (valid - p)..valid {
        let row = &hidden.data[t * d..(t + 1) * d];
        for (o, x) in desc[d..].iter_mut().zip(row) {
            *o += x;
        }
    }
    let inv = 1.0 / p as f32;
    for o in desc.iter_mut() {
        *o *= inv;
    }
    HostTensor::new(vec![2 * d], desc)
}

/// Trained Layer-Router weights (per layer), kept as host tensors ready
/// to feed the `router` executable of any backend.
pub struct RouterNet {
    layers: Vec<[HostTensor; 4]>, // w1, b1, w2, b2
}

impl RouterNet {
    /// Load from a `router_<name>.bin/.json` export.
    pub fn load(ws: &WeightStore, n_layers: usize) -> Result<Self> {
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let w1 = ws.layer_slice("w1", i)?;
            let b1 = ws.layer_slice("b1", i)?;
            let w2 = ws.layer_slice("w2", i)?;
            let b2 = ws.layer_slice("b2", i)?;
            layers.push([w1, b1, w2, b2]);
        }
        Ok(Self { layers })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Hard routing for layer `i`: true = FA (logit order is [SA, FA]).
    /// Returns (is_fa, logits).
    pub fn route(
        &self,
        rt: &mut dyn Backend,
        layer: usize,
        desc: &HostTensor,
    ) -> Result<(bool, [f32; 2])> {
        let [w1, b1, w2, b2] = &self.layers[layer];
        let out = rt.run(
            "router",
            &[Arg::F32(desc), Arg::F32(w1), Arg::F32(b1), Arg::F32(w2), Arg::F32(b2)],
        )?;
        let logits = &out[0].data;
        anyhow::ensure!(logits.len() == 2, "router output must be 2 logits");
        Ok((logits[1] > logits[0], [logits[0], logits[1]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pooling_is_mean_of_boundaries() {
        // rows: 0..8, d=2; valid 8, pool 2 -> prefix mean rows 0,1;
        // suffix mean rows 6,7
        let data: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let h = HostTensor::new(vec![8, 2], data);
        let d = pool_descriptor(&h, 8, 2);
        assert_eq!(d.shape, vec![4]);
        assert_eq!(d.data, vec![1.0, 2.0, 13.0, 14.0]);
    }

    #[test]
    fn pooling_clamps_to_valid() {
        let h = HostTensor::new(vec![8, 1], (0..8).map(|x| x as f32).collect());
        // only 3 valid rows, pool 16 -> both descriptors over rows 0..3
        let d = pool_descriptor(&h, 3, 16);
        assert!((d.data[0] - 1.0).abs() < 1e-6);
        assert!((d.data[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pooling_cost_is_length_invariant() {
        // structural check: descriptor dim independent of S
        for s in [16usize, 256, 2048] {
            let h = HostTensor::zeros(vec![s, 4]);
            assert_eq!(pool_descriptor(&h, s, 16).shape, vec![8]);
        }
    }

    #[test]
    fn policy_labels() {
        assert_eq!(Policy::Backbone.label(), "backbone");
        let p = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };
        assert_eq!(p.label(), "flux-fa-ssa-sd");
        let s = Policy::Static {
            modes: vec![AttnMode::Fa, AttnMode::Ta],
            decode: DecodeMode::Dense,
        };
        assert_eq!(s.label(), "static-1of2");
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [AttnMode::Fa, AttnMode::Ssa, AttnMode::Ta, AttnMode::Xa] {
            assert_eq!(AttnMode::parse(m.name()).unwrap(), m);
        }
        assert!(AttnMode::parse("bogus").is_err());
    }
}
