//! A800 decode-latency simulator: reproduces the head-level
//! synchronization long-tail of paper section 2.3 / Fig 1(b).
//!
//! Model (memory-bandwidth-bound decode, batch 1, BF16):
//!
//! * Each attention layer launches one thread block per head; head `h`
//!   must stream `bytes(h) = 2 * kv_len(h) * head_dim * 2B` of KV from
//!   HBM.
//! * Aggregate HBM bandwidth is `BW_TOTAL`; a single thread block can
//!   sustain at most `BW_TOTAL / n_heads_slots` (limited by per-SM
//!   outstanding-request capacity) — this is what creates the long
//!   tail: a lone retrieval head cannot soak the whole bus.
//! * Layer latency = max(sum(bytes)/BW_TOTAL, max_h bytes(h)/BW_BLOCK)
//!   + kernel-launch/sync overhead. Layers run sequentially.
//!
//! Calibration constants follow the A800-80G public spec (1935 GB/s
//! HBM2e, 108 SMs); absolute numbers are not the claim — the *shape*
//! (head-level ~= dense, layer-level ~ proportional) is (DESIGN.md §2).

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct GpuSimConfig {
    /// aggregate HBM bandwidth, bytes/sec
    pub hbm_bw: f64,
    /// fraction of aggregate bandwidth one thread block can sustain
    pub per_block_bw_frac: f64,
    /// fixed per-layer kernel launch + barrier cost, seconds
    pub layer_overhead_s: f64,
    /// bytes per KV element (BF16)
    pub dtype_bytes: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
}

impl Default for GpuSimConfig {
    fn default() -> Self {
        Self {
            hbm_bw: 1.935e12,            // A800-80G HBM2e
            // one decode-attention thread block pins a few SMs' worth of
            // outstanding HBM loads (~4.5 of 108 SMs): a lone retrieval
            // head cannot soak the whole bus, which is exactly the
            // synchronization long-tail of paper section 2.3
            per_block_bw_frac: 1.0 / 24.0,
            layer_overhead_s: 4e-6, // launch + __syncthreads tail
            dtype_bytes: 2,
            n_heads: 32,
            head_dim: 128,
            n_layers: 32,
        }
    }
}

/// Per-layer sparsity assignment for the simulator.
#[derive(Debug, Clone)]
pub enum SimPolicy {
    /// all heads in all layers see the full context
    Dense,
    /// head-level: in every layer, `sparse_frac` of heads use the
    /// sink+local window, the rest keep full context (Elastic-Attention
    /// -style allocation)
    HeadLevel { sparse_frac: f64, window: usize },
    /// layer-level: `sparse_frac` of layers use the window for *all*
    /// heads (FluxAttention)
    LayerLevel { sparse_frac: f64, window: usize },
}

/// Simulated decode latency for one token at `context_len`.
pub fn decode_latency_s(cfg: &GpuSimConfig, policy: &SimPolicy, context_len: usize) -> f64 {
    let bytes_per_tok = 2.0 * cfg.head_dim as f64 * cfg.dtype_bytes as f64;
    let per_block_bw = cfg.hbm_bw * cfg.per_block_bw_frac;
    let layer_time = |head_lens: &[usize]| -> f64 {
        let total_bytes: f64 = head_lens.iter().map(|&l| l as f64 * bytes_per_tok).sum();
        let max_head_bytes = head_lens
            .iter()
            .map(|&l| l as f64 * bytes_per_tok)
            .fold(0.0, f64::max);
        (total_bytes / cfg.hbm_bw).max(max_head_bytes / per_block_bw) + cfg.layer_overhead_s
    };

    let mut total = 0.0;
    for layer in 0..cfg.n_layers {
        let lens: Vec<usize> = match policy {
            SimPolicy::Dense => vec![context_len; cfg.n_heads],
            SimPolicy::HeadLevel { sparse_frac, window } => {
                let n_sparse = (cfg.n_heads as f64 * sparse_frac).round() as usize;
                (0..cfg.n_heads)
                    .map(|h| if h < n_sparse { (*window).min(context_len) } else { context_len })
                    .collect()
            }
            SimPolicy::LayerLevel { sparse_frac, window } => {
                let n_sparse_layers = (cfg.n_layers as f64 * sparse_frac).round() as usize;
                let len = if layer < n_sparse_layers {
                    (*window).min(context_len)
                } else {
                    context_len
                };
                vec![len; cfg.n_heads]
            }
        };
        total += layer_time(&lens);
    }
    total
}

/// Speedup of `policy` over dense decode at `context_len`.
pub fn decode_speedup(cfg: &GpuSimConfig, policy: &SimPolicy, context_len: usize) -> f64 {
    decode_latency_s(cfg, &SimPolicy::Dense, context_len)
        / decode_latency_s(cfg, policy, context_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GpuSimConfig {
        GpuSimConfig::default()
    }

    #[test]
    fn dense_latency_grows_with_context() {
        let c = cfg();
        let l1 = decode_latency_s(&c, &SimPolicy::Dense, 8_192);
        let l2 = decode_latency_s(&c, &SimPolicy::Dense, 262_144);
        assert!(l2 > l1 * 10.0);
    }

    #[test]
    fn head_level_speedup_is_marginal() {
        // paper Fig 1(b): head-level sparsity yields only marginal
        // wall-clock gains because retrieval heads dominate (long tail)
        let c = cfg();
        let hl = SimPolicy::HeadLevel { sparse_frac: 0.5, window: 2048 };
        let s = decode_speedup(&c, &hl, 262_144);
        assert!(s < 1.5, "head-level speedup should be marginal, got {s:.2}");
    }

    #[test]
    fn layer_level_speedup_is_proportional() {
        let c = cfg();
        let ll = SimPolicy::LayerLevel { sparse_frac: 0.5, window: 2048 };
        let s = decode_speedup(&c, &ll, 262_144);
        assert!(s > 1.8, "layer-level speedup should approach 2x, got {s:.2}");
    }

    #[test]
    fn layer_beats_head_at_matched_omega() {
        let c = cfg();
        for ctx in [16_384usize, 65_536, 262_144] {
            let hl = decode_speedup(&c, &SimPolicy::HeadLevel { sparse_frac: 0.5, window: 2048 }, ctx);
            let ll = decode_speedup(&c, &SimPolicy::LayerLevel { sparse_frac: 0.5, window: 2048 }, ctx);
            assert!(ll > hl, "ctx {ctx}: layer {ll:.2} <= head {hl:.2}");
        }
    }

    #[test]
    fn full_sparsity_saturates_at_overhead() {
        let c = cfg();
        let ll = SimPolicy::LayerLevel { sparse_frac: 1.0, window: 2048 };
        let lat = decode_latency_s(&c, &ll, 1_048_576);
        // all layers windowed: latency should be microseconds-scale,
        // bounded by overhead, independent of the million-token context
        assert!(lat < c.n_layers as f64 * (c.layer_overhead_s + 1e-4));
        let lat_small = decode_latency_s(&c, &ll, 4_096);
        assert!((lat - lat_small).abs() / lat < 0.05);
    }
}
