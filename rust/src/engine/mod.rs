//! The serving engine: owns the execution backend, model weights,
//! routers and all per-request KV state, and executes prefill / decode
//! steps.
//!
//! Backends are not required to be `Send` (PJRT handles are raw
//! pointers), so the [`Engine`] lives on one dedicated executor thread;
//! the coordinator drives it through the [`EngineHandle`] channel API
//! (mirrors the single-GPU worker model of vLLM-style engines — one
//! device, serialized kernel stream). Which backend runs underneath —
//! the pure-Rust [`crate::runtime::RefBackend`] or PJRT — is decided by
//! [`crate::runtime::open_backend`] from the artifact manifest; the
//! engine itself is backend-agnostic (DESIGN.md §2).
//!
//! Request data path (DESIGN.md §5):
//!
//! ```text
//! prefill:  embed -> for each layer: [pool -> route]? -> layer exe
//!           -> cache K/V (full or sink+local per routing) -> lm_head
//! decode:   embed(tok) -> for each layer: qkv exe -> cache.append ->
//!           attend exe (fa bucket | sa ring) -> lm_head -> next token
//! batched:  one round over B requests (DESIGN.md §9): per layer, one
//!           qkv_batch call, then the batch partitioned by that layer's
//!           routed mode into one attend_batch_fa + one attend_batch_sa
//!           call (KV staged as views), then one (B,d)x(d,V) lm_head
//! ```

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::MetaConfig;
use crate::kvcache::prefix::{context_key, PrefixCache, PrefixStats, RingSnap};
use crate::kvcache::{FullCache, KvPool, LayerCache, SparseCache};
use crate::model::{argmax, ModelWeights};
use crate::router::{pool_descriptor, AttnMode, DecodeMode, Policy, RouterNet};
use crate::runtime::{open_backend, Arg, Backend, HostTensor, TensorView, WeightStore};

/// Timing + routing info returned by prefill (feeds metrics and the
/// paper's efficiency figures).
#[derive(Debug, Clone)]
pub struct PrefillReport {
    pub bucket: usize,
    pub prompt_len: usize,
    pub modes: Vec<AttnMode>,
    pub omsr: f64,
    pub total_us: u64,
    pub router_us: u64,
    pub first_token: u32,
    pub kv_bytes: usize,
    /// Engine calls the prefill took: 1 for a monolithic prefill, the
    /// chunk count for a chunked one (DESIGN.md §10).
    pub chunks: usize,
    /// Prompt tokens reused from the cross-request prefix cache
    /// (DESIGN.md §13) — 0 on a cold run; a hit's chunks covered only
    /// the remaining suffix.
    pub cached_prefix_tokens: usize,
}

/// One in-flight chunked prefill job (DESIGN.md §10): the prompt is
/// split into `chunk_tokens`-sized chunks, each run as one engine call
/// at the smallest covering prefill bucket, attending over the
/// already-staged KV prefix through zero-copy views. The layer router
/// runs once on the first chunk and its per-layer decision is pinned
/// for the rest, so every chunk's K/V lands directly in the routed
/// cache layout (FullCache always staged for cross-chunk attention;
/// sparse-routed layers additionally ring-prime a SparseCache and drop
/// the staging buffer on completion).
struct PrefillJob {
    tokens: Vec<u32>,
    policy: Policy,
    router_name: String,
    chunk_tokens: usize,
    total_bucket: usize,
    decode_mode: DecodeMode,
    consumed: usize,
    /// pinned on the first chunk; empty until then
    modes: Vec<AttnMode>,
    /// per-layer natural-order KV prefix, capacity `total_bucket` (the
    /// same capacity a monolithic prefill's caches end with)
    staging: Vec<FullCache>,
    /// per-layer sparse rings for SA-routed layers under sparse decode
    rings: Vec<Option<SparseCache>>,
    router_us: u64,
    compute_us: u64,
    chunks_done: usize,
    /// clamp chunks so a boundary lands exactly here, then snapshot
    /// the rings (prefix-cache insertion point for sparse decode)
    snap_at: Option<usize>,
    /// page-aligned prefix length to insert into the cache on
    /// completion (0 = nothing to insert)
    insert_upto: usize,
    /// ring snapshots captured at `insert_upto`, handed to the index
    ring_snaps: Vec<Option<RingSnap>>,
    /// pinned prefix-cache endpoint this job was primed from
    prefix_node: Option<usize>,
    /// tokens reused from the cache (0 on a cold run)
    cached_prefix: usize,
}

/// Result of one [`Engine::prefill_chunk`] call.
#[derive(Debug)]
pub enum ChunkOutcome {
    /// The chunk ran; more prompt remains.
    More { consumed: usize, total_tokens: usize },
    /// The final chunk ran: the request is live (decode-ready) under
    /// `id` and the prefill report covers the whole prompt.
    Done { id: u64, report: PrefillReport },
}

/// What [`Engine::preempt`] freed and kept (DESIGN.md §15). The
/// coordinator holds `ring_snaps` while the victim is parked and hands
/// them back to [`Engine::catch_up`] (which verifies the rebuilt rings
/// against them and frees the blocks) — or to [`Engine::free_snaps`]
/// when the parked request is cancelled, expired, or failed over.
#[derive(Debug, Clone)]
pub struct PreemptInfo {
    /// Pool pages returned by the preemption (every cache the request
    /// held — the pages the failing allocation can now draw on).
    pub pages_freed: usize,
    /// Pool pages still held by the ring snapshots in `ring_snaps`.
    pub snap_pages: usize,
    /// Per-layer sparse-ring snapshots (`None` for FA/dense layers and
    /// for rings whose snapshot allocation failed).
    pub ring_snaps: Vec<Option<RingSnap>>,
}

/// One live request's state inside the engine.
pub struct RequestState {
    pub caches: Vec<LayerCache>,
    pub modes: Vec<AttnMode>,
    pub decode_mode: DecodeMode,
    pub n_tokens: usize, // prompt + generated so far (positions)
    pub last_token: u32,
}

/// Outcome of one batched decode round (DESIGN.md §9). Everything the
/// scheduler needs per token round rides on this one reply — including
/// the KV-interchange totals (the reply piggyback is the only
/// scheduler-facing totals channel; the old standalone polling job is
/// gone).
#[derive(Debug)]
pub struct DecodeBatchReport {
    /// Per-request results, aligned with the input ids.
    pub tokens: Vec<Result<u32>>,
    /// Per-request wall-clock attribution, aligned with `tokens`. The
    /// batched path computes all tokens together, so each entry is the
    /// round's wall time divided evenly across the batch, the division
    /// remainder spread over the leading entries (the amortized
    /// per-token engine cost — summing over the batch recovers the
    /// round exactly); the serial fallback times each step individually.
    pub step_us: Vec<u64>,
    /// Wall-clock of the whole round.
    pub total_us: u64,
    /// Cumulative engine KV-interchange totals
    /// `(bytes moved, bytes borrowed)` as of the end of this round.
    pub kv_transfer: (u64, u64),
    /// Sum over this round's layers of the FA-group sizes — the
    /// (layer, mode) occupancy of the contiguous kernel groups.
    pub fa_group_slots: u64,
    /// Same for the SA (sparse-ring) groups.
    pub sa_group_slots: u64,
    /// Whether the batched kernels ran (false = serial fallback:
    /// `FLUX_BATCH_DECODE=0` or a backend without batch support).
    pub batched: bool,
    /// KV-pool occupancy gauges as of the end of this round:
    /// `(pages_allocated, pages_free, pages_peak)` — piggybacked so the
    /// scheduler's metrics fold needs no extra engine round-trip.
    pub pool_pages: (u64, u64, u64),
    /// Cumulative prefix-cache evictions as of this round (piggybacked
    /// like the pool gauges; 0 with the cache disabled).
    pub prefix_evictions: u64,
    /// Pool pages currently retained by the prefix index.
    pub prefix_retained_pages: u64,
    /// Which data-parallel replica produced this round (DESIGN.md §14;
    /// 0 for a standalone engine).
    pub replica: usize,
}

/// Admission-relevant pool + model geometry, fetched once by the
/// coordinator at startup (DESIGN.md §11): everything the scheduler
/// needs to compute a request's worst-case page reservation without
/// asking the engine per request.
#[derive(Debug, Clone)]
pub struct PoolProfile {
    /// tokens per page (pool pages are `page_tokens * H * D` floats)
    pub page_tokens: usize,
    /// the pool's page budget
    pub total_pages: usize,
    pub n_layers: usize,
    /// sparse-ring capacity in tokens (SA_BUF)
    pub sa_buf: usize,
    /// published prefill buckets, ascending — initial FA capacities
    pub prefill_buckets: Vec<usize>,
}

impl PoolProfile {
    /// Worst-case page reservation for a `(prompt, max_new)` request:
    /// per layer, the fully-grown FA capacity (initial capacity = the
    /// smallest covering prefill bucket, doubled until it covers
    /// `prompt + max_new`) PLUS one SA ring — the sum covers every
    /// reachable layout, including the chunked-prefill transient where
    /// a layer holds FA staging and a ring simultaneously. Engine-side
    /// growth frees the old run before allocating the doubled one, so
    /// this is a true upper bound (the budget-admission formula,
    /// DESIGN.md §11).
    pub fn worst_case_pages(&self, prompt_len: usize, max_new: usize) -> usize {
        let per = self.page_tokens.max(1);
        let mut cap = self
            .prefill_buckets
            .iter()
            .copied()
            .find(|&b| b >= prompt_len)
            .or_else(|| self.prefill_buckets.last().copied())
            .unwrap_or_else(|| prompt_len.max(1));
        let need = prompt_len + max_new;
        while cap < need {
            cap *= 2;
        }
        let fa = cap.div_ceil(per).max(1);
        let sa = self.sa_buf.div_ceil(per).max(1);
        self.n_layers * (fa + sa)
    }

    /// Route-aware page footprint for a request whose per-layer route
    /// is pinned (DESIGN.md §15): FA-routed layers (and every layer
    /// under dense decode, which keeps its `FullCache`) cost the
    /// fully-grown FA capacity from the same covering-bucket/doubling
    /// computation as [`PoolProfile::worst_case_pages`]; sparse-decode
    /// SA layers end promotion holding only their fixed `sa_buf` ring.
    /// This is the steady-state peak AFTER the prefill→decode
    /// promotion — the value the scheduler shrinks the `Budgets` ledger
    /// charge to once the router has fired (growth frees the old run
    /// before allocating the doubled one, so per-layer concurrency
    /// never exceeds the final capacity).
    ///
    /// Unlike the worst case, this bound is TIGHT: a full run emits
    /// `max_new` tokens but appends KV only for the first `max_new - 1`
    /// of them (the last emitted token is returned, never attended), so
    /// the doubling covers `prompt + max_new - 1` tokens — exactly the
    /// pages the request peaks at, which the charge-equals-peak
    /// property test pins.
    pub fn routed_pages(
        &self,
        prompt_len: usize,
        max_new: usize,
        modes: &[AttnMode],
        decode_mode: DecodeMode,
    ) -> usize {
        let per = self.page_tokens.max(1);
        let mut cap = self
            .prefill_buckets
            .iter()
            .copied()
            .find(|&b| b >= prompt_len)
            .or_else(|| self.prefill_buckets.last().copied())
            .unwrap_or_else(|| prompt_len.max(1));
        let need = (prompt_len + max_new).saturating_sub(1).max(prompt_len.max(1));
        while cap < need {
            cap *= 2;
        }
        let fa = cap.div_ceil(per).max(1);
        let sa = self.sa_buf.div_ceil(per).max(1);
        modes
            .iter()
            .map(|&m| {
                if matches!(m, AttnMode::Fa) || matches!(decode_mode, DecodeMode::Dense) {
                    fa
                } else {
                    sa
                }
            })
            .sum()
    }
}

/// The engine proper (not `Send`; lives on the executor thread).
pub struct Engine {
    pub rt: Box<dyn Backend>,
    pub weights: ModelWeights,
    pub routers: HashMap<String, RouterNet>,
    cfg: MetaConfig,
    /// the paged KV block pool every cache draws from (DESIGN.md §11)
    pool: KvPool,
    /// cross-request radix prefix cache over the pool (DESIGN.md §13);
    /// starts disabled until the coordinator configures it
    prefix: PrefixCache,
    requests: HashMap<u64, RequestState>,
    /// in-flight chunked prefill jobs (DESIGN.md §10), keyed separately
    /// from live requests — a job becomes a request on its final chunk
    prefill_jobs: HashMap<u64, PrefillJob>,
    next_id: u64,
    /// Stage decode KV arguments as borrowed views instead of cloning
    /// (`FLUX_ZERO_COPY=0` disables, for before/after benchmarking).
    zero_copy: bool,
    /// Run decode rounds through the batched (layer, mode)-bucketed
    /// kernels when the backend supports them (`FLUX_BATCH_DECODE=0`
    /// falls back to the serial per-request walk for A/B benchmarking).
    batch_decode: bool,
    /// Which data-parallel replica this engine serves (DESIGN.md §14);
    /// stamped onto every [`DecodeBatchReport`] so the scheduler's
    /// metrics fold attributes rounds without extra plumbing. 0 for a
    /// standalone (single-replica) engine.
    replica: usize,
}

impl Engine {
    /// Tokens per KV pool page: 32 tokens × H × D floats — small enough
    /// that a sparse ring wastes < one page, large enough that a
    /// 2048-token cache is a 64-entry run.
    pub const DEFAULT_PAGE_TOKENS: usize = 32;

    /// Load backend + weights + every available router variant and
    /// prepare all executables listed in the manifest, with a
    /// default-sized KV pool.
    pub fn load(artifacts: &std::path::Path) -> Result<Self> {
        Self::load_with_pool(artifacts, None)
    }

    /// [`Engine::load`] with an explicit pool geometry
    /// `(page_tokens, budget_tokens)` — the bench pool-pressure
    /// scenario and tests size the pool down to force typed exhaustion;
    /// `None` gives every request room (budget = worst case of the
    /// default `max_active_requests`).
    pub fn load_with_pool(
        artifacts: &std::path::Path,
        pool_geometry: Option<(usize, usize)>,
    ) -> Result<Self> {
        Self::load_with_faults(artifacts, pool_geometry, None)
    }

    /// [`Engine::load_with_pool`] with an optional fault-injection plan
    /// (DESIGN.md §12): the backend is wrapped in a
    /// [`crate::runtime::chaos::ChaosBackend`] that injects the plan's
    /// kernel failures, panics and stalls at the scheduled call
    /// indices. A plan describes one engine lifetime — supervision
    /// respawns fault-free.
    pub fn load_with_faults(
        artifacts: &std::path::Path,
        pool_geometry: Option<(usize, usize)>,
        faults: Option<crate::runtime::chaos::FaultPlan>,
    ) -> Result<Self> {
        let cfg = MetaConfig::load(artifacts)?;
        let manifest = crate::util::json::Json::parse(&std::fs::read_to_string(
            artifacts.join("manifest.json"),
        )?)
        .map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        let hint = manifest.get("backend").and_then(crate::util::json::Json::as_str);
        let mut rt = open_backend(&cfg, hint)?;
        if let Some(plan) = faults {
            rt = crate::runtime::chaos::ChaosBackend::wrap(rt, plan);
        }
        for exe in manifest
            .get("executables")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap_or(&[])
        {
            if let Some(name) = exe.as_str() {
                rt.load(name)?;
            }
        }
        let ws = WeightStore::load(artifacts.join("weights.bin"), artifacts.join("weights.json"))?;
        let weights = ModelWeights::load(&cfg, &ws)?;
        let mut routers = HashMap::new();
        for entry in std::fs::read_dir(artifacts)? {
            let path = entry?.path();
            let name = path.file_name().unwrap().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".bin") {
                if let Some(variant) = stem.strip_prefix("router_") {
                    let rws = WeightStore::load(&path, artifacts.join(format!("{stem}.json")))?;
                    routers.insert(variant.to_string(), RouterNet::load(&rws, cfg.model.n_layers)?);
                }
            }
        }
        if rt.accepts_decode_batch() {
            // batched entry points are host-backend-only and never in
            // the AOT manifest — prepared here when advertised
            for exe in ["decode_qkv_batch", "attend_batch_fa", "attend_batch_sa", "lm_head_batch"]
            {
                rt.load(exe)?;
            }
        }
        if rt.accepts_prefill_chunks() {
            // history-aware chunked prefill entry points (DESIGN.md §10)
            // are likewise host-backend-only
            for mode in ["fa", "ssa", "ta", "xa"] {
                for &b in &cfg.prefill_buckets {
                    rt.load(&format!("layer_{mode}_prefill_chunk_{b}"))?;
                }
            }
        }
        let zero_copy = std::env::var("FLUX_ZERO_COPY").map(|v| v != "0").unwrap_or(true);
        let batch_decode = std::env::var("FLUX_BATCH_DECODE").map(|v| v != "0").unwrap_or(true);
        let (page_tokens, budget_tokens) = pool_geometry.unwrap_or_else(|| {
            // default budget: every slot of the default admission cap
            // (32 requests) at its worst case — the largest prefill
            // bucket of FA cache plus one sparse ring, per layer. The
            // arenas grow lazily, so an idle engine holds no KV memory.
            let max_bucket = cfg.prefill_buckets.last().copied().unwrap_or(2048);
            (
                Self::DEFAULT_PAGE_TOKENS,
                (max_bucket + cfg.sa_buf) * cfg.model.n_layers * 32,
            )
        });
        let pool = KvPool::with_budget(
            page_tokens,
            cfg.model.n_heads,
            cfg.model.head_dim,
            budget_tokens,
        );
        let prefix = PrefixCache::new(
            page_tokens,
            cfg.model.n_layers,
            cfg.model.n_heads,
            cfg.model.head_dim,
        );
        Ok(Self {
            rt,
            weights,
            routers,
            cfg,
            pool,
            prefix,
            requests: HashMap::new(),
            prefill_jobs: HashMap::new(),
            next_id: 0,
            zero_copy,
            batch_decode,
            replica: 0,
        })
    }

    pub fn cfg(&self) -> &MetaConfig {
        &self.cfg
    }

    /// Stamp the replica identity carried on every report
    /// (DESIGN.md §14). Set once right after load by
    /// [`EngineHandle::spawn_replica`]-style constructors.
    pub fn set_replica(&mut self, replica: usize) {
        self.replica = replica;
    }

    /// This engine's replica identity (0 for standalone engines).
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// The KV block pool (occupancy gauges for metrics / tests).
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Admission-relevant pool + model geometry (DESIGN.md §11).
    pub fn pool_profile(&self) -> PoolProfile {
        let hd = (self.cfg.model.n_heads * self.cfg.model.head_dim).max(1);
        PoolProfile {
            page_tokens: self.pool.page_floats() / hd,
            total_pages: self.pool.total_pages(),
            n_layers: self.cfg.model.n_layers,
            sa_buf: self.cfg.sa_buf,
            prefill_buckets: self.cfg.prefill_buckets.clone(),
        }
    }

    fn pool_gauges(&self) -> (u64, u64, u64) {
        (
            self.pool.pages_allocated() as u64,
            self.pool.pages_free() as u64,
            self.pool.pages_peak() as u64,
        )
    }

    /// Enable/disable the cross-request prefix cache (DESIGN.md §13).
    /// Reconfiguring clears the index; `capacity_pages` defaults to
    /// half the pool so cached prefixes can never starve admissions.
    pub fn set_prefix_cache(&mut self, enabled: bool, capacity_pages: Option<usize>) {
        let cap = capacity_pages.unwrap_or_else(|| (self.pool.total_pages() / 2).max(1));
        self.prefix.configure(&mut self.pool, enabled, cap);
    }

    /// Drop every cached prefix: unpinned entries free their pages now,
    /// pinned ones on the owning job's release.
    pub fn prefix_clear(&mut self) {
        self.prefix.clear(&mut self.pool);
    }

    /// Prefix-cache counter snapshot (hits, misses, tokens reused,
    /// evictions, inserts, live nodes, retained pages).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.stats()
    }

    /// Pool pages legitimately retained by the prefix index — the
    /// tolerance `drained()` checks run with (retained ≠ leaked).
    pub fn prefix_retained_pages(&self) -> usize {
        self.prefix.retained_pages()
    }

    /// Toggle the zero-copy KV staging path (the bench harness compares
    /// clone vs view in-process; serving always leaves this on).
    pub fn set_zero_copy(&mut self, on: bool) {
        self.zero_copy = on;
    }

    pub fn zero_copy(&self) -> bool {
        self.zero_copy
    }

    /// Toggle the batched decode path (the bench harness A/Bs batched
    /// vs serial in-process; serving leaves this on).
    pub fn set_batch_decode(&mut self, on: bool) {
        self.batch_decode = on;
    }

    pub fn batch_decode(&self) -> bool {
        self.batch_decode
    }

    /// Set the backend kernel worker count (no-op for device backends).
    pub fn set_threads(&mut self, n: usize) {
        self.rt.set_threads(n);
    }

    /// Aggregate KV-interchange accounting across all executables:
    /// `(bytes physically copied, bytes staged as borrowed views)`.
    pub fn kv_transfer_totals(&self) -> (u64, u64) {
        self.rt
            .stats()
            .values()
            .fold((0, 0), |(m, b), s| (m + s.kv_bytes_moved, b + s.kv_bytes_borrowed))
    }

    /// Aggregate prefill row accounting across all executables:
    /// `(rows carrying real tokens, bucket-padding rows)` — the
    /// compute-utilization ledger `flux bench` reports.
    pub fn prefill_row_totals(&self) -> (u64, u64) {
        self.rt
            .stats()
            .values()
            .fold((0, 0), |(v, p), s| (v + s.rows_valid, p + s.rows_padded))
    }

    pub fn router(&self, name: &str) -> Result<&RouterNet> {
        self.routers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("router variant '{name}' not found in artifacts"))
    }

    pub fn active_requests(&self) -> usize {
        self.requests.len()
    }

    /// In-flight chunked prefill jobs (not yet decode-ready requests).
    pub fn active_prefill_jobs(&self) -> usize {
        self.prefill_jobs.len()
    }

    /// KV bytes held by live requests AND by in-flight prefill jobs'
    /// staging buffers + rings (a cancelled job must return this to the
    /// pre-job level — pinned by `tests/chunked.rs`).
    pub fn total_kv_bytes(&self) -> usize {
        let live: usize = self
            .requests
            .values()
            .map(|r| r.caches.iter().map(|c| c.bytes()).sum::<usize>())
            .sum();
        let staged: usize = self
            .prefill_jobs
            .values()
            .map(|j| {
                j.staging.iter().map(|c| c.bytes()).sum::<usize>()
                    + j.rings.iter().flatten().map(|c| c.bytes()).sum::<usize>()
            })
            .sum();
        live + staged
    }

    /// Prefill a prompt under `policy` using router variant
    /// `router_name` (ignored for static policies). Returns the request
    /// id and a report.
    pub fn prefill(
        &mut self,
        tokens: &[u32],
        policy: &Policy,
        router_name: &str,
    ) -> Result<(u64, PrefillReport)> {
        let t_start = Instant::now();
        let n_layers = self.cfg.model.n_layers;
        let bucket = self
            .cfg
            .prefill_bucket(tokens.len())
            .ok_or_else(|| anyhow::anyhow!("prompt of {} tokens exceeds max bucket", tokens.len()))?;
        let valid = tokens.len();
        let desc_pool = self.cfg.sparsity.pool_size;
        let sink = self.cfg.sparsity.sink_size;
        let local = self.cfg.sparsity.local_size;
        let sa_buf = self.cfg.sa_buf;
        let (nh, hd) = (self.cfg.model.n_heads, self.cfg.model.head_dim);
        let decode_mode = policy.decode_mode();

        let mut hidden = self.weights.embed_tokens(tokens, bucket);
        let mut modes = Vec::with_capacity(n_layers);
        let mut caches: Vec<LayerCache> = Vec::with_capacity(n_layers);
        let mut router_us = 0u64;
        // padded tail rows are skipped inside the layer kernels when the
        // backend opts in (AOT artifacts keep the 9-input signature)
        let valid_arr = [valid as i32];
        let pass_valid = self.rt.accepts_prefill_valid_arg();

        // fallible section in one scope: a failure at any layer —
        // including pool exhaustion — frees the partial caches below
        // instead of leaking their pages
        let run = (|| -> Result<u32> {
            for layer in 0..n_layers {
                // --- routing decision for this layer ---
                let mode = route_layer(
                    &mut *self.rt,
                    &self.routers,
                    policy,
                    router_name,
                    &hidden,
                    valid,
                    desc_pool,
                    layer,
                    &mut router_us,
                )?;
                modes.push(mode);

                // --- layer execution ---
                let exe = format!("{}_{}", mode.exe_prefix(), bucket);
                let w = &self.weights.layers[layer];
                let mut call_args = vec![
                    Arg::F32(&hidden),
                    Arg::F32(&w.norm1),
                    Arg::F32(&w.wq),
                    Arg::F32(&w.wk),
                    Arg::F32(&w.wv),
                    Arg::F32(&w.wo),
                    Arg::F32(&w.norm2),
                    Arg::F32(&w.w_ff1),
                    Arg::F32(&w.w_ff2),
                ];
                if pass_valid {
                    call_args.push(Arg::I32(&valid_arr));
                }
                let mut out = self.rt.run(&exe, &call_args)?;
                self.rt.note_prefill_rows(&exe, valid as u64, (bucket - valid) as u64);
                anyhow::ensure!(out.len() == 3, "prefill layer must return (hidden, k, v)");
                let v = out.pop().unwrap();
                let k = out.pop().unwrap();
                hidden = out.pop().unwrap();

                // --- KV retention per routing decision + decode mode ---
                let sparse_cache = decode_mode == DecodeMode::Sparse && mode != AttnMode::Fa;
                let cache = if sparse_cache {
                    let mut c = SparseCache::new(&mut self.pool, nh, hd, sink, local, sa_buf)?;
                    c.load_prefill(&mut self.pool, &k, &v, valid);
                    LayerCache::Sparse(c)
                } else {
                    let mut c = FullCache::new(&mut self.pool, nh, hd, bucket)?;
                    c.load_prefill(&mut self.pool, &k, &v, valid)?;
                    LayerCache::Full(c)
                };
                caches.push(cache);
            }
            // first generated token from the last valid position —
            // staged as a borrowed view of the hidden state, no row copy
            self.lm_head_last_row(&hidden, valid)
        })();
        let first_token = match run {
            Ok(t) => t,
            Err(e) => {
                for c in caches {
                    c.free(&mut self.pool);
                }
                return Err(e);
            }
        };
        let (id, omsr, kv_bytes) =
            self.promote_request(caches, &modes, decode_mode, valid, first_token);
        Ok((
            id,
            PrefillReport {
                bucket,
                prompt_len: valid,
                modes,
                omsr,
                total_us: t_start.elapsed().as_micros() as u64,
                router_us,
                first_token,
                kv_bytes,
                chunks: 1,
                cached_prefix_tokens: 0,
            },
        ))
    }

    /// Final-norm + vocabulary projection over the last valid row of
    /// `hidden` (borrowed view, no row copy) — the greedy first token.
    /// Shared by the monolithic and chunked prefill completions.
    fn lm_head_last_row(&mut self, hidden: &HostTensor, valid: usize) -> Result<u32> {
        let d = self.cfg.model.d_model;
        let d_shape = [d];
        let last_hidden = TensorView {
            shape: &d_shape,
            data: &hidden.data[(valid - 1) * d..valid * d],
        };
        let logits = self.rt.run(
            "lm_head",
            &[
                Arg::F32View(last_hidden),
                Arg::F32(&self.weights.norm_f),
                Arg::F32(&self.weights.lm_head),
            ],
        )?;
        Ok(argmax(&logits[0].data))
    }

    /// Insert a freshly prefilled request into the live table and derive
    /// the report's summary numbers — `(id, omsr, kv_bytes)`. Shared by
    /// the monolithic and chunked prefill completions so the promotion
    /// bookkeeping is written exactly once.
    fn promote_request(
        &mut self,
        caches: Vec<LayerCache>,
        modes: &[AttnMode],
        decode_mode: DecodeMode,
        n_tokens: usize,
        first_token: u32,
    ) -> (u64, f64, usize) {
        let omsr = modes.iter().filter(|m| **m != AttnMode::Fa).count() as f64
            / self.cfg.model.n_layers as f64;
        let kv_bytes = caches.iter().map(|c| c.bytes()).sum();
        let id = self.next_id;
        self.next_id += 1;
        self.requests.insert(
            id,
            RequestState {
                caches,
                modes: modes.to_vec(),
                decode_mode,
                n_tokens,
                last_token: first_token,
            },
        );
        (id, omsr, kv_bytes)
    }

    /// Open a chunked prefill job (DESIGN.md §10): validates the prompt
    /// against the bucket ledger and allocates per-layer staging, but
    /// runs no compute — each subsequent [`Engine::prefill_chunk`] call
    /// executes one chunk, so the scheduler can interleave decode
    /// rounds between chunks. `chunk_tokens == 0` plans one whole-prompt
    /// chunk (monolithic compute through the same code path); backends
    /// without chunk kernels degrade to one monolithic `prefill` call
    /// on the first `prefill_chunk`.
    pub fn prefill_open(
        &mut self,
        tokens: &[u32],
        policy: &Policy,
        router_name: &str,
        chunk_tokens: usize,
    ) -> Result<u64> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        let total_bucket = self
            .cfg
            .prefill_bucket(tokens.len())
            .ok_or_else(|| anyhow::anyhow!("prompt of {} tokens exceeds max bucket", tokens.len()))?;
        let chunked_backend = self.rt.accepts_prefill_chunks();
        let chunk_tokens = if !chunked_backend || chunk_tokens == 0 {
            tokens.len()
        } else {
            // XA chunk boundaries must be block-aligned; rounding up to
            // a block multiple costs nothing for the other modes
            let block = self.cfg.sparsity.block_size.max(1);
            chunk_tokens.max(1).div_ceil(block) * block
        };
        let (nh, hd) = (self.cfg.model.n_heads, self.cfg.model.head_dim);
        let n_layers = self.cfg.model.n_layers;
        // staging capacity == the monolithic bucket, so completed FA
        // caches are bit-identical (capacity included) to monolithic
        // ones; a partial allocation failure frees what was taken
        let mut staging = if chunked_backend {
            let mut v: Vec<FullCache> = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let need = self.pool.pages_for(nh * total_bucket * hd);
                let mut alloc = FullCache::new(&mut self.pool, nh, hd, total_bucket);
                if alloc.is_err() && self.prefix.evict_for(&mut self.pool, need) {
                    alloc = FullCache::new(&mut self.pool, nh, hd, total_bucket);
                }
                match alloc {
                    Ok(c) => v.push(c),
                    Err(e) => {
                        for c in v {
                            c.free(&mut self.pool);
                        }
                        return Err(e);
                    }
                }
            }
            v
        } else {
            Vec::new()
        };

        // --- cross-request prefix reuse (DESIGN.md §13): the longest
        // cached match primes staging with a pool-internal copy and
        // pins the stored route, so chunked compute starts after the
        // shared prefix ---
        let mut consumed = 0usize;
        let mut modes: Vec<AttnMode> = Vec::new();
        let mut rings: Vec<Option<SparseCache>> = Vec::new();
        let mut prefix_node: Option<usize> = None;
        let mut cached_prefix = 0usize;
        let mut snap_at: Option<usize> = None;
        let mut insert_upto = 0usize;
        if chunked_backend && self.prefix.enabled() {
            let key = context_key(policy, router_name);
            if let Some(hit) = self.prefix.acquire(&key, tokens) {
                let sink = self.cfg.sparsity.sink_size;
                let local = self.cfg.sparsity.local_size;
                let sa_buf = self.cfg.sa_buf;
                let mut prime_err: Option<anyhow::Error> = None;
                for (layer, &mode) in hit.route.iter().enumerate() {
                    for sg in &hit.segs[layer] {
                        staging[layer].prime_from_pool(
                            &mut self.pool,
                            sg.block,
                            sg.cap,
                            sg.row_off,
                            sg.rows,
                        );
                    }
                    if hit.decode_mode == DecodeMode::Sparse && mode != AttnMode::Fa {
                        let need = self.pool.pages_for(nh * sa_buf * hd);
                        let mut ring = SparseCache::new(&mut self.pool, nh, hd, sink, local, sa_buf);
                        if ring.is_err() && self.prefix.evict_for(&mut self.pool, need) {
                            ring = SparseCache::new(&mut self.pool, nh, hd, sink, local, sa_buf);
                        }
                        match ring {
                            Ok(mut r) => {
                                let snap =
                                    hit.rings[layer].as_ref().expect("usable endpoint has ring");
                                r.restore_snapshot(
                                    &mut self.pool,
                                    snap.block,
                                    snap.sink_len,
                                    snap.total_seen,
                                );
                                rings.push(Some(r));
                            }
                            Err(e) => {
                                prime_err = Some(e);
                                break;
                            }
                        }
                    } else {
                        rings.push(None);
                    }
                }
                if let Some(e) = prime_err {
                    // staging already carries primed rows, so falling
                    // back to a cold run in place is not possible —
                    // free everything and surface the typed pool error
                    for r in rings.into_iter().flatten() {
                        r.free(&mut self.pool);
                    }
                    for c in staging {
                        c.free(&mut self.pool);
                    }
                    self.prefix.unpin(&mut self.pool, hit.node);
                    return Err(e);
                }
                consumed = hit.depth;
                cached_prefix = hit.depth;
                modes = hit.route.clone();
                prefix_node = Some(hit.node);
                // plan the page-aligned extension of the cached entry:
                // ring-routed requests must snapshot at the boundary,
                // ring-free ones can insert straight from staging
                let page = self.prefix.page_tokens();
                let aligned = (tokens.len() / page) * page;
                if aligned > hit.depth {
                    if rings.iter().any(Option::is_some) {
                        snap_at = Some(aligned);
                    } else {
                        insert_upto = aligned;
                    }
                }
            }
        }

        let id = self.next_id;
        self.next_id += 1;
        self.prefill_jobs.insert(
            id,
            PrefillJob {
                tokens: tokens.to_vec(),
                policy: policy.clone(),
                router_name: router_name.to_string(),
                chunk_tokens,
                total_bucket,
                decode_mode: policy.decode_mode(),
                consumed,
                modes,
                staging,
                rings,
                router_us: 0,
                compute_us: 0,
                chunks_done: 0,
                snap_at,
                insert_upto,
                ring_snaps: Vec::new(),
                prefix_node,
                cached_prefix,
            },
        );
        Ok(id)
    }

    /// Run the next chunk of prefill job `job`. On the final chunk the
    /// job is promoted to a live request (KV in its routed layout, first
    /// token computed) and removed from the job table.
    ///
    /// A mid-chunk failure leaves earlier layers' KV already appended to
    /// the staging caches, so the job is unrecoverable: it is dropped
    /// (staged pages freed back to the pool) and the error returned —
    /// retrying the same job id fails cleanly instead of
    /// double-appending KV.
    pub fn prefill_chunk(&mut self, job: u64) -> Result<ChunkOutcome> {
        match self.prefill_chunk_inner(job) {
            Ok(out) => Ok(out),
            Err(e) => {
                if let Some(j) = self.prefill_jobs.remove(&job) {
                    self.free_job(j);
                }
                Err(e)
            }
        }
    }

    /// Return a dropped job's staging + ring pages (and any captured
    /// ring snapshots) to the pool, and release its prefix-cache pin.
    fn free_job(&mut self, j: PrefillJob) {
        for c in j.staging {
            c.free(&mut self.pool);
        }
        for r in j.rings.into_iter().flatten() {
            r.free(&mut self.pool);
        }
        for s in j.ring_snaps.into_iter().flatten() {
            self.pool.free(s.block);
        }
        if let Some(nid) = j.prefix_node {
            self.prefix.unpin(&mut self.pool, nid);
        }
    }

    fn prefill_chunk_inner(&mut self, job: u64) -> Result<ChunkOutcome> {
        if !self.rt.accepts_prefill_chunks() {
            // device backends: one monolithic call, same outcome shape
            let j = self
                .prefill_jobs
                .remove(&job)
                .ok_or_else(|| anyhow::anyhow!("unknown prefill job {job}"))?;
            let (id, report) = self.prefill(&j.tokens, &j.policy, &j.router_name)?;
            return Ok(ChunkOutcome::Done { id, report });
        }

        let t_start = Instant::now();
        let n_layers = self.cfg.model.n_layers;
        let pool = self.cfg.sparsity.pool_size;
        let sink = self.cfg.sparsity.sink_size;
        let local = self.cfg.sparsity.local_size;
        let sa_buf = self.cfg.sa_buf;
        let (nh, hd) = (self.cfg.model.n_heads, self.cfg.model.head_dim);

        let j = self
            .prefill_jobs
            .get_mut(&job)
            .ok_or_else(|| anyhow::anyhow!("unknown prefill job {job}"))?;
        let len = j.tokens.len();
        anyhow::ensure!(j.consumed < len, "prefill job {job} already complete");
        let base = j.consumed;
        let mut n = j.chunk_tokens.min(len - base);
        // clamp so a chunk boundary lands exactly on the planned ring-
        // snapshot point; never applies to a cold first chunk (snap_at
        // is planned only after it), so the router's input is untouched
        if let Some(p) = j.snap_at {
            if base < p {
                n = n.min(p - base);
            }
        }
        // smallest covering bucket for THIS chunk, not the request-level
        // maximum — the bucket-padding-waste fix
        let chunk_bucket = self
            .cfg
            .prefill_bucket(n)
            .ok_or_else(|| anyhow::anyhow!("chunk of {n} tokens exceeds max bucket"))?;
        // warm (prefix-hit) jobs arrive with the cached route pinned, so
        // the router must not re-run even though base > 0 on chunk one
        let first = j.modes.is_empty();
        let meta = [base as i32, n as i32, j.total_bucket as i32];
        let last = base + n == len;

        let mut hidden = self.weights.embed_tokens(&j.tokens[base..base + n], chunk_bucket);
        for layer in 0..n_layers {
            // --- routing: decided on the first chunk (the paper's
            // context-aware routing on the prompt prefix), pinned after ---
            let mode = if first {
                route_layer(
                    &mut *self.rt,
                    &self.routers,
                    &j.policy,
                    &j.router_name,
                    &hidden,
                    n,
                    pool,
                    layer,
                    &mut j.router_us,
                )?
            } else {
                j.modes[layer]
            };
            if first {
                j.modes.push(mode);
                let sparse = j.decode_mode == DecodeMode::Sparse && mode != AttnMode::Fa;
                let ring = if sparse {
                    let need = self.pool.pages_for(nh * sa_buf * hd);
                    let mut r = SparseCache::new(&mut self.pool, nh, hd, sink, local, sa_buf);
                    if r.is_err() && self.prefix.evict_for(&mut self.pool, need) {
                        r = SparseCache::new(&mut self.pool, nh, hd, sink, local, sa_buf);
                    }
                    Some(r?)
                } else {
                    None
                };
                j.rings.push(ring);
            }

            // --- chunk execution over the staged prefix (zero-copy) ---
            let exe = format!("{}_chunk_{}", mode.exe_prefix(), chunk_bucket);
            let w = &self.weights.layers[layer];
            let (kt, vt) = j.staging[layer].view(&self.pool);
            let call_args = [
                Arg::F32(&hidden),
                Arg::F32(&w.norm1),
                Arg::F32(&w.wq),
                Arg::F32(&w.wk),
                Arg::F32(&w.wv),
                Arg::F32(&w.wo),
                Arg::F32(&w.norm2),
                Arg::F32(&w.w_ff1),
                Arg::F32(&w.w_ff2),
                Arg::F32View(kt),
                Arg::F32View(vt),
                Arg::I32(&meta),
            ];
            let mut out = self.rt.run(&exe, &call_args)?;
            anyhow::ensure!(out.len() == 3, "prefill chunk must return (hidden, k, v)");
            let hist_bytes = (2 * nh * base * hd * 4) as u64;
            self.rt.note_kv_transfer(&exe, 0, hist_bytes);
            self.rt.note_prefill_rows(&exe, n as u64, (chunk_bucket - n) as u64);
            let v = out.pop().unwrap();
            let k = out.pop().unwrap();
            hidden = out.pop().unwrap();

            // --- KV landing: staging prefix always (cross-chunk
            // attention), plus ring-priming for sparse-routed layers ---
            j.staging[layer].append_prefill_chunk(&mut self.pool, &k, &v, n)?;
            if let Some(ring) = &mut j.rings[layer] {
                ring.append_prefill_chunk(&mut self.pool, &k, &v, n);
            }
        }
        // --- prefix-cache insertion planning (DESIGN.md §13): a cold
        // run can only decide after the first chunk, once the route
        // (and hence ring-need) is known. Ring-routed prefixes need the
        // ring state snapshotted exactly at the page boundary, which is
        // impossible if the first chunk already ran past it. ---
        if first && self.prefix.enabled() {
            let page = self.prefix.page_tokens();
            let aligned = (len / page) * page;
            if aligned > 0 {
                if j.rings.iter().any(Option::is_some) {
                    if aligned >= base + n {
                        j.snap_at = Some(aligned);
                    }
                } else {
                    j.insert_upto = aligned;
                }
            }
        }
        j.consumed += n;
        j.chunks_done += 1;
        j.compute_us += t_start.elapsed().as_micros() as u64;
        if j.snap_at == Some(j.consumed) {
            // boundary reached: capture every ring so the cached entry
            // can rebuild sparse decode state on a future hit
            j.snap_at = None;
            let mut snaps: Vec<Option<RingSnap>> = Vec::with_capacity(n_layers);
            let mut ok = true;
            let need = self.pool.pages_for(nh * sa_buf * hd);
            for r in &j.rings {
                match r {
                    Some(c) => {
                        let mut snap = c.snapshot(&mut self.pool);
                        if snap.is_err() && self.prefix.evict_for(&mut self.pool, need) {
                            snap = c.snapshot(&mut self.pool);
                        }
                        match snap {
                            Ok((block, sink_len, total_seen)) => {
                                snaps.push(Some(RingSnap { block, sink_len, total_seen }));
                            }
                            Err(_) => {
                                ok = false;
                                break;
                            }
                        }
                    }
                    None => snaps.push(None),
                }
            }
            if ok {
                j.ring_snaps = snaps;
                j.insert_upto = j.consumed;
            } else {
                // snapshot starved for pages: skip insertion, the
                // request itself is unaffected
                for s in snaps.into_iter().flatten() {
                    self.pool.free(s.block);
                }
            }
        }
        if !last {
            return Ok(ChunkOutcome::More { consumed: j.consumed, total_tokens: len });
        }

        // --- final chunk: first token + promotion to a live request ---
        let first_token = self.lm_head_last_row(&hidden, n)?;
        let mut j = self.prefill_jobs.remove(&job).expect("job present");
        // retire the completed prompt into the prefix index (page-
        // aligned), then release the pin taken at admission
        if self.prefix.enabled() && j.insert_upto > 0 {
            let key = context_key(&j.policy, &j.router_name);
            let snaps = std::mem::take(&mut j.ring_snaps);
            self.prefix.insert(
                &mut self.pool,
                &key,
                &j.tokens[..j.insert_upto],
                &j.modes,
                j.decode_mode,
                &j.staging,
                snaps,
            );
        }
        for s in std::mem::take(&mut j.ring_snaps).into_iter().flatten() {
            self.pool.free(s.block);
        }
        if let Some(nid) = j.prefix_node.take() {
            self.prefix.unpin(&mut self.pool, nid);
        }
        let modes = j.modes;
        let mut caches: Vec<LayerCache> = Vec::with_capacity(j.staging.len());
        for (full, ring) in j.staging.into_iter().zip(j.rings) {
            match ring {
                Some(r) => {
                    // sparse-routed layer keeps only the ring: the full
                    // staging prefix returns its pages to the pool here.
                    full.free(&mut self.pool);
                    caches.push(LayerCache::Sparse(r));
                }
                None => caches.push(LayerCache::Full(full)),
            }
        }
        let (id, omsr, kv_bytes) =
            self.promote_request(caches, &modes, j.decode_mode, len, first_token);
        Ok(ChunkOutcome::Done {
            id,
            report: PrefillReport {
                bucket: j.total_bucket,
                prompt_len: len,
                modes,
                omsr,
                total_us: j.compute_us,
                router_us: j.router_us,
                first_token,
                kv_bytes,
                chunks: j.chunks_done,
                cached_prefix_tokens: j.cached_prefix,
            },
        })
    }

    /// Drop a partially-prefilled job, freeing its staged KV (mid-
    /// prefill cancellation / deadline eviction).
    pub fn prefill_cancel(&mut self, job: u64) -> bool {
        match self.prefill_jobs.remove(&job) {
            Some(j) => {
                self.free_job(j);
                true
            }
            None => false,
        }
    }

    /// Pre-flight one decode append for request `id` (DESIGN.md §15):
    /// grow every Full layer's capacity for one more token BEFORE any
    /// layer writes its K/V, retrying each growth once through
    /// prefix-cache eviction. Sparse rings never allocate. A sparse
    /// ring append is an irreversible in-place overwrite, so without
    /// this a growth failure at layer L would leave layers `0..L`
    /// already advanced by the new token; with it, a pool-starved step
    /// fails with every cache bit-identical to before the call and is
    /// safe to retry once the scheduler has freed pages by preemption.
    fn reserve_decode_append(&mut self, id: u64) -> Result<()> {
        let state = self
            .requests
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown request {id}"))?;
        for cache in state.caches.iter_mut() {
            let LayerCache::Full(c) = cache else { continue };
            let mut reserved = c.reserve_for_append(&mut self.pool);
            if reserved.is_err() {
                let need = self
                    .pool
                    .pages_for(2 * self.cfg.model.n_heads * c.capacity().max(1) * self.cfg.model.head_dim);
                self.prefix.evict_for(&mut self.pool, need);
                reserved = c.reserve_for_append(&mut self.pool);
            }
            reserved?;
        }
        Ok(())
    }

    /// One decode step: consume the request's `last_token`, produce the
    /// next. The caller owns the stop condition (EOS / max tokens).
    pub fn decode_step(&mut self, id: u64) -> Result<u32> {
        // pre-flight capacity for every Full layer so a pool-starved
        // step fails with the request's caches untouched (§15)
        self.reserve_decode_append(id)?;
        let cfg = &self.cfg;
        let state = self
            .requests
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown request {id}"))?;
        let pos = state.n_tokens;
        let mut hidden = self.weights.embed_one(state.last_token);
        let pos_arr = [pos as i32];

        for layer in 0..cfg.model.n_layers {
            let w = &self.weights.layers[layer];
            // stage 1: project + rope the current token
            let qkv = self.rt.run(
                "decode_qkv",
                &[
                    Arg::F32(&hidden),
                    Arg::I32(&pos_arr),
                    Arg::F32(&w.norm1),
                    Arg::F32(&w.wq),
                    Arg::F32(&w.wk),
                    Arg::F32(&w.wv),
                ],
            )?;
            anyhow::ensure!(qkv.len() == 3, "decode_qkv must return (q, k, v)");
            let (q, k_new, v_new) = (&qkv[0], &qkv[1], &qkv[2]);

            // stage 2: append then attend over the cache. On the
            // zero-copy fast path the KV arguments are borrowed views of
            // the cache's internal executable-layout buffers — a decode
            // step clones no KV at all (pinned by the
            // `decode_fast_path_stages_kv_without_copies` integration
            // test via the ExeStats kv_bytes counters).
            let cache = &mut state.caches[layer];
            match cache {
                LayerCache::Full(c) => {
                    let mut appended = c.append(&mut self.pool, &k_new.data, &v_new.data);
                    if appended.is_err() {
                        // cache growth starved for pages: reclaim cold
                        // prefix-cache entries and retry once before
                        // surfacing the typed pool error
                        let need = self
                            .pool
                            .pages_for(2 * cfg.model.n_heads * c.capacity().max(1) * cfg.model.head_dim);
                        self.prefix.evict_for(&mut self.pool, need);
                        appended = c.append(&mut self.pool, &k_new.data, &v_new.data);
                    }
                    appended?;
                    let bucket = cfg
                        .decode_attend_bucket(c.len(), c.capacity())
                        .ok_or_else(|| anyhow::anyhow!("KV overflow at {}", c.len()))?;
                    let valid_arr = [c.len() as i32];
                    let exe = format!("decode_attend_fa_{bucket}");
                    let kv_bytes = (2 * cfg.model.n_heads * bucket * cfg.model.head_dim * 4) as u64;
                    let out = if self.zero_copy && bucket == c.capacity() {
                        let (kt, vt) = c.view(&self.pool);
                        let out = self.rt.run(
                            &exe,
                            &[
                                Arg::F32(&hidden),
                                Arg::F32(q),
                                Arg::F32View(kt),
                                Arg::F32View(vt),
                                Arg::I32(&valid_arr),
                                Arg::F32(&w.wo),
                                Arg::F32(&w.norm2),
                                Arg::F32(&w.w_ff1),
                                Arg::F32(&w.w_ff2),
                            ],
                        )?;
                        self.rt.note_kv_transfer(&exe, 0, kv_bytes);
                        out
                    } else {
                        // misaligned bucket (prefill buckets not in the
                        // decode ledger): re-bucket into owned tensors
                        let (kt, vt) = c.as_tensors(&self.pool, bucket);
                        let out = self.rt.run(
                            &exe,
                            &[
                                Arg::F32(&hidden),
                                Arg::F32(q),
                                Arg::F32(&kt),
                                Arg::F32(&vt),
                                Arg::I32(&valid_arr),
                                Arg::F32(&w.wo),
                                Arg::F32(&w.norm2),
                                Arg::F32(&w.w_ff1),
                                Arg::F32(&w.w_ff2),
                            ],
                        )?;
                        self.rt.note_kv_transfer(&exe, kv_bytes, 0);
                        out
                    };
                    anyhow::ensure!(!out.is_empty(), "decode_attend returned no output");
                    hidden = out.into_iter().next().unwrap();
                }
                LayerCache::Sparse(c) => {
                    c.append(&mut self.pool, &k_new.data, &v_new.data);
                    let kv_bytes =
                        (2 * cfg.model.n_heads * cfg.sa_buf * cfg.model.head_dim * 4) as u64;
                    let out = if self.zero_copy {
                        // the sparse ring is always in executable layout
                        let (kt, vt, valid) = c.view(&self.pool);
                        let valid_arr = [valid as i32];
                        let out = self.rt.run(
                            "decode_attend_sa",
                            &[
                                Arg::F32(&hidden),
                                Arg::F32(q),
                                Arg::F32View(kt),
                                Arg::F32View(vt),
                                Arg::I32(&valid_arr),
                                Arg::F32(&w.wo),
                                Arg::F32(&w.norm2),
                                Arg::F32(&w.w_ff1),
                                Arg::F32(&w.w_ff2),
                            ],
                        )?;
                        self.rt.note_kv_transfer("decode_attend_sa", 0, kv_bytes);
                        out
                    } else {
                        let (kt, vt, valid) = c.as_tensors(&self.pool);
                        let valid_arr = [valid as i32];
                        let out = self.rt.run(
                            "decode_attend_sa",
                            &[
                                Arg::F32(&hidden),
                                Arg::F32(q),
                                Arg::F32(&kt),
                                Arg::F32(&vt),
                                Arg::I32(&valid_arr),
                                Arg::F32(&w.wo),
                                Arg::F32(&w.norm2),
                                Arg::F32(&w.w_ff1),
                                Arg::F32(&w.w_ff2),
                            ],
                        )?;
                        self.rt.note_kv_transfer("decode_attend_sa", kv_bytes, 0);
                        out
                    };
                    anyhow::ensure!(!out.is_empty(), "decode_attend returned no output");
                    hidden = out.into_iter().next().unwrap();
                }
            }
        }

        let logits = self.rt.run(
            "lm_head",
            &[
                Arg::F32(&hidden),
                Arg::F32(&self.weights.norm_f),
                Arg::F32(&self.weights.lm_head),
            ],
        )?;
        let next = argmax(&logits[0].data);
        state.n_tokens += 1;
        state.last_token = next;
        Ok(next)
    }

    /// One decode step for every request in `ids` — a single token
    /// round (DESIGN.md §9). Per-request results are aligned with the
    /// input order; a failed request never poisons its batchmates.
    pub fn decode_batch(&mut self, ids: &[u64]) -> Vec<Result<u32>> {
        self.decode_batch_report(ids).tokens
    }

    /// [`Engine::decode_batch`] plus the round's timing, KV-transfer
    /// totals and per-mode group occupancy — the full scheduler reply.
    pub fn decode_batch_report(&mut self, ids: &[u64]) -> DecodeBatchReport {
        if self.batch_decode && self.rt.accepts_decode_batch() {
            self.decode_batch_batched(ids)
        } else {
            self.decode_batch_serial(ids)
        }
    }

    /// Serial fallback: B independent `decode_step` walks (backends
    /// without batch support, or `FLUX_BATCH_DECODE=0` for A/B runs).
    fn decode_batch_serial(&mut self, ids: &[u64]) -> DecodeBatchReport {
        let t0 = Instant::now();
        let mut tokens = Vec::with_capacity(ids.len());
        let mut step_us = Vec::with_capacity(ids.len());
        let (mut fa_group_slots, mut sa_group_slots) = (0u64, 0u64);
        let mut seen = HashSet::with_capacity(ids.len());
        for &id in ids {
            // a repeated id must fail its own slot, exactly like the
            // batched path — stepping it twice would silently advance
            // the request two tokens in one round
            if !seen.insert(id) {
                tokens.push(Err(anyhow::anyhow!("duplicate request {id} in decode round")));
                step_us.push(0);
                continue;
            }
            if let Some(state) = self.requests.get(&id) {
                for cache in &state.caches {
                    match cache {
                        LayerCache::Full(_) => fa_group_slots += 1,
                        LayerCache::Sparse(_) => sa_group_slots += 1,
                    }
                }
            }
            let t = Instant::now();
            tokens.push(self.decode_step(id));
            step_us.push(t.elapsed().as_micros() as u64);
        }
        DecodeBatchReport {
            tokens,
            step_us,
            total_us: t0.elapsed().as_micros() as u64,
            kv_transfer: self.kv_transfer_totals(),
            fa_group_slots,
            sa_group_slots,
            batched: false,
            pool_pages: self.pool_gauges(),
            prefix_evictions: self.prefix.stats().evictions,
            prefix_retained_pages: self.prefix.retained_pages() as u64,
            replica: self.replica,
        }
    }

    /// The batched decode hot path. Per layer, the batch is partitioned
    /// by that layer's routed cache layout into an FA group (full
    /// caches, per-request buckets) and an SA group (sparse rings) —
    /// routing is per-request per-layer, so this is exactly the paper's
    /// contiguous same-mode grouping. Each group runs as ONE backend
    /// call with every request's KV staged zero-copy; the round ends in
    /// one `(B,d)×(d,V)` lm_head. Token order is bit-identical to B
    /// independent serial `decode_step` loops (pinned by
    /// `tests/batched.rs`).
    fn decode_batch_batched(&mut self, ids: &[u64]) -> DecodeBatchReport {
        let t0 = Instant::now();
        let n_layers = self.cfg.model.n_layers;
        let d = self.cfg.model.d_model;
        let (nh, dd) = (self.cfg.model.n_heads, self.cfg.model.head_dim);
        let hd = nh * dd;
        let sa_buf = self.cfg.sa_buf;

        // Detach the batch's states from the request map so the layer
        // loop can append to one slot's caches while staging borrowed
        // views of the others; everything is re-attached before return.
        let mut tokens: Vec<Option<Result<u32>>> =
            std::iter::repeat_with(|| None).take(ids.len()).collect();
        let mut slots: Vec<(usize, u64, RequestState)> = Vec::with_capacity(ids.len());
        let mut seen = HashSet::with_capacity(ids.len());
        for (i, &id) in ids.iter().enumerate() {
            // detaching on first sight makes a repeated id indistinguishable
            // from an unknown one; name the failure explicitly instead
            if !seen.insert(id) {
                tokens[i] = Some(Err(anyhow::anyhow!("duplicate request {id} in decode round")));
                continue;
            }
            match self.requests.remove(&id) {
                Some(s) => slots.push((i, id, s)),
                None => tokens[i] = Some(Err(anyhow::anyhow!("unknown request {id}"))),
            }
        }
        let n_slots = slots.len();
        let mut hidden: Vec<Vec<f32>> =
            slots.iter().map(|(_, _, s)| self.weights.embed_one(s.last_token).data).collect();
        let mut failed: Vec<Option<String>> = vec![None; n_slots];
        let (mut fa_group_slots, mut sa_group_slots) = (0u64, 0u64);

        // Pre-flight (DESIGN.md §15): grow every slot's Full layers for
        // this token BEFORE any layer writes its K/V. A sparse ring
        // append is an irreversible in-place overwrite, so a mid-round
        // growth failure at layer L would otherwise leave layers 0..L
        // already advanced; reserving up front means a pool-starved
        // slot fails alone with its caches untouched — safe to retry
        // next round once the scheduler has preempted a victim.
        for (si, (_, _, state)) in slots.iter_mut().enumerate() {
            for cache in state.caches.iter_mut() {
                let LayerCache::Full(c) = cache else { continue };
                let mut reserved = c.reserve_for_append(&mut self.pool);
                if reserved.is_err() {
                    let need = self.pool.pages_for(2 * nh * c.capacity().max(1) * dd);
                    self.prefix.evict_for(&mut self.pool, need);
                    reserved = c.reserve_for_append(&mut self.pool);
                }
                if let Err(e) = reserved {
                    failed[si] = Some(e.to_string());
                    break;
                }
            }
        }

        for layer in 0..n_layers {
            let live: Vec<usize> = (0..n_slots).filter(|&si| failed[si].is_none()).collect();
            if live.is_empty() {
                break;
            }
            let bb = live.len();
            let w = &self.weights.layers[layer];

            // stage 1: one batched project + RoPE over every live row
            let mut x_data = Vec::with_capacity(bb * d);
            let mut pos = Vec::with_capacity(bb);
            for &si in &live {
                x_data.extend_from_slice(&hidden[si]);
                pos.push(slots[si].2.n_tokens as i32);
            }
            let x = HostTensor::new(vec![bb, d], x_data);
            let qkv = match self.rt.run(
                "decode_qkv_batch",
                &[
                    Arg::F32(&x),
                    Arg::I32(&pos),
                    Arg::F32(&w.norm1),
                    Arg::F32(&w.wq),
                    Arg::F32(&w.wk),
                    Arg::F32(&w.wv),
                ],
            ) {
                Ok(out) => out,
                Err(e) => {
                    let msg = e.to_string();
                    for &si in &live {
                        failed[si] = Some(msg.clone());
                    }
                    break;
                }
            };
            let (q_all, k_all, v_all) = (&qkv[0], &qkv[1], &qkv[2]);

            // append the new token's K/V, partitioning the batch by
            // this layer's routed cache layout
            let mut fa_rows: Vec<usize> = Vec::new(); // indices into `live`
            let mut sa_rows: Vec<usize> = Vec::new();
            for (row, &si) in live.iter().enumerate() {
                let k_new = &k_all.data[row * hd..(row + 1) * hd];
                let v_new = &v_all.data[row * hd..(row + 1) * hd];
                match &mut slots[si].2.caches[layer] {
                    LayerCache::Full(c) => {
                        let mut res = c.append(&mut self.pool, k_new, v_new);
                        if res.is_err() {
                            // growth starved for pages: reclaim cold
                            // prefix-cache entries and retry once
                            let need = self.pool.pages_for(2 * nh * c.capacity().max(1) * dd);
                            self.prefix.evict_for(&mut self.pool, need);
                            res = c.append(&mut self.pool, k_new, v_new);
                        }
                        match res {
                            // a slot whose cache growth outruns the pool
                            // fails alone — its batchmates keep decoding
                            Ok(()) => fa_rows.push(row),
                            Err(e) => failed[si] = Some(e.to_string()),
                        }
                    }
                    LayerCache::Sparse(c) => {
                        c.append(&mut self.pool, k_new, v_new);
                        sa_rows.push(row);
                    }
                }
            }

            // stage 2: one batched attend per (layer, mode) group
            for (sparse, rows) in [(false, &fa_rows), (true, &sa_rows)] {
                if rows.is_empty() {
                    continue;
                }
                enum Kv {
                    View,
                    Owned(usize),
                }
                struct Member {
                    row: usize,
                    kv: Kv,
                    valid: usize,
                }
                let mut owned: Vec<(HostTensor, HostTensor)> = Vec::new();
                let mut members: Vec<Member> = Vec::with_capacity(rows.len());
                let (mut moved, mut borrowed) = (0u64, 0u64);
                for &row in rows {
                    let si = live[row];
                    match &slots[si].2.caches[layer] {
                        LayerCache::Full(c) => {
                            let Some(bucket) = self.cfg.decode_attend_bucket(c.len(), c.capacity())
                            else {
                                failed[si] = Some(format!("KV overflow at {}", c.len()));
                                continue;
                            };
                            let bytes = (2 * nh * bucket * dd * 4) as u64;
                            if self.zero_copy && bucket == c.capacity() {
                                members.push(Member { row, kv: Kv::View, valid: c.len() });
                                borrowed += bytes;
                            } else {
                                owned.push(c.as_tensors(&self.pool, bucket));
                                members.push(Member {
                                    row,
                                    kv: Kv::Owned(owned.len() - 1),
                                    valid: c.len(),
                                });
                                moved += bytes;
                            }
                        }
                        LayerCache::Sparse(c) => {
                            let bytes = (2 * nh * sa_buf * dd * 4) as u64;
                            if self.zero_copy {
                                members.push(Member { row, kv: Kv::View, valid: c.len() });
                                borrowed += bytes;
                            } else {
                                let (kt, vt, _) = c.as_tensors(&self.pool);
                                owned.push((kt, vt));
                                members.push(Member {
                                    row,
                                    kv: Kv::Owned(owned.len() - 1),
                                    valid: c.len(),
                                });
                                moved += bytes;
                            }
                        }
                    }
                }
                if members.is_empty() {
                    continue;
                }
                let bg = members.len();
                let mut xg_data = Vec::with_capacity(bg * d);
                let mut qg_data = Vec::with_capacity(bg * hd);
                let mut valid_arr: Vec<i32> = Vec::with_capacity(bg);
                for mem in &members {
                    xg_data.extend_from_slice(&x.data[mem.row * d..(mem.row + 1) * d]);
                    qg_data.extend_from_slice(&q_all.data[mem.row * hd..(mem.row + 1) * hd]);
                    valid_arr.push(mem.valid as i32);
                }
                let xg = HostTensor::new(vec![bg, d], xg_data);
                let qg = HostTensor::new(vec![bg, nh, dd], qg_data);
                let exe = if sparse { "attend_batch_sa" } else { "attend_batch_fa" };
                let mut call: Vec<Arg> = vec![
                    Arg::F32(&xg),
                    Arg::F32(&qg),
                    Arg::I32(&valid_arr),
                    Arg::F32(&w.wo),
                    Arg::F32(&w.norm2),
                    Arg::F32(&w.w_ff1),
                    Arg::F32(&w.w_ff2),
                ];
                for mem in &members {
                    match &mem.kv {
                        Kv::View => match &slots[live[mem.row]].2.caches[layer] {
                            LayerCache::Full(c) => {
                                let (kt, vt) = c.view(&self.pool);
                                call.push(Arg::F32View(kt));
                                call.push(Arg::F32View(vt));
                            }
                            LayerCache::Sparse(c) => {
                                let (kt, vt, _) = c.view(&self.pool);
                                call.push(Arg::F32View(kt));
                                call.push(Arg::F32View(vt));
                            }
                        },
                        Kv::Owned(j) => {
                            call.push(Arg::F32(&owned[*j].0));
                            call.push(Arg::F32(&owned[*j].1));
                        }
                    }
                }
                match self.rt.run(exe, &call) {
                    Ok(out) => {
                        self.rt.note_kv_transfer(exe, moved, borrowed);
                        let y = &out[0];
                        for (g, mem) in members.iter().enumerate() {
                            hidden[live[mem.row]].copy_from_slice(&y.data[g * d..(g + 1) * d]);
                        }
                        if sparse {
                            sa_group_slots += bg as u64;
                        } else {
                            fa_group_slots += bg as u64;
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for mem in &members {
                            failed[live[mem.row]] = Some(msg.clone());
                        }
                    }
                }
            }
        }

        // the whole round's lm_head is one (B,d)×(d,V) matmul
        let live: Vec<usize> = (0..n_slots).filter(|&si| failed[si].is_none()).collect();
        if !live.is_empty() {
            let bb = live.len();
            let mut x_data = Vec::with_capacity(bb * d);
            for &si in &live {
                x_data.extend_from_slice(&hidden[si]);
            }
            let x = HostTensor::new(vec![bb, d], x_data);
            match self.rt.run(
                "lm_head_batch",
                &[Arg::F32(&x), Arg::F32(&self.weights.norm_f), Arg::F32(&self.weights.lm_head)],
            ) {
                Ok(out) => {
                    let logits = &out[0];
                    let v = self.cfg.model.vocab_size;
                    for (g, &si) in live.iter().enumerate() {
                        let tok = argmax(&logits.data[g * v..(g + 1) * v]);
                        let (i, _, state) = &mut slots[si];
                        state.n_tokens += 1;
                        state.last_token = tok;
                        tokens[*i] = Some(Ok(tok));
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for &si in &live {
                        failed[si] = Some(msg.clone());
                    }
                }
            }
        }

        // re-attach states and materialize per-slot failures
        for (si, (i, id, state)) in slots.into_iter().enumerate() {
            if let Some(msg) = failed[si].take() {
                tokens[i] = Some(Err(anyhow::anyhow!(msg)));
            }
            self.requests.insert(id, state);
        }
        let total_us = t0.elapsed().as_micros() as u64;
        // amortized attribution: each slot gets total/n, with the
        // division remainder spread over the first slots so the batch
        // sums back to exactly the round's wall time
        let n = ids.len().max(1) as u64;
        let (share, rem) = (total_us / n, total_us % n);
        DecodeBatchReport {
            tokens: tokens
                .into_iter()
                .map(|t| t.unwrap_or_else(|| Err(anyhow::anyhow!("request dropped from batch"))))
                .collect(),
            step_us: (0..ids.len() as u64).map(|i| share + u64::from(i < rem)).collect(),
            total_us,
            kv_transfer: self.kv_transfer_totals(),
            fa_group_slots,
            sa_group_slots,
            batched: true,
            pool_pages: self.pool_gauges(),
            prefix_evictions: self.prefix.stats().evictions,
            prefix_retained_pages: self.prefix.retained_pages() as u64,
            replica: self.replica,
        }
    }

    /// Convenience: prefill + greedy decode until EOS or `max_new`.
    pub fn generate(
        &mut self,
        tokens: &[u32],
        policy: &Policy,
        router_name: &str,
        max_new: usize,
    ) -> Result<(Vec<u32>, PrefillReport)> {
        let (id, report) = self.prefill(tokens, policy, router_name)?;
        let mut out = vec![report.first_token];
        while out.len() < max_new && *out.last().unwrap() != crate::tokenizer::EOS {
            out.push(self.decode_step(id)?);
        }
        self.release(id);
        Ok((out, report))
    }

    /// UnComp-style layer profiling (paper Appendix C.1): run an FA
    /// prefill and return each layer's matrix-entropy score of its
    /// output hidden states. Feeds the entropy-ranked static baselines
    /// and the Fig 1a progressive-sparsification experiment.
    pub fn profile_entropy(&mut self, tokens: &[u32], top_k: usize) -> Result<Vec<f64>> {
        let cfg = &self.cfg;
        let bucket = cfg
            .prefill_bucket(tokens.len())
            .ok_or_else(|| anyhow::anyhow!("prompt too long"))?;
        let valid = tokens.len();
        let d = cfg.model.d_model;
        let n_layers = cfg.model.n_layers;
        let mut hidden = self.weights.embed_tokens(tokens, bucket);
        let mut scores = Vec::with_capacity(n_layers);
        let exe = format!("layer_fa_prefill_{bucket}");
        let valid_arr = [valid as i32];
        let pass_valid = self.rt.accepts_prefill_valid_arg();
        for layer in 0..n_layers {
            let w = &self.weights.layers[layer];
            let mut call_args = vec![
                Arg::F32(&hidden),
                Arg::F32(&w.norm1),
                Arg::F32(&w.wq),
                Arg::F32(&w.wk),
                Arg::F32(&w.wv),
                Arg::F32(&w.wo),
                Arg::F32(&w.norm2),
                Arg::F32(&w.w_ff1),
                Arg::F32(&w.w_ff2),
            ];
            if pass_valid {
                call_args.push(Arg::I32(&valid_arr));
            }
            let out = self.rt.run(&exe, &call_args)?;
            hidden = out.into_iter().next().unwrap();
            scores.push(crate::baselines::matrix_entropy(
                &hidden.data[..valid * d],
                valid,
                d,
                top_k,
            ));
        }
        Ok(scores)
    }

    /// Drop a request's state (cancellation or completion), returning
    /// every page it held to the pool.
    pub fn release(&mut self, id: u64) -> bool {
        match self.requests.remove(&id) {
            Some(state) => {
                for c in state.caches {
                    c.free(&mut self.pool);
                }
                true
            }
            None => false,
        }
    }

    pub fn request_state(&self, id: u64) -> Option<&RequestState> {
        self.requests.get(&id)
    }

    /// Preempt a live request (DESIGN.md §15): drop its state and free
    /// ALL its pages, but first snapshot each sparse ring into a fresh
    /// pool block — ring state is not reconstructible by replaying the
    /// prompt alone (the window has overwritten older tokens in place),
    /// so the snapshots serve as the integrity oracle the resume path's
    /// teacher-forced catch-up is checked against. Full caches are
    /// freed FIRST so the (much smaller) snapshots can draw on their
    /// pages even on a bone-dry pool; a snapshot that still fails
    /// degrades to `None` (that layer just skips the catch-up check).
    pub fn preempt(&mut self, id: u64) -> Result<PreemptInfo> {
        let state = self
            .requests
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown request {id}"))?;
        let n_layers = state.caches.len();
        let mut ring_snaps: Vec<Option<RingSnap>> = vec![None; n_layers];
        let mut pages_freed = 0usize;
        let mut rings: Vec<(usize, SparseCache)> = Vec::new();
        for (layer, c) in state.caches.into_iter().enumerate() {
            match c {
                LayerCache::Full(f) => {
                    pages_freed += f.pages();
                    f.free(&mut self.pool);
                }
                LayerCache::Sparse(r) => rings.push((layer, r)),
            }
        }
        let mut snap_pages = 0usize;
        for (layer, r) in rings {
            if let Ok((block, sink_len, total_seen)) = r.snapshot(&mut self.pool) {
                snap_pages += block.pages;
                ring_snaps[layer] = Some(RingSnap { block, sink_len, total_seen });
            }
            pages_freed += r.pages();
            r.free(&mut self.pool);
        }
        Ok(PreemptInfo { pages_freed, snap_pages, ring_snaps })
    }

    /// Resume catch-up (DESIGN.md §15): after the resume prefill of the
    /// original PROMPT has re-derived the first generated token, replay
    /// the remaining already-streamed tokens through the real decode
    /// path, teacher-forcing each step's sampled token to the recorded
    /// one. Running the decode kernels (not prefill) rebuilds sparse
    /// rings in decode append order — ring contents after a wrap depend
    /// on the append order, so full-prompt re-prefill of
    /// `prompt ++ generated` would NOT be bit-identical for sparse
    /// routes; teacher-forcing through decode is. When `verify` carries
    /// preemption-time ring snapshots, the rebuilt rings are checked
    /// bitwise against them (cursor phase + contents); every snapshot
    /// block is returned to the pool on all exit paths.
    pub fn catch_up(&mut self, id: u64, force: &[u32], verify: &[Option<RingSnap>]) -> Result<()> {
        let mut result: Result<()> = Ok(());
        for &tok in force {
            if let Err(e) = self.decode_step(id) {
                result = Err(e);
                break;
            }
            let state = self.requests.get_mut(&id).expect("request exists after decode_step");
            state.last_token = tok;
        }
        if result.is_ok() {
            if let Some(state) = self.requests.get(&id) {
                for (layer, snap) in verify.iter().enumerate() {
                    let Some(s) = snap else { continue };
                    let ok = match state.caches.get(layer) {
                        Some(LayerCache::Sparse(r)) => {
                            r.matches_snapshot(&self.pool, s.block, s.sink_len, s.total_seen)
                        }
                        _ => false,
                    };
                    if !ok {
                        result = Err(anyhow::anyhow!(
                            "resume integrity: rebuilt ring at layer {layer} diverges from its preemption snapshot"
                        ));
                        break;
                    }
                }
            }
        }
        for s in verify.iter().flatten() {
            self.pool.free(s.block);
        }
        result
    }

    /// Return a batch of preemption-time ring snapshots to the pool
    /// without resuming (the parked request was cancelled, expired, or
    /// failed over to another replica).
    pub fn free_snaps(&mut self, snaps: &[Option<RingSnap>]) {
        for s in snaps.iter().flatten() {
            self.pool.free(s.block);
        }
    }
}

/// One layer's attention-mode decision, shared verbatim by the
/// monolithic and chunked prefill paths (a divergence here would break
/// the chunked-vs-monolithic routing contract): static policies are
/// table lookups; Flux runs the Layer Router on the pooled boundary
/// descriptor of `hidden`'s first `valid` rows, accumulating the router
/// wall time into `router_us`.
#[allow(clippy::too_many_arguments)]
fn route_layer(
    rt: &mut dyn Backend,
    routers: &HashMap<String, RouterNet>,
    policy: &Policy,
    router_name: &str,
    hidden: &HostTensor,
    valid: usize,
    pool: usize,
    layer: usize,
    router_us: &mut u64,
) -> Result<AttnMode> {
    Ok(match policy {
        Policy::Backbone => AttnMode::Fa,
        Policy::Static { modes, .. } => modes[layer],
        Policy::Flux { sa_mode, .. } => {
            let t0 = Instant::now();
            let desc = pool_descriptor(hidden, valid, pool);
            let net = routers
                .get(router_name)
                .ok_or_else(|| anyhow::anyhow!("router '{router_name}' missing"))?;
            let (is_fa, _) = net.route(rt, layer, &desc)?;
            *router_us += t0.elapsed().as_micros() as u64;
            if is_fa {
                AttnMode::Fa
            } else {
                *sa_mode
            }
        }
    })
}

// ---------------------------------------------------------------------------
// EngineHandle: Send/Sync channel facade for the coordinator
// ---------------------------------------------------------------------------

pub enum EngineJob {
    Prefill {
        tokens: Vec<u32>,
        policy: Policy,
        router: String,
        reply: std::sync::mpsc::Sender<Result<(u64, PrefillReport)>>,
    },
    /// Open a chunked prefill job (no compute — DESIGN.md §10).
    PrefillOpen {
        tokens: Vec<u32>,
        policy: Policy,
        router: String,
        chunk_tokens: usize,
        reply: std::sync::mpsc::Sender<Result<u64>>,
    },
    /// Run the next chunk of an open prefill job.
    PrefillChunk {
        job: u64,
        reply: std::sync::mpsc::Sender<Result<ChunkOutcome>>,
    },
    /// Drop a partially-prefilled job, freeing its staged KV.
    PrefillCancel {
        job: u64,
    },
    DecodeStep {
        id: u64,
        reply: std::sync::mpsc::Sender<Result<u32>>,
    },
    /// One token round over the whole active set: per-request results,
    /// timings, KV totals and group occupancy ride on a single reply —
    /// the scheduler's one engine round-trip per decode round. This
    /// reply piggyback is the ONLY KV-totals channel: the PR-4-era
    /// `KvTransferTotals` polling job was dead scheduler-facing surface
    /// and has been deleted (`Engine::kv_transfer_totals` remains for
    /// in-process callers like the bench harness).
    DecodeBatch {
        ids: Vec<u64>,
        reply: std::sync::mpsc::Sender<DecodeBatchReport>,
    },
    /// Largest admissible prompt length (the biggest prefill bucket) —
    /// the coordinator validates prompts at admission against this.
    MaxPromptLen {
        reply: std::sync::mpsc::Sender<usize>,
    },
    /// Pool geometry snapshot for worst-case page admission — fetched
    /// once by the coordinator at startup (the geometry is immutable).
    PoolProfile {
        reply: std::sync::mpsc::Sender<PoolProfile>,
    },
    Release {
        id: u64,
    },
    /// KV pool drain check (tests): `Ok` when every page is free apart
    /// from those legitimately retained by the prefix index, and the
    /// free list has coalesced. Queued FIFO like every other job, so it
    /// observes all previously-sent `Release`s.
    PoolDrained {
        reply: std::sync::mpsc::Sender<std::result::Result<(), String>>,
    },
    /// Enable/disable the cross-request prefix cache (DESIGN.md §13).
    SetPrefixCache {
        enabled: bool,
        capacity_pages: Option<usize>,
        reply: std::sync::mpsc::Sender<()>,
    },
    /// Drop every cached prefix (pinned entries free on last unpin).
    PrefixClear {
        reply: std::sync::mpsc::Sender<()>,
    },
    /// Prefix-cache counter snapshot.
    PrefixStats {
        reply: std::sync::mpsc::Sender<PrefixStats>,
    },
    /// Preempt a live request: free all its pages, snapshotting sparse
    /// rings first (DESIGN.md §15).
    Preempt {
        id: u64,
        reply: std::sync::mpsc::Sender<Result<PreemptInfo>>,
    },
    /// Teacher-forced resume catch-up after the resume prefill
    /// (DESIGN.md §15); verifies and frees the ring snapshots.
    CatchUp {
        id: u64,
        force: Vec<u32>,
        verify: Vec<Option<RingSnap>>,
        reply: std::sync::mpsc::Sender<Result<()>>,
    },
    /// Return un-resumed ring snapshots to the pool (parked request
    /// cancelled, expired, or failed over).
    FreeSnaps {
        snaps: Vec<Option<RingSnap>>,
    },
    Shutdown,
}

/// Typed engine-death error: the engine thread panicked, terminated, or
/// (with a round watchdog configured) stalled past its deadline. The
/// scheduler downcasts to this to route into supervision instead of
/// treating it like a per-request failure (DESIGN.md §12).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineFailed {
    /// Recorded panic message, or a description of how the thread died.
    pub cause: String,
    /// Which engine lifetime failed: 0 for the initial spawn,
    /// incremented by every [`EngineHandle::respawn`].
    pub generation: u64,
    /// `true` when a round watchdog classified the engine as stalled
    /// (the thread may still be alive inside a wedged kernel call; it
    /// winds itself down once its job channel disconnects).
    pub stalled: bool,
}

impl std::fmt::Display for EngineFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.stalled {
            write!(f, "engine stalled (generation {}): {}", self.generation, self.cause)
        } else {
            write!(f, "engine failed (generation {}): {}", self.generation, self.cause)
        }
    }
}

impl std::error::Error for EngineFailed {}

/// One engine lifetime as seen from the handle: the job channel into
/// the executor thread, the slot where that thread records its panic
/// cause, and the lifetime's generation number. [`EngineHandle::respawn`]
/// swaps the whole link atomically, so every handle clone migrates to
/// the new engine together.
struct EngineLink {
    tx: std::sync::mpsc::Sender<EngineJob>,
    failure: Arc<Mutex<Option<String>>>,
    generation: u64,
}

struct HandleInner {
    artifacts: std::path::PathBuf,
    pool_geometry: Option<(usize, usize)>,
    /// Replica identity stamped onto every engine lifetime this handle
    /// spawns (initial spawn AND respawns) — DESIGN.md §14.
    replica: usize,
    link: std::sync::RwLock<EngineLink>,
}

/// Cloneable, `Send` handle that forwards jobs to the executor thread.
/// Calls are blocking (the engine serializes all device work anyway);
/// the thread-based coordinator runs them from its scheduler thread.
///
/// Supervision (DESIGN.md §12): the job loop runs each job under
/// `catch_unwind`, so a kernel panic kills the *engine lifetime* (the
/// thread records its cause and exits — a panicked engine's state is
/// never reused) but not the process. Handle calls against a dead
/// engine return a typed [`EngineFailed`]; [`EngineHandle::respawn`]
/// loads a fresh engine from the original artifacts and atomically
/// repoints every clone of the handle at it.
#[derive(Clone)]
pub struct EngineHandle {
    inner: Arc<HandleInner>,
}

impl EngineHandle {
    /// Spawn the executor thread and load the engine on it.
    pub fn spawn(artifacts: std::path::PathBuf) -> Result<Self> {
        Self::spawn_inner(artifacts, None, None, 0)
    }

    /// [`EngineHandle::spawn`] with an explicit KV pool geometry
    /// `(page_tokens, budget_tokens)` — the pool-pressure bench and
    /// tests shrink the budget to force typed `Overloaded` rejections.
    pub fn spawn_with_pool(
        artifacts: std::path::PathBuf,
        page_tokens: usize,
        budget_tokens: usize,
    ) -> Result<Self> {
        Self::spawn_inner(artifacts, Some((page_tokens, budget_tokens)), None, 0)
    }

    /// [`EngineHandle::spawn`] with a deterministic fault-injection
    /// plan for the FIRST engine lifetime (chaos tests and the
    /// fault-recovery bench). Respawns are always fault-free.
    pub fn spawn_with_faults(
        artifacts: std::path::PathBuf,
        pool_geometry: Option<(usize, usize)>,
        plan: crate::runtime::chaos::FaultPlan,
    ) -> Result<Self> {
        Self::spawn_inner(artifacts, pool_geometry, Some(plan), 0)
    }

    /// [`EngineHandle::spawn`] as replica `replica` of a
    /// [`crate::coordinator::Coordinator`] replica set (DESIGN.md §14):
    /// the identity is stamped onto the engine (and every respawned
    /// lifetime) and rides on its reports.
    pub fn spawn_replica(artifacts: std::path::PathBuf, replica: usize) -> Result<Self> {
        Self::spawn_inner(artifacts, None, None, replica)
    }

    /// [`EngineHandle::spawn_replica`] with pool geometry and fault
    /// plan — replica-set chaos tests and the saturation bench fault
    /// ONE replica while its peers keep serving.
    pub fn spawn_replica_with(
        artifacts: std::path::PathBuf,
        pool_geometry: Option<(usize, usize)>,
        faults: Option<crate::runtime::chaos::FaultPlan>,
        replica: usize,
    ) -> Result<Self> {
        Self::spawn_inner(artifacts, pool_geometry, faults, replica)
    }

    /// [`EngineHandle::spawn`] honoring the `FLUX_FAULT_PLAN` /
    /// `FLUX_FAULT_SEED` environment (the `flux serve` / CI entry
    /// point; tests pass plans programmatically instead).
    pub fn spawn_from_env(artifacts: std::path::PathBuf) -> Result<Self> {
        Self::spawn_inner(artifacts, None, crate::runtime::chaos::FaultPlan::from_env()?, 0)
    }

    /// [`EngineHandle::spawn_from_env`] as replica `replica` — the
    /// `flux serve --replicas R` entry point. The env fault plan (when
    /// set) applies to every replica's first lifetime; each replica
    /// supervises and respawns independently.
    pub fn spawn_from_env_replica(
        artifacts: std::path::PathBuf,
        replica: usize,
    ) -> Result<Self> {
        Self::spawn_inner(
            artifacts,
            None,
            crate::runtime::chaos::FaultPlan::from_env()?,
            replica,
        )
    }

    fn spawn_inner(
        artifacts: std::path::PathBuf,
        pool_geometry: Option<(usize, usize)>,
        faults: Option<crate::runtime::chaos::FaultPlan>,
        replica: usize,
    ) -> Result<Self> {
        let (tx, failure) = Self::spawn_link(&artifacts, pool_geometry, faults, replica)?;
        Ok(Self {
            inner: Arc::new(HandleInner {
                artifacts,
                pool_geometry,
                replica,
                link: std::sync::RwLock::new(EngineLink { tx, failure, generation: 0 }),
            }),
        })
    }

    /// Spawn one executor thread (one engine lifetime) and wait for the
    /// engine to load on it. The returned failure slot is written by the
    /// thread if its job loop dies to a panic.
    fn spawn_link(
        artifacts: &std::path::Path,
        pool_geometry: Option<(usize, usize)>,
        faults: Option<crate::runtime::chaos::FaultPlan>,
        replica: usize,
    ) -> Result<(std::sync::mpsc::Sender<EngineJob>, Arc<Mutex<Option<String>>>)> {
        let (tx, rx) = std::sync::mpsc::channel::<EngineJob>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let failure: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let failure_slot = failure.clone();
        let artifacts = artifacts.to_path_buf();
        std::thread::Builder::new()
            .name(format!("flux-engine-{replica}"))
            .spawn(move || {
                let mut engine = match Engine::load_with_faults(&artifacts, pool_geometry, faults) {
                    Ok(mut e) => {
                        e.set_replica(replica);
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    // per-job panic isolation: a panicking kernel ends
                    // this engine lifetime (its state is untrusted from
                    // here on) but records why, so the supervisor can
                    // surface a typed cause instead of a hung channel
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_engine_job(&mut engine, job)
                    }));
                    match outcome {
                        Ok(true) => {}
                        Ok(false) => break,
                        Err(payload) => {
                            *failure_slot.lock().unwrap() = Some(panic_message(&payload));
                            break;
                        }
                    }
                }
            })?;
        ready_rx.recv()??;
        Ok((tx, failure))
    }

    /// Replace a dead (or stalled) engine with a fresh one loaded from
    /// the original artifacts, bumping the generation. Every clone of
    /// the handle migrates atomically; a stalled old thread winds itself
    /// down once its job channel disconnects (finishing — and freeing —
    /// whatever it was wedged on first). Returns the new generation.
    pub fn respawn(&self) -> Result<u64> {
        let mut link = self.inner.link.write().unwrap();
        let (tx, failure) = Self::spawn_link(
            &self.inner.artifacts,
            self.inner.pool_geometry,
            None,
            self.inner.replica,
        )?;
        let generation = link.generation + 1;
        *link = EngineLink { tx, failure, generation };
        Ok(generation)
    }

    /// Current engine generation: 0 for the initial spawn, +1 per
    /// [`EngineHandle::respawn`].
    pub fn generation(&self) -> u64 {
        self.inner.link.read().unwrap().generation
    }

    /// Replica identity this handle spawns its engine lifetimes under
    /// (DESIGN.md §14; 0 for standalone engines).
    pub fn replica(&self) -> usize {
        self.inner.replica
    }

    /// Snapshot the current link (never hold the lock across a blocking
    /// reply wait — `respawn` needs the write lock while the old engine
    /// may still be wedged).
    fn link(&self) -> (std::sync::mpsc::Sender<EngineJob>, Arc<Mutex<Option<String>>>, u64) {
        let l = self.inner.link.read().unwrap();
        (l.tx.clone(), l.failure.clone(), l.generation)
    }

    /// Typed engine-death error for the current lifetime, carrying the
    /// recorded panic cause when there is one.
    fn dead(failure: &Arc<Mutex<Option<String>>>, generation: u64) -> anyhow::Error {
        let cause = failure
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| "engine thread terminated".into());
        anyhow::Error::new(EngineFailed { cause, generation, stalled: false })
    }

    /// Send `job` and wait for its reply, with an optional watchdog
    /// deadline. A missing reply (thread dead) or a tripped deadline
    /// (thread stalled) both surface as typed [`EngineFailed`].
    fn roundtrip<T>(
        &self,
        rx: std::sync::mpsc::Receiver<T>,
        sent: std::result::Result<(), std::sync::mpsc::SendError<EngineJob>>,
        failure: Arc<Mutex<Option<String>>>,
        generation: u64,
        deadline: Option<std::time::Duration>,
    ) -> Result<T> {
        if sent.is_err() {
            return Err(Self::dead(&failure, generation));
        }
        match deadline {
            None => rx.recv().map_err(|_| Self::dead(&failure, generation)),
            Some(t) => rx.recv_timeout(t).map_err(|e| match e {
                std::sync::mpsc::RecvTimeoutError::Timeout => anyhow::Error::new(EngineFailed {
                    cause: format!("engine round exceeded the {}ms watchdog", t.as_millis()),
                    generation,
                    stalled: true,
                }),
                std::sync::mpsc::RecvTimeoutError::Disconnected => {
                    Self::dead(&failure, generation)
                }
            }),
        }
    }

    pub fn prefill(
        &self,
        tokens: Vec<u32>,
        policy: Policy,
        router: String,
    ) -> Result<(u64, PrefillReport)> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::Prefill { tokens, policy, router, reply });
        self.roundtrip(rx, sent, failure, generation, None)?
    }

    /// Open a chunked prefill job (DESIGN.md §10) — validation and
    /// staging allocation only; chunks run via
    /// [`EngineHandle::prefill_chunk`].
    pub fn prefill_open(
        &self,
        tokens: Vec<u32>,
        policy: Policy,
        router: String,
        chunk_tokens: usize,
    ) -> Result<u64> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::PrefillOpen { tokens, policy, router, chunk_tokens, reply });
        self.roundtrip(rx, sent, failure, generation, None)?
    }

    /// Run the next chunk of prefill job `job`; `Done` promotes the job
    /// to a live decode-ready request.
    pub fn prefill_chunk(&self, job: u64) -> Result<ChunkOutcome> {
        self.prefill_chunk_deadline(job, None)
    }

    /// [`EngineHandle::prefill_chunk`] under the round watchdog: a
    /// chunk call exceeding `deadline` returns a typed stalled
    /// [`EngineFailed`] instead of blocking the scheduler forever.
    pub fn prefill_chunk_deadline(
        &self,
        job: u64,
        deadline: Option<std::time::Duration>,
    ) -> Result<ChunkOutcome> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::PrefillChunk { job, reply });
        self.roundtrip(rx, sent, failure, generation, deadline)?
    }

    /// Drop a partially-prefilled job, freeing its staged KV.
    pub fn prefill_cancel(&self, job: u64) {
        let _ = self.link().0.send(EngineJob::PrefillCancel { job });
    }

    pub fn decode_step(&self, id: u64) -> Result<u32> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::DecodeStep { id, reply });
        self.roundtrip(rx, sent, failure, generation, None)?
    }

    /// One batched token round over `ids` — a single engine round-trip
    /// producing every active request's next token (DESIGN.md §9). The
    /// outer `Result` is engine liveness (typed [`EngineFailed`] on a
    /// dead engine); per-request failures are in
    /// [`DecodeBatchReport::tokens`].
    pub fn decode_batch(&self, ids: Vec<u64>) -> Result<DecodeBatchReport> {
        self.decode_batch_deadline(ids, None)
    }

    /// [`EngineHandle::decode_batch`] under the round watchdog: a round
    /// exceeding `deadline` returns a typed stalled [`EngineFailed`]
    /// instead of blocking the scheduler forever.
    pub fn decode_batch_deadline(
        &self,
        ids: Vec<u64>,
        deadline: Option<std::time::Duration>,
    ) -> Result<DecodeBatchReport> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::DecodeBatch { ids, reply });
        self.roundtrip(rx, sent, failure, generation, deadline)
    }

    /// Largest admissible prompt length (the biggest prefill bucket).
    pub fn max_prompt_len(&self) -> Result<usize> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::MaxPromptLen { reply });
        self.roundtrip(rx, sent, failure, generation, None)
    }

    /// Pool geometry for worst-case page admission (immutable after
    /// load; fetch once).
    pub fn pool_profile(&self) -> Result<PoolProfile> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::PoolProfile { reply });
        self.roundtrip(rx, sent, failure, generation, None)
    }

    /// Assert the engine-side KV pool has drained back to fully-free
    /// (tests). FIFO-ordered behind every `Release` already sent on
    /// this handle; errors carry the leak description (or engine death).
    pub fn pool_drained(&self) -> Result<()> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::PoolDrained { reply });
        self.roundtrip(rx, sent, failure, generation, None)?
            .map_err(|leak| anyhow::anyhow!("kv pool not drained: {leak}"))
    }

    pub fn release(&self, id: u64) {
        let _ = self.link().0.send(EngineJob::Release { id });
    }

    /// Enable/disable the cross-request prefix cache (DESIGN.md §13).
    /// Reconfiguring clears the index; `capacity_pages` defaults to
    /// half the pool.
    pub fn set_prefix_cache(&self, enabled: bool, capacity_pages: Option<usize>) -> Result<()> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::SetPrefixCache { enabled, capacity_pages, reply });
        self.roundtrip(rx, sent, failure, generation, None)
    }

    /// Drop every cached prefix (pinned entries free on last unpin).
    pub fn prefix_clear(&self) -> Result<()> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::PrefixClear { reply });
        self.roundtrip(rx, sent, failure, generation, None)
    }

    /// Prefix-cache counter snapshot.
    pub fn prefix_stats(&self) -> Result<PrefixStats> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::PrefixStats { reply });
        self.roundtrip(rx, sent, failure, generation, None)
    }

    /// Preempt request `id` (DESIGN.md §15): the engine frees every
    /// page it holds, handing back the ring snapshots the caller must
    /// keep for the resume catch-up (or dispose via
    /// [`EngineHandle::free_snaps`]).
    pub fn preempt(&self, id: u64) -> Result<PreemptInfo> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::Preempt { id, reply });
        self.roundtrip(rx, sent, failure, generation, None)?
    }

    /// Teacher-forced resume catch-up (DESIGN.md §15): replay the
    /// already-streamed tokens through the decode path, verify rebuilt
    /// rings against `verify`, and free the snapshot blocks.
    pub fn catch_up(&self, id: u64, force: Vec<u32>, verify: Vec<Option<RingSnap>>) -> Result<()> {
        let (tx, failure, generation) = self.link();
        let (reply, rx) = std::sync::mpsc::channel();
        let sent = tx.send(EngineJob::CatchUp { id, force, verify, reply });
        self.roundtrip(rx, sent, failure, generation, None)?
    }

    /// Return un-resumed ring snapshots to the pool (fire-and-forget,
    /// like [`EngineHandle::release`]).
    pub fn free_snaps(&self, snaps: Vec<Option<RingSnap>>) {
        let _ = self.link().0.send(EngineJob::FreeSnaps { snaps });
    }

    pub fn shutdown(&self) {
        let _ = self.link().0.send(EngineJob::Shutdown);
    }
}

/// Run one job against the engine; `false` means Shutdown. Every reply
/// send ignores a hung-up receiver (a timed-out watchdog caller).
fn run_engine_job(engine: &mut Engine, job: EngineJob) -> bool {
    match job {
        EngineJob::Prefill { tokens, policy, router, reply } => {
            let _ = reply.send(engine.prefill(&tokens, &policy, &router));
        }
        EngineJob::PrefillOpen { tokens, policy, router, chunk_tokens, reply } => {
            let _ = reply.send(engine.prefill_open(&tokens, &policy, &router, chunk_tokens));
        }
        EngineJob::PrefillChunk { job, reply } => {
            let _ = reply.send(engine.prefill_chunk(job));
        }
        EngineJob::PrefillCancel { job } => {
            engine.prefill_cancel(job);
        }
        EngineJob::DecodeStep { id, reply } => {
            let _ = reply.send(engine.decode_step(id));
        }
        EngineJob::DecodeBatch { ids, reply } => {
            let _ = reply.send(engine.decode_batch_report(&ids));
        }
        EngineJob::MaxPromptLen { reply } => {
            let max = engine.cfg().prefill_buckets.last().copied().unwrap_or(usize::MAX);
            let _ = reply.send(max);
        }
        EngineJob::PoolProfile { reply } => {
            let _ = reply.send(engine.pool_profile());
        }
        EngineJob::Release { id } => {
            engine.release(id);
        }
        EngineJob::PoolDrained { reply } => {
            let retained = engine.prefix_retained_pages();
            let _ = reply.send(engine.pool().drained_with_retained(retained));
        }
        EngineJob::SetPrefixCache { enabled, capacity_pages, reply } => {
            engine.set_prefix_cache(enabled, capacity_pages);
            let _ = reply.send(());
        }
        EngineJob::PrefixClear { reply } => {
            engine.prefix_clear();
            let _ = reply.send(());
        }
        EngineJob::PrefixStats { reply } => {
            let _ = reply.send(engine.prefix_stats());
        }
        EngineJob::Preempt { id, reply } => {
            let _ = reply.send(engine.preempt(id));
        }
        EngineJob::CatchUp { id, force, verify, reply } => {
            let _ = reply.send(engine.catch_up(id, &force, &verify));
        }
        EngineJob::FreeSnaps { snaps } => {
            engine.free_snaps(&snaps);
        }
        EngineJob::Shutdown => return false,
    }
    true
}

/// Best-effort panic payload → message (panics carry `&str` or `String`
/// in practice).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "engine thread panicked (non-string payload)".into()
    }
}
