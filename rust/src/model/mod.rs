//! Model weights + the layer-by-layer execution engine primitives.
//!
//! Weights are loaded once from the artifact export and kept as host
//! tensors in the argument order of the prefill/decode executables —
//! every [`crate::runtime::Backend::run`] call just borrows them, so
//! there is no per-call conversion on the hot path (the PJRT backend
//! does its own literal conversion at the device boundary).

use anyhow::Result;

use crate::config::MetaConfig;
use crate::runtime::{HostTensor, WeightStore};

/// Per-layer backbone weights, in the argument order of the
/// prefill/decode executables.
pub struct LayerWeights {
    pub norm1: HostTensor,
    pub wq: HostTensor,
    pub wk: HostTensor,
    pub wv: HostTensor,
    pub wo: HostTensor,
    pub norm2: HostTensor,
    pub w_ff1: HostTensor,
    pub w_ff2: HostTensor,
}

/// All backbone weights.
pub struct ModelWeights {
    pub layers: Vec<LayerWeights>,
    pub norm_f: HostTensor,
    pub lm_head: HostTensor,
    /// host-side embedding table (V, d) — lookup happens in rust
    pub embed: HostTensor,
    pub cfg: MetaConfig,
}

impl ModelWeights {
    pub fn load(cfg: &MetaConfig, ws: &WeightStore) -> Result<Self> {
        let mut layers = Vec::with_capacity(cfg.model.n_layers);
        for i in 0..cfg.model.n_layers {
            layers.push(LayerWeights {
                norm1: ws.layer_slice("layers.norm1", i)?,
                wq: ws.layer_slice("layers.wq", i)?,
                wk: ws.layer_slice("layers.wk", i)?,
                wv: ws.layer_slice("layers.wv", i)?,
                wo: ws.layer_slice("layers.wo", i)?,
                norm2: ws.layer_slice("layers.norm2", i)?,
                w_ff1: ws.layer_slice("layers.w_ff1", i)?,
                w_ff2: ws.layer_slice("layers.w_ff2", i)?,
            });
        }
        Ok(Self {
            layers,
            norm_f: ws.get("norm_f")?.clone(),
            lm_head: ws.get("lm_head")?.clone(),
            embed: ws.get("embed")?.clone(),
            cfg: cfg.clone(),
        })
    }

    /// Embedding lookup: tokens -> `(S_bucket, d)` hidden states, padded
    /// with zeros past `tokens.len()`.
    pub fn embed_tokens(&self, tokens: &[u32], bucket: usize) -> HostTensor {
        let d = self.cfg.model.d_model;
        let v = self.cfg.model.vocab_size;
        let mut out = vec![0.0f32; bucket * d];
        for (t, &id) in tokens.iter().enumerate().take(bucket) {
            let id = (id as usize).min(v - 1);
            out[t * d..(t + 1) * d].copy_from_slice(&self.embed.data[id * d..(id + 1) * d]);
        }
        HostTensor::new(vec![bucket, d], out)
    }

    /// Embedding of a single token -> `(d,)`.
    pub fn embed_one(&self, token: u32) -> HostTensor {
        let d = self.cfg.model.d_model;
        let id = (token as usize).min(self.cfg.model.vocab_size - 1);
        HostTensor::new(vec![d], self.embed.data[id * d..(id + 1) * d].to_vec())
    }
}

/// Greedy argmax over vocabulary logits.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
