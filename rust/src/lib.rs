//! FluxAttention: a context-aware, layer-level hybrid-attention serving
//! engine — reproduction of *Flux Attention: Context-Aware Hybrid Attention
//! for Efficient LLMs Inference* (Qiu et al., 2026).
//!
//! Architecture (see DESIGN.md at the repository root):
//! * **L3 (this crate)** — the serving coordinator: event-driven request
//!   sessions (streaming tokens, cancellation, deadlines — DESIGN.md §8),
//!   continuous batcher, prefill/decode scheduler, KV-cache manager with
//!   full and sparse (sink+local) layouts, the Layer Router integration,
//!   baselines, a GPU decode-latency simulator, metrics, the multiplexed
//!   NDJSON wire protocol and the eval harness. Python never runs on the
//!   request path.
//! * **Execution backends ([`runtime::Backend`])** — the engine calls
//!   named executables through a pluggable backend seam. The default is
//!   the hermetic pure-Rust [`runtime::RefBackend`] (reference CPU
//!   kernels + [`runtime::synthetic`] artifacts — `cargo test` exercises
//!   the full serving path with zero native dependencies). The `pjrt`
//!   cargo feature adds [`runtime::pjrt`], which loads AOT HLO-text
//!   artifacts via the PJRT C API.
//! * **L2/L1 (python/, build-time)** — the JAX model and Pallas kernels,
//!   AOT-lowered to HLO-text artifacts for the PJRT backend; the
//!   reference backend mirrors their math in Rust.

// Index-based loops are the house style in the numeric kernels (shapes
// and strides stay visible); executable signatures mirror the AOT
// argument lists.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::derivable_impls)]

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod gpu_sim;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod router;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use config::MetaConfig;
pub use coordinator::{
    CancelToken, Coordinator, Request, RequestError, Response, SessionEvent, SessionHandle,
};
pub use engine::{Engine, EngineHandle};
pub use router::{AttnMode, DecodeMode, Policy};
pub use runtime::{Backend, HostTensor, RefBackend};
pub use server::{ClientStream, StreamClient};
