//! FluxAttention: a context-aware, layer-level hybrid-attention serving
//! engine — reproduction of *Flux Attention: Context-Aware Hybrid Attention
//! for Efficient LLMs Inference* (Qiu et al., 2026).
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — the serving coordinator: request router,
//!   continuous batcher, prefill/decode scheduler, KV-cache manager with
//!   full and sparse (sink+local) layouts, the Layer Router integration,
//!   baselines, a GPU decode-latency simulator, metrics and the eval
//!   harness. Python never runs on the request path.
//! * **L2/L1 (python/, build-time)** — the JAX model and Pallas kernels,
//!   AOT-lowered to HLO-text artifacts loaded here via the PJRT C API.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod gpu_sim;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod router;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use config::MetaConfig;
pub use engine::{Engine, EngineHandle};
pub use router::{AttnMode, DecodeMode, Policy};
