//! Experiment drivers: one function per paper table/figure.
//! Each prints the paper-style table and writes JSON to `results/`.

use anyhow::Result;

use crate::baselines;
use crate::engine::{ChunkOutcome, Engine, PrefillReport};
use crate::gpu_sim::{decode_speedup, GpuSimConfig, SimPolicy};
use crate::jobj;
use crate::router::{AttnMode, DecodeMode, Policy};
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{generate, Task, LONGBENCH_TASKS};

use super::{format_table, run_task, TaskResult};

fn save_json(name: &str, value: &Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{name}.json"), value.to_string())?;
    Ok(())
}

/// Calibrate entropy scores on a small mixed prompt set.
pub fn entropy_scores(engine: &mut Engine, seq_len: usize) -> Result<Vec<f64>> {
    let mut rng = Rng::seed_from_u64(1234);
    let n_layers = engine.cfg().model.n_layers;
    let mut acc = vec![0.0; n_layers];
    let tasks = [Task::PRe, Task::Gov, Task::HotQA, Task::Trec];
    for task in tasks {
        let s = generate(task, &mut rng, seq_len);
        let top_k = engine.cfg().model.d_model;
        let scores = engine.profile_entropy(&s.prompt, top_k)?;
        for (a, s) in acc.iter_mut().zip(scores) {
            *a += s;
        }
    }
    for a in acc.iter_mut() {
        *a /= tasks.len() as f64;
    }
    Ok(acc)
}

/// The paper's baseline + FluxAttn method set for Tables 1-2.
pub fn method_set(engine: &mut Engine, seq_len: usize) -> Result<Vec<(String, Policy)>> {
    let scores = entropy_scores(engine, seq_len)?;
    let n_layers = engine.cfg().model.n_layers;
    Ok(vec![
        ("backbone".into(), Policy::Backbone),
        (
            "+DuoAttention".into(),
            Policy::Static {
                modes: baselines::duo_attention_modes(&scores),
                decode: DecodeMode::Dense,
            },
        ),
        (
            "+PruLong".into(),
            Policy::Static {
                modes: baselines::prulong_modes(&scores),
                decode: DecodeMode::Dense,
            },
        ),
        (
            "+TriangleMix".into(),
            Policy::Static {
                modes: baselines::trianglemix_modes(n_layers),
                decode: DecodeMode::Dense,
            },
        ),
        (
            "+FluxAttn(FA-SSA)".into(),
            Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense },
        ),
        (
            "+FluxAttn(FA-XA)".into(),
            Policy::Flux { sa_mode: AttnMode::Xa, decode: DecodeMode::Dense },
        ),
        (
            "+FluxAttn(FA-TA)".into(),
            Policy::Flux { sa_mode: AttnMode::Ta, decode: DecodeMode::Dense },
        ),
        (
            "+FluxAttn(FA-SSA)sd".into(),
            Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse },
        ),
    ])
}

/// Fig 1(a): accuracy vs progressive entropy-ranked sparsity.
pub fn fig1a(engine: &mut Engine, n: usize, seq_len: usize) -> Result<()> {
    let scores = entropy_scores(engine, seq_len)?;
    let omegas = [0.0, 0.25, 0.5, 0.75, 1.0];
    let tasks = [Task::PRe, Task::HotQA, Task::Gov, Task::Trec];
    let mut out = Json::obj();
    println!("== Fig 1(a): accuracy vs Omega_MSR (entropy-ranked static) ==");
    println!("{:<10}{:>8}{:>8}{:>8}{:>8}", "omega", "pre", "hotqa", "gov", "trec");
    for &omega in &omegas {
        let modes = baselines::entropy_ranked_modes(&scores, omega, AttnMode::Ssa);
        let policy = Policy::Static { modes, decode: DecodeMode::Dense };
        let mut row = Json::obj();
        let mut accs = vec![];
        for task in tasks {
            let r = run_task(engine, task, &policy, "balanced", n, seq_len, 11)?;
            row.set(task.name(), Json::from(r.acc));
            accs.push(r.acc);
        }
        println!(
            "{:<10.2}{:>8.1}{:>8.1}{:>8.1}{:>8.1}",
            omega, accs[0], accs[1], accs[2], accs[3]
        );
        out.set(&format!("{omega}"), row);
    }
    save_json("fig1a", &out)
}

/// Fig 1(b): decode speedup — head-level vs layer-level (GPU simulator,
/// paper scale) + measured CPU ratio at repo scale.
pub fn fig1b(engine: &mut Engine) -> Result<()> {
    let cfg = GpuSimConfig::default();
    println!("== Fig 1(b): decode speedup at Omega=0.5 (A800 simulator) ==");
    println!("{:<12}{:>12}{:>12}", "context", "head-level", "layer-level");
    let mut sim = Json::Arr(vec![]);
    for ctx in [8_192usize, 16_384, 32_768, 65_536, 131_072, 262_144] {
        let hl =
            decode_speedup(&cfg, &SimPolicy::HeadLevel { sparse_frac: 0.5, window: 2048 }, ctx);
        let ll =
            decode_speedup(&cfg, &SimPolicy::LayerLevel { sparse_frac: 0.5, window: 2048 }, ctx);
        println!("{:<12}{:>12.2}{:>12.2}", ctx, hl, ll);
        sim.push(jobj! {"context" => ctx, "head_level" => hl, "layer_level" => ll});
    }

    println!("-- measured (CPU PJRT, layer-level sparse decode vs dense) --");
    let mut measured = Json::Arr(vec![]);
    let n_layers = engine.cfg().model.n_layers;
    for seq in [256usize, 512, 1024, 2040] {
        let dense = run_task(engine, Task::PRe, &Policy::Backbone, "balanced", 2, seq, 21)?;
        let sparse = run_task(
            engine,
            Task::PRe,
            &Policy::Static {
                modes: vec![AttnMode::Ssa; n_layers],
                decode: DecodeMode::Sparse,
            },
            "balanced",
            2,
            seq,
            21,
        )?;
        let speedup = dense.decode_ms_per_tok / sparse.decode_ms_per_tok.max(1e-9);
        println!(
            "ctx {seq:>5}: dense {:.2} ms/tok, sparse {:.2} ms/tok, speedup {speedup:.2}x",
            dense.decode_ms_per_tok, sparse.decode_ms_per_tok,
        );
        measured.push(jobj! {
            "context" => seq, "dense_ms" => dense.decode_ms_per_tok,
            "sparse_ms" => sparse.decode_ms_per_tok, "speedup" => speedup
        });
    }
    let mut out = Json::obj();
    out.set("simulated", sim);
    out.set("measured", measured);
    save_json("fig1b", &out)
}

/// Table 1: LongBench-E proxy, all methods.
pub fn table1(engine: &mut Engine, n: usize, seq_len: usize) -> Result<()> {
    let methods = method_set(engine, seq_len)?;
    let mut rows: Vec<(String, Vec<TaskResult>)> = vec![];
    for (label, policy) in &methods {
        let mut results = vec![];
        for task in LONGBENCH_TASKS {
            results.push(run_task(engine, task, policy, "balanced", n, seq_len, 42)?);
        }
        eprintln!("  [table1] {label} done");
        rows.push((label.clone(), results));
    }
    println!("{}", format_table("Table 1: LongBench-E proxy", &rows));
    let mut j = Json::Arr(vec![]);
    for (l, rs) in &rows {
        let mut tasks = Json::Arr(vec![]);
        for r in rs {
            tasks.push(jobj! {"task" => r.task.name(), "acc" => r.acc, "omsr" => r.omsr});
        }
        let mut o = Json::obj();
        o.set("method", Json::from(l.as_str()));
        o.set("tasks", tasks);
        j.push(o);
    }
    save_json("table1", &j)
}

/// Table 2: RULER ladder + LongBench-v2 + math proxies.
pub fn table2(engine: &mut Engine, n: usize) -> Result<()> {
    let lengths = [64usize, 96, 128, 192, 256, 512];
    let methods = method_set(engine, 512)?;
    println!("== Table 2: RULER ladder / LongBench-v2 / Math ==");
    print!("{:<22}", "method");
    for l in lengths {
        print!("{l:>7}");
    }
    println!("{:>8}{:>8}{:>8}{:>8}", "lbv2-e", "lbv2-h", "gsm8k", "aime24");
    let mut j = Json::Arr(vec![]);
    for (label, policy) in &methods {
        print!("{label:<22}");
        let mut ruler = Json::Arr(vec![]);
        for &len in &lengths {
            let r = run_task(engine, Task::Ruler, policy, "balanced", n, len, 77)?;
            print!("{:>7.1}", r.acc);
            ruler.push(jobj! {"len" => len, "acc" => r.acc});
        }
        let e = run_task(engine, Task::Lbv2Easy, policy, "balanced", n, 256, 78)?;
        let h = run_task(engine, Task::Lbv2Hard, policy, "balanced", n, 256, 79)?;
        let g = run_task(engine, Task::Gsm, policy, "balanced", n, 128, 80)?;
        let a = run_task(engine, Task::Aime, policy, "balanced", n, 128, 81)?;
        println!("{:>8.1}{:>8.1}{:>8.1}{:>8.1}", e.acc, h.acc, g.acc, a.acc);
        let mut o = jobj! {
            "method" => label.as_str(), "lbv2_easy" => e.acc, "lbv2_hard" => h.acc,
            "gsm" => g.acc, "aime" => a.acc
        };
        o.set("ruler", ruler);
        j.push(o);
    }
    save_json("table2", &j)
}

/// Fig 3: prefill end-to-end + decode latency vs context length.
pub fn fig3(engine: &mut Engine) -> Result<()> {
    println!("== Fig 3(a): prefill latency vs context (end-to-end) ==");
    let n_layers = engine.cfg().model.n_layers;
    let policies: Vec<(String, Policy)> = vec![
        ("dense".into(), Policy::Backbone),
        ("flux-ta".into(), Policy::Flux { sa_mode: AttnMode::Ta, decode: DecodeMode::Dense }),
        (
            "all-ssa".into(),
            Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Dense },
        ),
        (
            "all-ta".into(),
            Policy::Static { modes: vec![AttnMode::Ta; n_layers], decode: DecodeMode::Dense },
        ),
    ];
    let mut j = Json::Arr(vec![]);
    for seq in [128usize, 256, 512, 1024, 2040] {
        let mut row = jobj! {"context" => seq};
        let mut dense_ms = 0.0;
        for (label, policy) in &policies {
            let r = run_task(engine, Task::PRe, policy, "balanced", 2, seq, 33)?;
            if label == "dense" {
                dense_ms = r.prefill_ms;
            }
            let speedup = dense_ms / r.prefill_ms.max(1e-9);
            println!(
                "ctx {seq:>5} {label:<10} prefill {:>9.1} ms  speedup {speedup:.2}x",
                r.prefill_ms
            );
            row.set(label, jobj! {"ms" => r.prefill_ms, "speedup" => speedup});
        }
        j.push(row);
    }
    save_json("fig3a", &j)?;

    println!("== Fig 3(b): decode kernel latency vs KV length ==");
    let mut j = Json::Arr(vec![]);
    for seq in [256usize, 512, 1024, 2040] {
        let dense = run_task(engine, Task::PRe, &Policy::Backbone, "balanced", 1, seq, 61)?;
        let sp = run_task(
            engine,
            Task::PRe,
            &Policy::Static {
                modes: vec![AttnMode::Ssa; n_layers],
                decode: DecodeMode::Sparse,
            },
            "balanced",
            1,
            seq,
            61,
        )?;
        let ratio = dense.decode_ms_per_tok / sp.decode_ms_per_tok.max(1e-9);
        println!(
            "kv {seq:>5}: dense {:.2} ms, sparse {:.2} ms, {ratio:.2}x",
            dense.decode_ms_per_tok, sp.decode_ms_per_tok
        );
        j.push(jobj! {"kv" => seq, "dense_ms" => dense.decode_ms_per_tok,
                      "sparse_ms" => sp.decode_ms_per_tok, "speedup" => ratio});
    }
    save_json("fig3b", &j)
}

/// Fig 4: layer x task routing activation heat map.
pub fn fig4(engine: &mut Engine, n: usize, seq_len: usize) -> Result<()> {
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let n_layers = engine.cfg().model.n_layers;
    let tasks = [Task::Qasper, Task::HotQA, Task::Gov, Task::Trec, Task::PRe, Task::Lcc];
    println!("== Fig 4: FA activation frequency per (task, layer) ==");
    print!("{:<10}", "task");
    for l in 0..n_layers {
        print!("  L{l}");
    }
    println!();
    let mut j = Json::obj();
    for task in tasks {
        let mut counts = vec![0usize; n_layers];
        let mut rng = Rng::seed_from_u64(91 ^ task as u64);
        for _ in 0..n {
            let s = generate(task, &mut rng, seq_len);
            let (id, report) = engine.prefill(&s.prompt, &policy, "balanced")?;
            engine.release(id);
            for (c, m) in counts.iter_mut().zip(&report.modes) {
                *c += (*m == AttnMode::Fa) as usize;
            }
        }
        print!("{:<10}", task.name());
        let freqs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        for f in &freqs {
            print!("{f:>4.1}");
        }
        println!();
        j.set(task.name(), Json::from(freqs));
    }
    save_json("fig4", &j)
}

/// Fig 5 / Fig 8: evaluate router sweep variants (t-targets / pooling).
pub fn sweep(
    engine: &mut Engine,
    variants: &[String],
    n: usize,
    seq_len: usize,
    name: &str,
) -> Result<()> {
    let tasks = [Task::PRe, Task::HotQA, Task::Gov, Task::Trec];
    println!("== {name}: performance + Omega_MSR per router variant ==");
    let mut j = Json::Arr(vec![]);
    for v in variants {
        if engine.router(v).is_err() {
            eprintln!("  (skipping missing router variant {v})");
            continue;
        }
        let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
        let mut accs = Json::obj();
        let mut mean = 0.0;
        let mut omsr = 0.0;
        for task in tasks {
            let r = run_task(engine, task, &policy, v, n, seq_len, 55)?;
            accs.set(task.name(), Json::from(r.acc));
            mean += r.acc / tasks.len() as f64;
            omsr += r.omsr / tasks.len() as f64;
        }
        println!("variant {v:<12} mean_acc {mean:>6.1}  omsr {omsr:.2}");
        let mut o = jobj! {"variant" => v.as_str(), "mean" => mean, "omsr" => omsr};
        o.set("accs", accs);
        j.push(o);
    }
    save_json(name, &j)
}

/// Fig 9: router overhead vs sequence length (length invariance).
pub fn fig9(engine: &mut Engine) -> Result<()> {
    println!("== Fig 9: router overhead per layer vs context length ==");
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let n_layers = engine.cfg().model.n_layers as f64;
    let mut j = Json::Arr(vec![]);
    for seq in [128usize, 256, 512, 1024, 2040] {
        let mut rng = Rng::seed_from_u64(seq as u64);
        let s = generate(Task::PRe, &mut rng, seq);
        let mut total = 0u64;
        let reps = 3;
        for _ in 0..reps {
            let (id, report) = engine.prefill(&s.prompt, &policy, "balanced")?;
            engine.release(id);
            total += report.router_us;
        }
        let per_layer_ms = total as f64 / reps as f64 / n_layers / 1e3;
        println!("ctx {seq:>5}: {per_layer_ms:.4} ms/layer");
        j.push(jobj! {"context" => seq, "ms_per_layer" => per_layer_ms});
    }
    save_json("fig9", &j)
}

/// Error-analysis transcripts (paper Figs 11-13 substitute).
pub fn cases(engine: &mut Engine) -> Result<()> {
    let tok = Tokenizer::new();
    let mut rng = Rng::seed_from_u64(7);
    println!("== Qualitative cases (paper Figs 11-13) ==");
    let n_layers = engine.cfg().model.n_layers;
    let mut j = Json::Arr(vec![]);
    for task in [Task::Qasper, Task::HotQA, Task::PRe] {
        let s = generate(task, &mut rng, 512);
        let methods: Vec<(String, Policy)> = vec![
            ("backbone".into(), Policy::Backbone),
            (
                "all-ssa(static)".into(),
                Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Dense },
            ),
            ("flux-ssa".into(), Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense }),
        ];
        println!(
            "--- task {} | query ...{} | gold {}",
            task.name(),
            tok.decode(&s.prompt[s.prompt.len().saturating_sub(4)..]),
            tok.decode(&s.answer)
        );
        let mut case = jobj! {
            "task" => task.name(),
            "query_tail" => tok.decode(&s.prompt[s.prompt.len().saturating_sub(8)..]),
            "gold" => tok.decode(&s.answer)
        };
        for (label, policy) in methods {
            let (gen, report) =
                engine.generate(&s.prompt, &policy, "balanced", s.answer.len() + 1)?;
            let correct = super::exact_match(&gen, &s.answer);
            println!(
                "  {label:<16} -> {:<18} {} (omsr {:.2})",
                tok.decode(&gen),
                if correct { "CORRECT" } else { "WRONG" },
                report.omsr
            );
            case.set(&label, jobj! {"pred" => tok.decode(&gen), "correct" => correct});
        }
        j.push(case);
    }
    save_json("cases", &j)
}

/// Memory accounting table: KV bytes per policy (supports the paper's
/// "KV cache reduction" claim in section 3.3).
pub fn kv_memory(engine: &mut Engine, seq_len: usize) -> Result<()> {
    println!("== KV memory per request at ctx {seq_len} ==");
    let n_layers = engine.cfg().model.n_layers;
    let mut j = Json::Arr(vec![]);
    for (label, policy) in [
        ("dense".to_string(), Policy::Backbone),
        (
            "flux-ssa-sd".to_string(),
            Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse },
        ),
        (
            "all-ssa-sd".to_string(),
            Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Sparse },
        ),
    ] {
        let r = run_task(engine, Task::PRe, &policy, "balanced", 2, seq_len, 17)?;
        println!("{label:<14} {:>12.0} bytes", r.kv_bytes);
        j.push(jobj! {"policy" => label, "kv_bytes" => r.kv_bytes});
    }
    save_json("kv_memory", &j)
}

/// Drive a chunked prefill job to completion (the cross-request prefix
/// cache only engages on the chunked path, DESIGN.md §13).
fn chunked_prefill(
    engine: &mut Engine,
    tokens: &[u32],
    policy: &Policy,
    chunk: usize,
) -> Result<(u64, PrefillReport)> {
    let job = engine.prefill_open(tokens, policy, "balanced", chunk)?;
    loop {
        if let ChunkOutcome::Done { id, report } = engine.prefill_chunk(job)? {
            return Ok((id, report));
        }
    }
}

fn route_str(modes: &[AttnMode]) -> String {
    modes.iter().map(|m| m.name()).collect::<Vec<_>>().join("-")
}

/// Route-disagreement ledger (DESIGN.md §13): a prefix-cache hit pins
/// the route the cached KV was computed under instead of re-running the
/// Layer Router on the new (longer) prompt — trading possible
/// context-sensitivity drift for the skipped prefill. This harness
/// measures that trade: each warm session's pinned route is compared
/// against a fresh router run on the SAME full prompt (a monolithic
/// prefill never consults the cache), and per-layer disagreement is
/// tabulated across tasks.
pub fn route_ledger(engine: &mut Engine, n: usize, seq_len: usize) -> Result<()> {
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let n_layers = engine.cfg().model.n_layers;
    let vocab = engine.cfg().model.vocab_size as u32;
    let page = Engine::DEFAULT_PAGE_TOKENS;
    engine.set_prefix_cache(true, None);
    println!("== Route ledger: pinned cached route vs fresh full-prompt route ==");
    let tasks = [Task::PRe, Task::HotQA, Task::Gov, Task::Trec];
    let mut per_layer = vec![0u64; n_layers];
    let mut warm_total = 0u64;
    let mut warm_hits = 0u64;
    let mut j = Json::Arr(vec![]);
    for task in tasks {
        let mut rng = Rng::seed_from_u64(131 ^ task as u64);
        let mut shared = generate(task, &mut rng, seq_len).prompt;
        // page-aligned shared run + distinct short suffixes per session
        shared.truncate(shared.len() / page * page);
        let mut cold = shared.clone();
        cold.extend((0..8).map(|_| rng.range_u32(0, vocab)));
        // the cold session seeds the cache with the shared run
        let (id, _) = chunked_prefill(engine, &cold, &policy, 64)?;
        engine.release(id);
        let mut sessions = Json::Arr(vec![]);
        for s in 0..n {
            let mut prompt = shared.clone();
            prompt.extend((0..8).map(|_| rng.range_u32(0, vocab)));
            let (wid, warm) = chunked_prefill(engine, &prompt, &policy, 64)?;
            engine.release(wid);
            let (fid, fresh) = engine.prefill(&prompt, &policy, "balanced")?;
            engine.release(fid);
            let mut disagree = 0usize;
            for (l, (a, b)) in warm.modes.iter().zip(&fresh.modes).enumerate() {
                if a != b {
                    disagree += 1;
                    per_layer[l] += 1;
                }
            }
            warm_total += 1;
            warm_hits += (warm.cached_prefix_tokens > 0) as u64;
            println!(
                "  {:<8} s{s}: cached {:>4} tok  disagree {disagree}/{n_layers} layers",
                task.name(),
                warm.cached_prefix_tokens
            );
            sessions.push(jobj! {
                "cached_prefix_tokens" => warm.cached_prefix_tokens,
                "disagree_layers" => disagree,
                "pinned" => route_str(&warm.modes),
                "fresh" => route_str(&fresh.modes)
            });
        }
        let mut o = jobj! {"task" => task.name(), "shared_tokens" => shared.len()};
        o.set("sessions", sessions);
        j.push(o);
    }
    let frac: Vec<f64> =
        per_layer.iter().map(|&c| c as f64 / warm_total.max(1) as f64).collect();
    println!("  warm sessions {warm_total}, prefix hits {warm_hits}");
    print!("  per-layer disagreement freq:");
    for f in &frac {
        print!(" {f:.2}");
    }
    println!();
    let mut out = Json::obj();
    out.set("tasks", j);
    out.set("warm_sessions", Json::from(warm_total as usize));
    out.set("warm_hits", Json::from(warm_hits as usize));
    out.set("per_layer_disagreement", Json::from(frac));
    // leave the engine as found — ledger runs are standalone
    engine.prefix_clear();
    engine.set_prefix_cache(false, None);
    save_json("route_ledger", &out)
}

/// Figs 6/7/10: summarize the python-side training trajectories
/// (artifacts/curves/*.json) — LM loss, per-category sparsity (Omega)
/// convergence, lambda dynamics, balanced-vs-unbalanced divergence, and
/// the continued-training accuracy curve.
pub fn curves(artifacts: &std::path::Path) -> Result<()> {
    let dir = artifacts.join("curves");
    let read = |name: &str| -> Option<Json> {
        std::fs::read_to_string(dir.join(name))
            .ok()
            .and_then(|t| Json::parse(&t).ok())
    };

    println!("== Fig 10: router training dynamics (balanced mix) ==");
    if let Some(j) = read("router_balanced.json") {
        if let Some(traj) = j.get("trajectory").and_then(Json::as_arr) {
            let tail = |cat: &str, key: &str| -> Vec<f64> {
                traj.iter()
                    .filter(|e| e.get("cat").and_then(Json::as_str) == Some(cat))
                    .filter_map(|e| e.get(key).and_then(Json::as_f64))
                    .collect()
            };
            for cat in ["retr", "hol"] {
                let sa = tail(cat, "sa_frac");
                let lm = tail(cat, "lm_loss");
                if sa.is_empty() {
                    continue;
                }
                let last = &sa[sa.len().saturating_sub(8)..];
                let sa_end = last.iter().sum::<f64>() / last.len() as f64;
                println!(
                    "  {cat:<5} batches={:<4} lm_loss {:.3} -> {:.3}   sa_frac -> {sa_end:.3}",
                    sa.len(),
                    lm.first().unwrap_or(&0.0),
                    lm.last().unwrap_or(&0.0),
                );
            }
            if let Some(last) = traj.last() {
                println!(
                    "  lambda1 retr {:.2} hol {:.2} | lambda2 retr {:.2} hol {:.2}",
                    last.get("lam1_retr").and_then(Json::as_f64).unwrap_or(0.0),
                    last.get("lam1_hol").and_then(Json::as_f64).unwrap_or(0.0),
                    last.get("lam2_retr").and_then(Json::as_f64).unwrap_or(0.0),
                    last.get("lam2_hol").and_then(Json::as_f64).unwrap_or(0.0),
                );
            }
        }
    } else {
        println!("  (artifacts/curves/router_balanced.json missing)");
    }

    println!("== Fig 7: balanced vs unbalanced data mix ==");
    for name in ["router_balanced.json", "router_unbalanced.json"] {
        if let Some(j) = read(name) {
            if let Some(traj) = j.get("trajectory").and_then(Json::as_arr) {
                let sa = |cat: &str| -> f64 {
                    let v: Vec<f64> = traj
                        .iter()
                        .rev()
                        .filter(|e| e.get("cat").and_then(Json::as_str) == Some(cat))
                        .take(8)
                        .filter_map(|e| e.get("sa_frac").and_then(Json::as_f64))
                        .collect();
                    if v.is_empty() { f64::NAN } else { v.iter().sum::<f64>() / v.len() as f64 }
                };
                println!(
                    "  {name:<26} final sa_frac: retr {:.3}  hol {:.3}  (divergence {:.3})",
                    sa("retr"),
                    sa("hol"),
                    (sa("hol") - sa("retr")).abs()
                );
            }
        } else {
            println!("  ({name} missing)");
        }
    }

    println!("== Fig 6: continued training with frozen router ==");
    if let Some(j) = read("continued.json") {
        if let Some(arr) = j.as_arr() {
            for e in arr {
                println!(
                    "  step {:>4}  loss {:.3}  acc {:.3}",
                    e.get("step").and_then(Json::as_usize).unwrap_or(0),
                    e.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
                    e.get("acc").and_then(Json::as_f64).unwrap_or(0.0)
                );
            }
        }
    } else {
        println!("  (artifacts/curves/continued.json missing — run `python -m compile.train --stage continued`)");
    }
    Ok(())
}
