//! Evaluation harness: task scoring + the experiment drivers that
//! regenerate every table and figure of the paper (DESIGN.md section 5).
//!
//! Scoring contract (LOOM-Eval substitute): greedy generation, exact
//! match of the expected answer tokens (all our proxy answers are short
//! and deterministic), scaled to 0-100 like the paper's tables.

pub mod experiments;

use anyhow::Result;

use crate::util::rng::Rng;

use crate::engine::Engine;
use crate::router::Policy;
use crate::tokenizer::EOS;
use crate::workload::{generate, Sample, Task};

/// Aggregated result of evaluating one (task, policy) cell.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: Task,
    pub n: usize,
    pub acc: f64,
    pub omsr: f64,
    pub prefill_ms: f64,
    pub decode_ms_per_tok: f64,
    pub kv_bytes: f64,
}

/// Exact-match score of a generation against the expected answer.
/// The generation may legitimately continue past the answer (EOS or
/// padding filler); only the leading `answer.len()` tokens count.
pub fn exact_match(generated: &[u32], answer: &[u32]) -> bool {
    generated.len() >= answer.len() && &generated[..answer.len()] == answer
}

/// Token-level F1 (multi-token answers; reported for completeness).
pub fn token_f1(generated: &[u32], answer: &[u32]) -> f64 {
    if answer.is_empty() {
        return 0.0;
    }
    let gen: Vec<u32> = generated.iter().copied().filter(|&t| t != EOS).collect();
    if gen.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    let mut pool = answer.to_vec();
    for g in &gen {
        if let Some(i) = pool.iter().position(|a| a == g) {
            pool.remove(i);
            hits += 1;
        }
    }
    let p = hits as f64 / gen.len() as f64;
    let r = hits as f64 / answer.len() as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Evaluate `n` samples of `task` at `seq_len` under `policy`.
pub fn run_task(
    engine: &mut Engine,
    task: Task,
    policy: &Policy,
    router: &str,
    n: usize,
    seq_len: usize,
    seed: u64,
) -> Result<TaskResult> {
    let mut rng = Rng::seed_from_u64(seed ^ ((task as u64) << 32) ^ seq_len as u64);
    let mut hits = 0usize;
    let mut omsr_sum = 0.0;
    let mut prefill_us = 0u64;
    let mut decode_us = 0u64;
    let mut decode_toks = 0usize;
    let mut kv_bytes = 0.0;
    for _ in 0..n {
        let Sample { prompt, answer, .. } = generate(task, &mut rng, seq_len);
        let max_new = answer.len() + 1;
        let (id, report) = engine.prefill(&prompt, policy, router)?;
        let mut gen = vec![report.first_token];
        let t0 = std::time::Instant::now();
        while gen.len() < max_new && *gen.last().unwrap() != EOS {
            gen.push(engine.decode_step(id)?);
        }
        decode_us += t0.elapsed().as_micros() as u64;
        decode_toks += gen.len().saturating_sub(1);
        engine.release(id);
        hits += exact_match(&gen, &answer) as usize;
        omsr_sum += report.omsr;
        prefill_us += report.total_us;
        kv_bytes += report.kv_bytes as f64;
    }
    Ok(TaskResult {
        task,
        n,
        acc: 100.0 * hits as f64 / n as f64,
        omsr: omsr_sum / n as f64,
        prefill_ms: prefill_us as f64 / 1e3 / n as f64,
        decode_ms_per_tok: if decode_toks > 0 {
            decode_us as f64 / 1e3 / decode_toks as f64
        } else {
            0.0
        },
        kv_bytes: kv_bytes / n as f64,
    })
}

/// Pretty one-row-per-task table, paper style.
pub fn format_table(title: &str, rows: &[(String, Vec<TaskResult>)]) -> String {
    let mut out = format!("== {title} ==\n");
    if let Some((_, first)) = rows.first() {
        out.push_str(&format!("{:<22}", "method"));
        for r in first {
            out.push_str(&format!("{:>9}", r.task.name()));
        }
        out.push_str(&format!("{:>8}{:>7}\n", "avg", "omsr"));
    }
    for (label, results) in rows {
        out.push_str(&format!("{label:<22}"));
        let mut sum = 0.0;
        let mut osum = 0.0;
        for r in results {
            out.push_str(&format!("{:>9.2}", r.acc));
            sum += r.acc;
            osum += r.omsr;
        }
        let n = results.len() as f64;
        out.push_str(&format!("{:>8.2}{:>7.2}\n", sum / n, osum / n));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_semantics() {
        assert!(exact_match(&[5, 2], &[5]));
        assert!(exact_match(&[5, 9, 9], &[5, 9]));
        assert!(!exact_match(&[9], &[5]));
        assert!(!exact_match(&[], &[5]));
    }

    #[test]
    fn f1_bounds() {
        // use content-range ids (2 == EOS is filtered from generations)
        assert_eq!(token_f1(&[41, 42, 43], &[41, 42, 43]), 1.0);
        assert_eq!(token_f1(&[70, 80], &[41, 42]), 0.0);
        let f = token_f1(&[41, 99], &[41, 42]);
        assert!(f > 0.0 && f < 1.0);
        // EOS in the generation is ignored, not counted as a miss
        assert_eq!(token_f1(&[41, 2], &[41]), 1.0);
    }
}
