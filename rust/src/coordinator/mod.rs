//! The serving coordinator: request lifecycle, admission control,
//! continuous batching and the prefill/decode scheduler.
//!
//! This is the L3 systems half of the paper: the Layer Router decides
//! *what* to compute per layer; the coordinator decides *when*, keeping
//! decode latency low (decode-priority batched rounds over the active
//! set — one `DecodeBatch` engine round-trip produces every active
//! request's next token, DESIGN.md §9) while admitting new prefills,
//! and tracking per-request routing decisions cached at prefill time
//! (paper section 3.3 — zero per-token routing overhead).
//!
//! Prefill is chunked and schedulable (DESIGN.md §10): the scheduler is
//! one round loop that each iteration runs ONE batched decode round
//! plus up to [`crate::config::ServingConfig::prefill_chunk_budget`]
//! prefill chunks, so a long prompt prefills incrementally instead of
//! stalling every running stream for its whole prefill (the
//! head-of-line blocking the monolithic admit path had). Mid-prefill
//! cancellation and deadline eviction are checked between chunks and
//! free the engine-side partial KV.
//!
//! Request lifecycle (DESIGN.md §8): [`Coordinator::open`] returns a
//! [`SessionHandle`] whose typed event stream mirrors the request's
//! life — `Queued` → `Prefilled` (TTFT point) → `Token`* → terminal
//! `Done` or `Error`. Sessions support explicit [`SessionHandle::cancel`]
//! and cancel-on-drop (the scheduler releases the engine slot and KV
//! cache between decode steps), per-request wall-clock deadlines
//! ([`Request::deadline_ms`], evicted with
//! [`RequestError::DeadlineExceeded`]), and stop conditions beyond EOS
//! ([`Request::stop_tokens`]). The legacy blocking [`Coordinator::submit`]
//! and channel-based [`Coordinator::submit_async`] are thin adapters over
//! the same scheduler path.
//!
//! Threading model (no async runtime in the offline vendor set): one
//! scheduler thread owns the active set and drives the engine thread;
//! streaming clients consume a per-session event channel. This matches
//! the single-device execution reality — the engine serializes all
//! kernel launches regardless.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{AdmissionMode, ServingConfig};
use crate::engine::{ChunkOutcome, EngineFailed, EngineHandle, PoolProfile, PrefillReport};
use crate::kvcache::prefix::RingSnap;
use crate::metrics::ServingMetrics;
use crate::router::{AttnMode, Policy};
use crate::tokenizer::EOS;

/// A client-facing request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub policy: Policy,
    pub router: String,
    /// Wall-clock budget measured from admission. When it elapses the
    /// request is evicted between decode steps with
    /// [`RequestError::DeadlineExceeded`]. `None` falls back to
    /// [`ServingConfig::default_deadline_ms`].
    pub deadline_ms: Option<u64>,
    /// Stop conditions beyond EOS: generation terminates after emitting
    /// any of these tokens (the stop token is included in the output,
    /// like EOS).
    pub stop_tokens: Vec<u32>,
    /// Keep decoding through EOS until `max_new` / a stop token /
    /// the deadline (benchmark and load-generation workloads).
    pub ignore_eos: bool,
}

impl Default for Request {
    fn default() -> Self {
        Self {
            prompt: vec![],
            max_new: 8,
            policy: Policy::Backbone,
            router: "balanced".into(),
            deadline_ms: None,
            stop_tokens: vec![],
            ignore_eos: false,
        }
    }
}

/// Completed response (also the `stats` payload of [`SessionEvent::Done`]).
#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<u32>,
    pub omsr: f64,
    pub modes: Vec<String>,
    pub ttft_us: u64,
    pub e2e_us: u64,
    pub decode_us_per_token: f64,
    pub queue_us: u64,
    /// Which data-parallel replica served the request (DESIGN.md §14;
    /// 0 on a single-replica coordinator).
    pub replica: usize,
}

/// Typed failure modes of the request lifecycle. Admission errors
/// (`QueueFull`, `Invalid`, `PromptTooLong`) are returned synchronously
/// from [`Coordinator::open`]; the rest arrive as terminal
/// [`SessionEvent::Error`] events.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestError {
    /// Admission queue full (backpressure) — retry later.
    QueueFull,
    /// Request rejected at admission (empty prompt, oversized `max_new`).
    Invalid(String),
    /// Prompt longer than the largest prefill bucket — rejected before
    /// queueing instead of surfacing as an engine failure.
    PromptTooLong { len: usize, max: usize },
    /// The request cannot be admitted right now (or ever): its worst
    /// case exceeds a serving budget, or every replica's queue is above
    /// its high watermark. `detail` is a STABLE token naming which
    /// budget tripped — `"prefill_tokens"`, `"total_tokens"`,
    /// `"pages"` (structural: the request can never fit) or
    /// `"queue_watermark"` (transient: retry after backoff) — carried
    /// on the wire error frame so clients can tell the two apart.
    Overloaded { detail: &'static str, message: String },
    /// `deadline_ms` elapsed; the request was evicted between decode
    /// steps and its engine slot and KV cache released.
    DeadlineExceeded,
    /// Cancelled via [`SessionHandle::cancel`], cancel-on-drop, or a
    /// wire `cancel` frame.
    Cancelled,
    /// Per-request engine-side failure (prefill or decode step) — the
    /// engine itself survived.
    Engine(String),
    /// The engine thread itself died (kernel panic) or stalled past the
    /// round watchdog: every in-flight request of that engine lifetime
    /// is retired with this, and supervision restarts the engine within
    /// its retry budget (DESIGN.md §12). Retryable — a restarted engine
    /// (or, in a replica set, a healthy peer) serves fresh submissions
    /// of the same request. `replica` names the failed replica
    /// (DESIGN.md §14; 0 on a single-replica coordinator) and is
    /// carried on the wire error frame.
    EngineFailed { cause: String, generation: u64, replica: usize },
    /// The coordinator is draining for shutdown ([`Coordinator::drain`]):
    /// in-flight streams finish, new admissions are rejected.
    Draining,
    /// The request was preempted under KV-pool pressure (DESIGN.md §15)
    /// more than [`crate::config::ServingConfig::max_preemptions`]
    /// times: rather than thrash park/resume forever it fails typed.
    /// Retryable — a resubmission re-enters admission fresh, ideally
    /// after backoff while the pool pressure clears.
    PreemptionExhausted { preemptions: u32 },
    /// Scheduler shut down.
    Shutdown,
}

impl RequestError {
    /// Stable machine-readable discriminator (the wire `kind` field).
    pub fn kind(&self) -> &'static str {
        match self {
            RequestError::QueueFull => "queue_full",
            RequestError::Invalid(_) => "invalid",
            RequestError::PromptTooLong { .. } => "prompt_too_long",
            RequestError::Overloaded { .. } => "overloaded",
            RequestError::DeadlineExceeded => "deadline_exceeded",
            RequestError::Cancelled => "cancelled",
            RequestError::Engine(_) => "engine",
            RequestError::EngineFailed { .. } => "engine_failed",
            RequestError::Draining => "draining",
            RequestError::PreemptionExhausted { .. } => "preemption_exhausted",
            RequestError::Shutdown => "shutdown",
        }
    }

    /// Whether an identical resubmission has a real chance of
    /// succeeding: transient load / lifecycle states (`queue_full`,
    /// `overloaded`, `draining` — another replica — and `engine_failed`
    /// during restart), not request defects or terminal outcomes. The
    /// wire protocol carries this as the error frame's `retryable` flag
    /// and [`crate::server::StreamClient::retry_with_backoff`] keys on
    /// it.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            RequestError::QueueFull
                | RequestError::Overloaded { .. }
                | RequestError::Draining
                | RequestError::EngineFailed { .. }
                | RequestError::PreemptionExhausted { .. }
        )
    }

    /// The stable `Overloaded` detail token (which budget tripped), for
    /// the wire error frame's `detail` field. `None` for other errors.
    pub fn overload_detail(&self) -> Option<&'static str> {
        match self {
            RequestError::Overloaded { detail, .. } => Some(detail),
            _ => None,
        }
    }

    /// The replica a typed engine failure came from, for the wire error
    /// frame's `replica` field. `None` for other errors.
    pub fn failed_replica(&self) -> Option<usize> {
        match self {
            RequestError::EngineFailed { replica, .. } => Some(*replica),
            _ => None,
        }
    }
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::QueueFull => {
                write!(f, "admission queue full: request rejected (backpressure)")
            }
            RequestError::Invalid(m) => write!(f, "invalid request: {m}"),
            RequestError::PromptTooLong { len, max } => {
                write!(f, "prompt of {len} tokens exceeds the largest prefill bucket ({max})")
            }
            RequestError::Overloaded { detail, message } => {
                write!(f, "overloaded ({detail}): {message}")
            }
            RequestError::DeadlineExceeded => {
                write!(f, "deadline exceeded: request evicted mid-generation")
            }
            RequestError::Cancelled => write!(f, "request cancelled"),
            RequestError::Engine(m) => write!(f, "engine failure: {m}"),
            RequestError::EngineFailed { cause, generation, replica } => {
                write!(f, "engine failed (replica {replica}, generation {generation}): {cause}")
            }
            RequestError::Draining => {
                write!(f, "draining: coordinator shutting down, not admitting new requests")
            }
            RequestError::PreemptionExhausted { preemptions } => {
                write!(
                    f,
                    "preemption budget exhausted: preempted {preemptions} times under KV-pool \
                     pressure"
                )
            }
            RequestError::Shutdown => write!(f, "scheduler shut down"),
        }
    }
}

impl std::error::Error for RequestError {}

/// One event in a session's lifecycle. `Done` and `Error` are terminal:
/// the stream closes after either.
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// Accepted into the admission queue.
    Queued,
    /// Prefill finished; the first token is available (the TTFT point).
    /// `cached_prefix_tokens` is how much of the prompt was reused from
    /// the cross-request prefix cache (0 on a cold run, DESIGN.md §13).
    Prefilled {
        first_token: u32,
        omsr: f64,
        modes: Vec<String>,
        ttft_us: u64,
        queue_us: u64,
        cached_prefix_tokens: usize,
    },
    /// One decoded token.
    Token { tok: u32, step_us: u64 },
    /// The request was preempted under KV-pool pressure (DESIGN.md
    /// §15): its pages were reclaimed for a starved peer and it is
    /// parked for a transparent resume. The `streamed` tokens emitted
    /// so far stay valid; the stream continues bit-identically after
    /// [`SessionEvent::Resumed`].
    Preempted { streamed: usize, preemptions: u32 },
    /// A preempted request finished its recompute resume: decode
    /// continues exactly where the stream left off (no token is ever
    /// re-emitted). `resume_us` is park → catch-up-complete wall clock.
    Resumed { resume_us: u64, preemptions: u32 },
    /// Generation finished (EOS, stop token, or `max_new`).
    Done { stats: Response },
    /// The request failed, was cancelled, or exceeded its deadline.
    Error { error: RequestError },
}

/// Cloneable cancellation signal for a session. Setting it is
/// idempotent; the scheduler observes it between decode steps and
/// releases the engine slot and KV cache.
#[derive(Debug, Clone)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    fn new() -> Self {
        Self(Arc::new(AtomicBool::new(false)))
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Client end of one open session: a typed event stream plus the
/// cancellation signal. Dropping the handle cancels the session
/// (a no-op once a terminal event has been emitted).
pub struct SessionHandle {
    events: Receiver<SessionEvent>,
    cancel: CancelToken,
}

impl SessionHandle {
    /// Signal cancellation; the scheduler evicts the request between
    /// decode steps and emits a terminal [`RequestError::Cancelled`].
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A detached cancellation signal (e.g. for a wire `cancel` frame
    /// handler on another thread).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Blocking receive; `None` once the stream is closed (after a
    /// terminal event, or scheduler shutdown).
    pub fn recv(&self) -> Option<SessionEvent> {
        self.events.recv().ok()
    }

    pub fn try_recv(&self) -> Option<SessionEvent> {
        self.events.try_recv().ok()
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Option<SessionEvent> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Drain to completion — the blocking-API adapter. Returns the
    /// `Done` stats or the terminal error.
    pub fn wait(self) -> Result<Response> {
        while let Some(ev) = self.recv() {
            match ev {
                SessionEvent::Done { stats } => return Ok(stats),
                SessionEvent::Error { error } => return Err(error.into()),
                _ => {}
            }
        }
        Err(RequestError::Shutdown.into())
    }
}

impl Drop for SessionHandle {
    fn drop(&mut self) {
        // cancel-on-drop: abandoned streams stop decoding instead of
        // running to completion; harmless after a terminal event.
        self.cancel.cancel();
    }
}

/// Where a request's lifecycle events go: the session API streams every
/// event; the legacy blocking adapters only see the terminal result.
enum Sink {
    Aggregate(Sender<Result<Response>>),
    Stream(Sender<SessionEvent>),
}

impl Sink {
    /// Emit a non-terminal event. Returns `false` when the stream's
    /// receiver is gone (client hung up) — the scheduler treats that as
    /// cancellation.
    fn event(&self, ev: SessionEvent) -> bool {
        match self {
            Sink::Stream(tx) => tx.send(ev).is_ok(),
            Sink::Aggregate(_) => true,
        }
    }

    fn done(&self, resp: Response) {
        match self {
            Sink::Stream(tx) => {
                let _ = tx.send(SessionEvent::Done { stats: resp });
            }
            Sink::Aggregate(tx) => {
                let _ = tx.send(Ok(resp));
            }
        }
    }

    fn error(&self, err: RequestError) {
        match self {
            Sink::Stream(tx) => {
                let _ = tx.send(SessionEvent::Error { error: err });
            }
            Sink::Aggregate(tx) => {
                let _ = tx.send(Err(err.into()));
            }
        }
    }
}

/// Committed-token charge against one replica's load gauge
/// (DESIGN.md §14): taken at dispatch, released when the request
/// reaches ANY terminal state — the guard rides the request through
/// `Pending` → `Prefilling` → `Active` and the drop releases it, so no
/// terminal path can leak load.
struct LoadGuard {
    committed: Arc<AtomicUsize>,
    tokens: usize,
}

impl LoadGuard {
    fn charge(committed: &Arc<AtomicUsize>, tokens: usize) -> Self {
        committed.fetch_add(tokens, Ordering::Relaxed);
        Self { committed: committed.clone(), tokens }
    }
}

impl Drop for LoadGuard {
    fn drop(&mut self) {
        self.committed.fetch_sub(self.tokens, Ordering::Relaxed);
    }
}

/// Everything needed to transparently resume a preempted request
/// (DESIGN.md §15): the tokens already streamed, the pinned route, and
/// the sparse-ring snapshots still held in the pool for the catch-up
/// integrity check. Rides on a [`Pending`] — a parked victim is a
/// pending request that happens to carry history.
struct ResumeState {
    /// Tokens already emitted to the client (first token + decode
    /// steps). Empty for a prefill-phase victim: nothing streamed yet,
    /// so its resume is an ordinary prefill.
    generated: Vec<u32>,
    /// Pinned per-layer route. Empty ⇒ the router re-fires on resume
    /// (only for prefill-phase victims preempted before the router
    /// ran; deterministic, so it re-derives the same decision).
    route: Vec<AttnMode>,
    /// Per-layer sparse-ring snapshots, verified against the rebuilt
    /// rings by [`EngineHandle::catch_up`] (which frees them). Cleared
    /// whenever a resume crosses an engine lifetime or a replica
    /// boundary — the pool they point into is gone.
    snaps: Vec<Option<RingSnap>>,
    /// Engine generation the snaps were taken under.
    snap_generation: u64,
    /// Pool pages the snaps still occupy, charged against the page
    /// ledger while parked.
    snap_pages: usize,
    omsr: f64,
    modes: Vec<String>,
    t_first_token: Option<Instant>,
    decode_us: u64,
    queue_us: Option<u64>,
    /// Times this request has been preempted (capped by
    /// [`ServingConfig::max_preemptions`]).
    preemptions: u32,
    /// When the preemption happened — resume latency is measured
    /// park → catch-up complete.
    t_preempted: Instant,
}

struct Pending {
    req: Request,
    /// `Some` for a parked preemption victim awaiting resume
    /// (DESIGN.md §15); `None` for a fresh arrival.
    resume: Option<ResumeState>,
    sink: Sink,
    cancel: CancelToken,
    t_arrival: Instant,
    deadline: Option<Instant>,
    /// Committed-token charge on the replica this request was
    /// dispatched to; replaced when a failover re-dispatches it.
    load: Option<LoadGuard>,
}

/// A request whose prefill job is open on the engine but not yet
/// complete — it consumes an active slot (its staged KV is real memory)
/// and advances one chunk at a time through the round loop.
struct Prefilling {
    job: u64,
    /// Prompt length, released from the prefill token budget when the
    /// final chunk promotes the request (DESIGN.md §11).
    prompt_len: usize,
    /// Worst-case total tokens (`prompt + max_new`) reserved against
    /// `max_batch_total_tokens` for the request's whole lifetime.
    budget_total: usize,
    /// Worst-case KV pages reserved against the pool.
    budget_pages: usize,
    max_new: usize,
    stop_tokens: Vec<u32>,
    ignore_eos: bool,
    policy_label: String,
    /// Arrival → first prefill-chunk execution, stamped when the first
    /// chunk is about to run (NOT at job open): time parked in the
    /// prefilling deque behind other requests' chunks is queue time.
    queue_us: Option<u64>,
    t_arrival: Instant,
    deadline: Option<Instant>,
    cancel: CancelToken,
    sink: Sink,
    /// Committed-token charge, released on any terminal (drop).
    load: Option<LoadGuard>,
    /// The original request, kept so the request can be preempted and
    /// resumed (the resume replays `req.prompt`, DESIGN.md §15).
    req: Request,
    /// `Some` when this prefill IS a resume replay of a preempted
    /// request; consumed by the catch-up at promotion.
    resume: Option<ResumeState>,
}

struct Active {
    engine_id: u64,
    /// Which replica's engine owns this request (DESIGN.md §14).
    replica: usize,
    /// Worst-case reservations inherited from [`Prefilling`], released
    /// at retirement.
    budget_total: usize,
    budget_pages: usize,
    generated: Vec<u32>,
    max_new: usize,
    stop_tokens: Vec<u32>,
    ignore_eos: bool,
    omsr: f64,
    modes: Vec<String>,
    t_arrival: Instant,
    t_first_token: Instant,
    decode_us: u64,
    queue_us: u64,
    deadline: Option<Instant>,
    cancel: CancelToken,
    sink: Sink,
    /// Committed-token charge, released on any terminal (drop).
    load: Option<LoadGuard>,
    /// The original request, kept so the request can be preempted and
    /// resumed (DESIGN.md §15).
    req: Request,
    /// The pinned per-layer route (typed mirror of `modes`), carried
    /// into the resume snapshot on preemption so the router never
    /// re-fires.
    route: Vec<AttnMode>,
    /// Times this request has been preempted so far.
    preemptions: u32,
}

/// Continuous-batching coordinator handle over a set of R
/// data-parallel engine replicas (DESIGN.md §14). [`Coordinator::open`]
/// is the primary API (event-driven session); [`Coordinator::submit`] /
/// [`Coordinator::submit_async`] are compatibility adapters over it.
///
/// Each replica owns its own engine (backend + KV pool + optional
/// prefix cache), admission queue and scheduler loop; the coordinator
/// is the dispatch layer on top — least-loaded by committed tokens,
/// session affinity toward warm prefix caches, queue-depth watermark
/// backpressure, and per-replica supervision so one replica's death
/// fails only its own in-flight streams.
pub struct Coordinator {
    set: Arc<ReplicaSetInner>,
    /// Largest prefill bucket, fetched from the engine at startup —
    /// longer prompts are rejected at admission with a typed error.
    max_prompt_len: usize,
    max_new_cap: usize,
    /// Serving token budgets (DESIGN.md §11) — a request whose worst
    /// case can never fit is rejected `Overloaded` at enqueue.
    max_batch_prefill_tokens: usize,
    max_batch_total_tokens: usize,
    /// KV pool geometry, fetched once at startup (immutable after
    /// engine load) — drives worst-case page admission.
    pool_profile: Option<PoolProfile>,
    default_deadline_ms: Option<u64>,
    /// The serving config, kept for `drain_replica` rejoin (a fresh
    /// scheduler loop needs the same knobs).
    cfg: ServingConfig,
    pub metrics: Arc<Mutex<ServingMetrics>>,
}

/// Replica lifecycle as the dispatcher sees it (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq)]
enum ReplicaState {
    /// In the dispatch set.
    Serving,
    /// `drain_replica` in progress: no new dispatch, in-flight streams
    /// finish, then the replica respawns and rejoins.
    Draining,
    /// Restart budget exhausted (or respawn failed): permanently out of
    /// the dispatch set; queued work failed over when it died.
    Dead,
}

/// The mutable half of a replica slot, swapped atomically on
/// death / drain-rejoin.
struct SlotLink {
    /// `None` once the replica left the serving set (its scheduler
    /// loop's receiver is gone).
    queue_tx: Option<SyncSender<Pending>>,
    /// This replica lifetime's drain/shutdown handshake.
    shared: Arc<SchedulerShared>,
    state: ReplicaState,
}

/// One engine replica: its handle, queue and load gauges.
struct ReplicaSlot {
    engine: EngineHandle,
    /// Depth of the replica's admission queue (shared with its
    /// scheduler loop, which decrements on dequeue).
    queue_depth: Arc<AtomicUsize>,
    /// Committed tokens: Σ (prompt + max_new) over work dispatched here
    /// and not yet retired — the load signal dispatch balances on
    /// (tokens, not request count: one 2k-prompt request is not one
    /// 8-token request).
    committed_tokens: Arc<AtomicUsize>,
    /// Watermark hysteresis latch: set when `queue_depth` reaches the
    /// high watermark, cleared when it drains to the low watermark.
    saturated: AtomicBool,
    link: Mutex<SlotLink>,
}

impl ReplicaSlot {
    /// Update and read the watermark latch (DESIGN.md §14): depth ≥
    /// high ⇒ saturated until depth ≤ low. `None` high watermark
    /// disables backpressure entirely.
    fn saturated_now(&self, high: Option<usize>, low: usize) -> bool {
        let Some(high) = high else { return false };
        let depth = self.queue_depth.load(Ordering::Relaxed);
        if depth >= high {
            self.saturated.store(true, Ordering::Relaxed);
            true
        } else if depth <= low {
            self.saturated.store(false, Ordering::Relaxed);
            false
        } else {
            self.saturated.load(Ordering::Relaxed)
        }
    }
}

/// Dispatch state shared by the coordinator handle and every replica's
/// scheduler loop (the loops hold it `Weak`, so dropping the
/// coordinator still disconnects the queues and winds the loops down).
struct ReplicaSetInner {
    slots: Vec<ReplicaSlot>,
    /// Global drain flag ([`Coordinator::drain`]): admission off
    /// everywhere, failover disabled (a draining set has no healthy
    /// peers to fail over to).
    draining: AtomicBool,
    /// Session-affinity index: hash of the prompt's first KV page →
    /// replica last dispatched a prompt with that head (DESIGN.md §14).
    /// Warm prefix-cache pages live in exactly one replica's pool, so
    /// routing shared-prefix traffic there is what turns the §13 cache
    /// into hits under scale-out. Bounded; cleared wholesale on
    /// overflow, purged per-replica on death/respawn (the pages died
    /// with the pool).
    affinity: Mutex<std::collections::HashMap<u64, usize>>,
    /// Prompt tokens hashed into the affinity key (one KV page); 0
    /// disables affinity (prefix cache off).
    affinity_tokens: usize,
    queue_high_watermark: Option<usize>,
    queue_low_watermark: usize,
    metrics: Arc<Mutex<ServingMetrics>>,
}

/// Cap on affinity-index entries before a wholesale reset (a trivially
/// bounded stand-in for LRU: the index is a routing hint, not state).
const AFFINITY_CAP: usize = 4096;

/// FNV-1a over the token ids of a prompt head — the affinity key.
fn affinity_key(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

impl ReplicaSetInner {
    /// Pick a replica and enqueue `p` (DESIGN.md §14). Policy, in
    /// order: session affinity (warm prefix pages) when the owner is
    /// serving and unsaturated; otherwise least committed tokens, ties
    /// to the lowest index (deterministic). On failure the request is
    /// handed back with the rejection. `exclude` drops one replica from
    /// consideration (failover away from the caller).
    fn dispatch(
        &self,
        mut p: Pending,
        exclude: Option<usize>,
    ) -> std::result::Result<(), (Pending, RequestError)> {
        if self.draining.load(Ordering::SeqCst) {
            return Err((p, RequestError::Draining));
        }
        let tokens = p.req.prompt.len() + p.req.max_new;
        let key = (self.affinity_tokens > 0 && p.req.prompt.len() >= self.affinity_tokens)
            .then(|| affinity_key(&p.req.prompt[..self.affinity_tokens]));
        loop {
            // serving replicas only — a Draining/Dead slot is out of
            // the dispatch set even though its loop may still be running
            let serving: Vec<usize> = (0..self.slots.len())
                .filter(|&i| Some(i) != exclude)
                .filter(|&i| self.slots[i].link.lock().unwrap().state == ReplicaState::Serving)
                .collect();
            if serving.is_empty() {
                return Err((p, RequestError::Shutdown));
            }
            let open: Vec<usize> = serving
                .iter()
                .copied()
                .filter(|&i| {
                    !self.slots[i]
                        .saturated_now(self.queue_high_watermark, self.queue_low_watermark)
                })
                .collect();
            if open.is_empty() {
                // every serving replica is above its high watermark:
                // typed retryable backpressure BEFORE the queues grow
                // to the hard capacity bound
                return Err((
                    p,
                    RequestError::Overloaded {
                        detail: "queue_watermark",
                        message: format!(
                            "all {} serving replica queues above the high watermark",
                            serving.len()
                        ),
                    },
                ));
            }
            let affinity_owner = key.and_then(|k| {
                let map = self.affinity.lock().unwrap();
                map.get(&k).copied().filter(|i| open.contains(i))
            });
            let pick = affinity_owner.unwrap_or_else(|| {
                *open
                    .iter()
                    .min_by_key(|&&i| {
                        (self.slots[i].committed_tokens.load(Ordering::Relaxed), i)
                    })
                    .expect("open is non-empty")
            });
            let slot = &self.slots[pick];
            // charge BEFORE the send so a racing dispatch on another
            // thread sees this request's load; dropped again on a miss
            p.load = Some(LoadGuard::charge(&slot.committed_tokens, tokens));
            let sent = {
                let link = slot.link.lock().unwrap();
                match (&link.queue_tx, link.state) {
                    (Some(tx), ReplicaState::Serving) => tx.try_send(p),
                    // state flipped between the scan and here: retry
                    _ => Err(TrySendError::Disconnected(p)),
                }
            };
            match sent {
                Ok(()) => {
                    slot.queue_depth.fetch_add(1, Ordering::Relaxed);
                    if let Some(k) = key {
                        let mut map = self.affinity.lock().unwrap();
                        if map.len() >= AFFINITY_CAP {
                            map.clear();
                        }
                        map.insert(k, pick);
                    }
                    let mut m = self.metrics.lock().unwrap();
                    if affinity_owner.is_some() {
                        m.dispatch_affinity_hits += 1;
                    }
                    let r = m.replica_mut(pick);
                    r.dispatched += 1;
                    r.committed_tokens =
                        slot.committed_tokens.load(Ordering::Relaxed) as u64;
                    r.queue_depth = slot.queue_depth.load(Ordering::Relaxed) as u64;
                    return Ok(());
                }
                Err(TrySendError::Full(mut back)) => {
                    back.load = None; // release the charge
                    return Err((back, RequestError::QueueFull));
                }
                Err(TrySendError::Disconnected(mut back)) => {
                    // the replica died between the scan and the send:
                    // take it out of the set and retry the remainder
                    back.load = None;
                    let mut link = slot.link.lock().unwrap();
                    link.queue_tx = None;
                    link.state = ReplicaState::Dead;
                    drop(link);
                    p = back;
                }
            }
        }
    }

    /// Drop affinity entries owned by replica `i` — its warm pages died
    /// with the pool (death, respawn, or drain-rejoin).
    fn purge_affinity(&self, i: usize) {
        self.affinity.lock().unwrap().retain(|_, &mut owner| owner != i);
    }
}

/// Coordinator ↔ scheduler shutdown handshake (DESIGN.md §12), one per
/// replica lifetime: the drain flag flips admission off; the scheduler
/// signals `done` when it has retired everything and exited (whatever
/// the reason).
struct SchedulerShared {
    draining: AtomicBool,
    done: Mutex<bool>,
    done_cv: std::sync::Condvar,
}

impl SchedulerShared {
    fn new() -> Self {
        Self {
            draining: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: std::sync::Condvar::new(),
        }
    }

    /// Block until the loop signals done or `deadline` elapses.
    fn wait_done(&self, deadline: Duration) -> bool {
        let t0 = Instant::now();
        let mut done = self.done.lock().unwrap();
        while !*done {
            let Some(remaining) = deadline.checked_sub(t0.elapsed()) else {
                return false;
            };
            let (guard, timeout) = self.done_cv.wait_timeout(done, remaining).unwrap();
            done = guard;
            if timeout.timed_out() && !*done {
                return false;
            }
        }
        true
    }
}

/// Spawn (or respawn, on drain-rejoin) replica `i`'s scheduler loop:
/// fresh queue channel + handshake, thread named `flux-scheduler-<i>`,
/// slot link swapped in atomically so dispatch migrates with it.
fn spawn_replica_loop(
    set: &Arc<ReplicaSetInner>,
    i: usize,
    engine: EngineHandle,
    cfg: &ServingConfig,
    pool_profile: &Option<PoolProfile>,
    metrics: &Arc<Mutex<ServingMetrics>>,
) -> Result<()> {
    let (queue_tx, queue_rx) = std::sync::mpsc::sync_channel(cfg.queue_capacity);
    let shared = Arc::new(SchedulerShared::new());
    let slot = &set.slots[i];
    let queue_depth = slot.queue_depth.clone();
    let ctx = ReplicaCtx { index: i, set: Arc::downgrade(set) };
    {
        let (cfg, pool_profile, metrics, shared) =
            (cfg.clone(), pool_profile.clone(), metrics.clone(), shared.clone());
        std::thread::Builder::new().name(format!("flux-scheduler-{i}")).spawn(move || {
            let _done = SchedulerDoneGuard(shared.clone());
            scheduler_loop(engine, cfg, pool_profile, queue_rx, queue_depth, metrics, shared, ctx)
        })?;
    }
    *slot.link.lock().unwrap() =
        SlotLink { queue_tx: Some(queue_tx), shared, state: ReplicaState::Serving };
    Ok(())
}

/// A scheduler loop's view of its own replica set membership: its index
/// plus a weak ref back to the dispatch layer for failover (weak so a
/// dropped coordinator still disconnects the queues and ends the loops).
struct ReplicaCtx {
    index: usize,
    set: std::sync::Weak<ReplicaSetInner>,
}

impl ReplicaCtx {
    /// Re-dispatch a queued-but-undispatched request to a healthy peer
    /// (replica death or drain, DESIGN.md §14); falls back to a typed
    /// rejection with `fallback` when no peer can take it (single
    /// replica, global drain, or every peer saturated/dead).
    fn failover_or_reject(
        &self,
        metrics: &Arc<Mutex<ServingMetrics>>,
        mut p: Pending,
        fallback: RequestError,
    ) {
        // ring snapshots are pool-local: they must never cross a
        // replica boundary (a peer's pool coincidentally at the same
        // generation would "verify" — and free — pages it doesn't own).
        // Callers release the ledger charge before failing over; this
        // is the belt-and-braces choke point.
        if let Some(rs) = p.resume.as_mut() {
            rs.snaps.clear();
            rs.snap_pages = 0;
        }
        match self.set.upgrade() {
            Some(set) => match set.dispatch(p, Some(self.index)) {
                Ok(()) => {
                    metrics.lock().unwrap().dispatch_failovers += 1;
                }
                Err((p, _)) => reject_pending(metrics, p, fallback),
            },
            None => reject_pending(metrics, p, fallback),
        }
    }

    /// Mark this replica permanently failed (restart budget exhausted)
    /// and purge its affinity entries.
    fn mark_dead(&self, metrics: &Arc<Mutex<ServingMetrics>>) {
        if let Some(set) = self.set.upgrade() {
            let slot = &set.slots[self.index];
            let mut link = slot.link.lock().unwrap();
            link.queue_tx = None;
            link.state = ReplicaState::Dead;
            drop(link);
            set.purge_affinity(self.index);
        }
        metrics.lock().unwrap().replica_mut(self.index).deaths += 1;
    }

    /// Purge this replica's affinity entries (fresh engine lifetime:
    /// the warm pages died with the old pool).
    fn purge_affinity(&self) {
        if let Some(set) = self.set.upgrade() {
            set.purge_affinity(self.index);
        }
    }
}

/// Marks the scheduler as done on every exit path — including a
/// scheduler panic — so [`Coordinator::drain`] never waits on a thread
/// that is already gone.
struct SchedulerDoneGuard(Arc<SchedulerShared>);

impl Drop for SchedulerDoneGuard {
    fn drop(&mut self) {
        *self.0.done.lock().unwrap() = true;
        self.0.done_cv.notify_all();
    }
}

impl Coordinator {
    /// Start a single-replica coordinator — the PR-3…8 layout, and the
    /// common test entry point. Equivalent to
    /// [`Coordinator::start_replicas`] with one engine.
    pub fn start(engine: EngineHandle, cfg: ServingConfig) -> Result<Arc<Self>> {
        Self::start_replicas(vec![engine], cfg)
    }

    /// Start the replica set (DESIGN.md §14): one scheduler loop per
    /// engine, plus the dispatch layer. Fails — typed, no panic — when
    /// an engine is unreachable or a thread can't spawn (the serving
    /// binary turns this into a clean CLI error). The engines must
    /// share artifacts (identical buckets and pool geometry); profile
    /// data is fetched from the first.
    pub fn start_replicas(engines: Vec<EngineHandle>, cfg: ServingConfig) -> Result<Arc<Self>> {
        anyhow::ensure!(!engines.is_empty(), "replica set needs at least one engine");
        let metrics = Arc::new(Mutex::new(ServingMetrics::default()));
        let max_prompt_len = engines[0].max_prompt_len()?;
        let pool_profile = engines[0].pool_profile().ok();
        let low_default = cfg.queue_high_watermark.map(|h| h / 2).unwrap_or(0);
        let set = Arc::new(ReplicaSetInner {
            slots: engines
                .iter()
                .map(|e| ReplicaSlot {
                    engine: e.clone(),
                    queue_depth: Arc::new(AtomicUsize::new(0)),
                    committed_tokens: Arc::new(AtomicUsize::new(0)),
                    saturated: AtomicBool::new(false),
                    link: Mutex::new(SlotLink {
                        queue_tx: None,
                        shared: Arc::new(SchedulerShared::new()),
                        state: ReplicaState::Serving,
                    }),
                })
                .collect(),
            draining: AtomicBool::new(false),
            affinity: Mutex::new(std::collections::HashMap::new()),
            affinity_tokens: if cfg.prefix_cache {
                pool_profile.as_ref().map_or(32, |pp| pp.page_tokens.max(1))
            } else {
                0
            },
            queue_high_watermark: cfg.queue_high_watermark,
            queue_low_watermark: cfg.queue_low_watermark.unwrap_or(low_default),
            metrics: metrics.clone(),
        });
        for (i, engine) in engines.into_iter().enumerate() {
            if cfg.prefix_cache {
                // each engine boots with the prefix cache disabled;
                // arm every replica's before any request can be
                // admitted (DESIGN.md §13)
                engine.set_prefix_cache(true, cfg.prefix_cache_pages)?;
            }
            spawn_replica_loop(&set, i, engine, &cfg, &pool_profile, &metrics)?;
        }
        Ok(Arc::new(Self {
            set,
            max_prompt_len,
            max_new_cap: cfg.max_new_cap,
            max_batch_prefill_tokens: cfg.max_batch_prefill_tokens,
            max_batch_total_tokens: cfg.max_batch_total_tokens,
            pool_profile,
            default_deadline_ms: cfg.default_deadline_ms,
            cfg,
            metrics,
        }))
    }

    /// Graceful drain of the WHOLE set (DESIGN.md §12): stop admitting
    /// (new submissions get typed [`RequestError::Draining`]), let
    /// every in-flight stream on every replica finish, then shut the
    /// engines down. Blocks until every scheduler loop has wound down
    /// or `deadline` elapses; returns whether the drain completed in
    /// time. Idempotent.
    pub fn drain(&self, deadline: Duration) -> bool {
        self.set.draining.store(true, Ordering::SeqCst);
        let t0 = Instant::now();
        for slot in &self.set.slots {
            let shared = slot.link.lock().unwrap().shared.clone();
            shared.draining.store(true, Ordering::SeqCst);
            let remaining = deadline.saturating_sub(t0.elapsed());
            if !shared.wait_done(remaining) {
                return false;
            }
        }
        true
    }

    /// Whether [`Coordinator::drain`] has been initiated.
    pub fn is_draining(&self) -> bool {
        self.set.draining.load(Ordering::SeqCst)
    }

    /// Rolling restart of one replica (DESIGN.md §14): take it out of
    /// the dispatch set, let its in-flight streams finish (queued but
    /// undispatched work fails over to healthy peers), then respawn its
    /// engine and rejoin. The rest of the set keeps serving throughout.
    /// Returns `Ok(false)` when the drain didn't finish within
    /// `deadline` (the replica stays `Draining`; a later call can
    /// complete the cycle).
    pub fn drain_replica(&self, i: usize, deadline: Duration) -> Result<bool> {
        anyhow::ensure!(i < self.set.slots.len(), "no replica {i}");
        let slot = &self.set.slots[i];
        let shared = {
            let mut link = slot.link.lock().unwrap();
            if link.state == ReplicaState::Dead {
                anyhow::bail!("replica {i} is dead");
            }
            link.state = ReplicaState::Draining;
            link.shared.draining.store(true, Ordering::SeqCst);
            link.shared.clone()
        };
        if !shared.wait_done(deadline) {
            return Ok(false);
        }
        // the loop exited cleanly and shut its engine lifetime down;
        // bring up a fresh one. Warm prefix pages died with the pool:
        // purge this replica's affinity entries and (defensively) its
        // prefix index before re-arming the cache.
        self.set.purge_affinity(i);
        if let Err(e) = slot.engine.respawn() {
            slot.link.lock().unwrap().state = ReplicaState::Dead;
            self.metrics.lock().unwrap().replica_mut(i).deaths += 1;
            return Err(e.context(format!("replica {i} failed to respawn after drain")));
        }
        if self.cfg.prefix_cache {
            let _ = slot.engine.prefix_clear();
            slot.engine.set_prefix_cache(true, self.cfg.prefix_cache_pages)?;
        }
        spawn_replica_loop(
            &self.set,
            i,
            slot.engine.clone(),
            &self.cfg,
            &self.pool_profile,
            &self.metrics,
        )?;
        // a global drain that raced the rejoin must still stop this
        // fresh loop
        if self.set.draining.load(Ordering::SeqCst) {
            slot.link.lock().unwrap().shared.draining.store(true, Ordering::SeqCst);
        }
        self.metrics.lock().unwrap().replica_mut(i).drains += 1;
        Ok(true)
    }

    /// Number of replicas in the set (serving or not).
    pub fn replicas(&self) -> usize {
        self.set.slots.len()
    }

    /// Per-replica committed-token load gauges (tests / introspection).
    pub fn replica_loads(&self) -> Vec<usize> {
        self.set
            .slots
            .iter()
            .map(|s| s.committed_tokens.load(Ordering::Relaxed))
            .collect()
    }

    /// Per-replica engine generations (0 = initial lifetime; bumps on
    /// every supervision respawn or drain-rejoin).
    pub fn replica_generations(&self) -> Vec<u64> {
        self.set.slots.iter().map(|s| s.engine.generation()).collect()
    }

    /// Open an event-driven session. Admission errors (full queue,
    /// over-long prompt, invalid request) are returned synchronously;
    /// everything after admission arrives on the event stream.
    pub fn open(&self, req: Request) -> std::result::Result<SessionHandle, RequestError> {
        let (tx, rx) = std::sync::mpsc::channel();
        let cancel = CancelToken::new();
        // Queued goes into the channel before enqueueing so it always
        // precedes Prefilled, even if the scheduler admits immediately.
        let _ = tx.send(SessionEvent::Queued);
        self.enqueue(req, Sink::Stream(tx), cancel.clone())?;
        Ok(SessionHandle { events: rx, cancel })
    }

    /// Submit and wait for completion — a thin adapter over [`open`].
    ///
    /// [`open`]: Coordinator::open
    pub fn submit(&self, req: Request) -> Result<Response> {
        self.open(req)?.wait()
    }

    /// Submit and get the reply channel immediately (legacy async
    /// adapter; prefer [`Coordinator::open`] for streaming).
    pub fn submit_async(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.enqueue(req, Sink::Aggregate(reply), CancelToken::new())?;
        Ok(rx)
    }

    fn enqueue(
        &self,
        req: Request,
        sink: Sink,
        cancel: CancelToken,
    ) -> std::result::Result<(), RequestError> {
        if self.set.draining.load(Ordering::SeqCst) {
            self.metrics.lock().unwrap().requests_rejected += 1;
            return Err(RequestError::Draining);
        }
        if req.prompt.is_empty() {
            self.metrics.lock().unwrap().requests_rejected += 1;
            return Err(RequestError::Invalid("empty prompt".into()));
        }
        // max_new == 0 asks for zero generated tokens; the decode loop
        // would still produce one (every prefill ends in a first token),
        // so the degenerate request is rejected instead of clamped
        if req.max_new == 0 {
            self.metrics.lock().unwrap().requests_rejected += 1;
            return Err(RequestError::Invalid("max_new must be at least 1".into()));
        }
        if req.max_new > self.max_new_cap {
            self.metrics.lock().unwrap().requests_rejected += 1;
            return Err(RequestError::Invalid(format!(
                "max_new {} exceeds cap {}",
                req.max_new, self.max_new_cap
            )));
        }
        if req.prompt.len() > self.max_prompt_len {
            self.metrics.lock().unwrap().requests_rejected += 1;
            return Err(RequestError::PromptTooLong {
                len: req.prompt.len(),
                max: self.max_prompt_len,
            });
        }
        // budget feasibility (DESIGN.md §11): a request whose WORST case
        // exceeds a whole serving budget can never be scheduled — reject
        // it now instead of letting it wedge the admission head forever
        if req.prompt.len() > self.max_batch_prefill_tokens {
            let mut m = self.metrics.lock().unwrap();
            m.requests_rejected += 1;
            m.requests_overloaded += 1;
            return Err(RequestError::Overloaded {
                detail: "prefill_tokens",
                message: format!(
                    "prompt of {} tokens exceeds max_batch_prefill_tokens {}",
                    req.prompt.len(),
                    self.max_batch_prefill_tokens
                ),
            });
        }
        if req.prompt.len() + req.max_new > self.max_batch_total_tokens {
            let mut m = self.metrics.lock().unwrap();
            m.requests_rejected += 1;
            m.requests_overloaded += 1;
            return Err(RequestError::Overloaded {
                detail: "total_tokens",
                message: format!(
                    "worst case of {} tokens exceeds max_batch_total_tokens {}",
                    req.prompt.len() + req.max_new,
                    self.max_batch_total_tokens
                ),
            });
        }
        if let Some(pp) = &self.pool_profile {
            // the admission charge (DESIGN.md §15): the worst case under
            // `WorstCase` (today's behavior, bit-for-bit), a configurable
            // fraction of it under `Optimistic` — route-aware truth
            // replaces the estimate at the prefill→decode promotion, and
            // runtime pool exhaustion is handled by preemption
            let worst = pp.worst_case_pages(req.prompt.len(), req.max_new);
            let pages = self.cfg.admission_mode.admission_pages(worst);
            if pages > pp.total_pages {
                let mut m = self.metrics.lock().unwrap();
                m.requests_rejected += 1;
                m.requests_overloaded += 1;
                return Err(RequestError::Overloaded {
                    detail: "pages",
                    message: format!(
                        "admission charge of {pages} KV pages exceeds the pool budget of {}",
                        pp.total_pages
                    ),
                });
            }
        }
        let t_arrival = Instant::now();
        let deadline = req
            .deadline_ms
            .or(self.default_deadline_ms)
            .and_then(|ms| t_arrival.checked_add(Duration::from_millis(ms)));
        let pending = Pending { req, resume: None, sink, cancel, t_arrival, deadline, load: None };
        match self.set.dispatch(pending, None) {
            Ok(()) => Ok(()),
            Err((_, err)) => {
                {
                    let mut m = self.metrics.lock().unwrap();
                    match &err {
                        RequestError::Shutdown => {}
                        RequestError::Overloaded { .. } => {
                            m.requests_rejected += 1;
                            m.requests_overloaded += 1;
                            m.watermark_rejections += 1;
                        }
                        _ => m.requests_rejected += 1,
                    }
                }
                Err(err)
            }
        }
    }

    /// Total queued-but-undispatched requests across every replica.
    pub fn queue_depth(&self) -> usize {
        self.set.slots.iter().map(|s| s.queue_depth.load(Ordering::Relaxed)).sum()
    }
}

/// The unified round scheduler (DESIGN.md §10): every loop iteration
/// runs ONE batched decode round over the active set plus up to
/// `prefill_chunk_budget` prefill chunks off the prefilling queue, so
/// inter-token latency of running streams stays flat while long prompts
/// prefill incrementally — no head-of-line blocking on a monolithic
/// prefill, no fixed decode-rounds-per-prefill ratio.
fn scheduler_loop(
    engine: EngineHandle,
    cfg: ServingConfig,
    pool_profile: Option<PoolProfile>,
    queue_rx: Receiver<Pending>,
    queue_depth: Arc<AtomicUsize>,
    metrics: Arc<Mutex<ServingMetrics>>,
    shared: Arc<SchedulerShared>,
    ctx: ReplicaCtx,
) {
    let mut active: VecDeque<Active> = VecDeque::new();
    let mut prefilling: VecDeque<Prefilling> = VecDeque::new();
    let mut budgets = Budgets::default();
    // the head-of-line request whose worst case doesn't fit the running
    // batch's budgets right now: it parks here (FIFO preserved) until
    // retirements free budget, instead of being dropped or skipped
    let mut parked: Option<Pending> = None;
    // preemption victims awaiting resume (DESIGN.md §15): requests
    // whose KV pages were reclaimed under pool pressure. They outrank
    // fresh arrivals at admission — they already streamed tokens.
    let mut victims: VecDeque<Pending> = VecDeque::new();
    let mut queue_closed = false;
    let chunk_budget = cfg.prefill_chunk_budget.max(1);
    let round_timeout = cfg.engine_round_timeout_ms.map(Duration::from_millis);
    loop {
        // --- drain (DESIGN.md §12): reject parked + queued arrivals
        // with a typed error, keep running rounds until the in-flight
        // set finishes, then shut the engine down and exit ---
        if shared.draining.load(Ordering::SeqCst) {
            // queued-but-undispatched work never touched this engine:
            // during a per-replica drain it fails over to a healthy
            // peer; during a global drain every peer refuses and the
            // request is rejected with the typed fallback
            if let Some(p) = parked.take() {
                ctx.failover_or_reject(&metrics, p, RequestError::Draining);
            }
            // parked preemption victims drain with their LOGICAL
            // snapshot (streamed tokens + pinned route): ring snaps are
            // pool-local, so they are freed here and a peer resumes by
            // full recompute; with no peer the stream ends typed
            while let Some(mut p) = victims.pop_front() {
                if let Some(rs) = p.resume.as_mut() {
                    budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
                    if engine.generation() == rs.snap_generation {
                        engine.free_snaps(std::mem::take(&mut rs.snaps));
                    }
                    rs.snaps.clear();
                    rs.snap_pages = 0;
                }
                ctx.failover_or_reject(&metrics, p, RequestError::Draining);
            }
            if active.is_empty() && prefilling.is_empty() {
                engine.shutdown();
                return;
            }
        } else {
            // a parked head-of-line request that died while waiting
            // (cancelled, or deadline elapsed) must not sit holding the
            // admission head until a slot frees up: retire it now with
            // the same counters the open-path rejection uses
            if let Some(p) = parked.take() {
                if p.cancel.is_cancelled() {
                    let mut m = metrics.lock().unwrap();
                    m.requests_cancelled += 1;
                    m.stream_tokens.record_value(0);
                    drop(m);
                    p.sink.error(RequestError::Cancelled);
                } else if p.deadline.is_some_and(|d| Instant::now() >= d) {
                    let mut m = metrics.lock().unwrap();
                    m.requests_expired += 1;
                    m.stream_tokens.record_value(0);
                    drop(m);
                    p.sink.error(RequestError::DeadlineExceeded);
                } else {
                    parked = Some(p);
                }
            }
            // parked victims honor cancel and deadline while waiting
            sweep_victims(&engine, &metrics, &mut budgets, &mut victims);
            let mut engine_down: Option<anyhow::Error> = None;
            // --- resume (DESIGN.md §15): parked preemption victims
            // re-enter the prefill pipeline ahead of fresh arrivals.
            // The route is already pinned, so the page charge is the
            // TRUE routed peak, not an estimate ---
            while active.len() + prefilling.len() < cfg.max_active_requests {
                let Some(mut p) = victims.pop_front() else { break };
                let prompt_len = p.req.prompt.len();
                let worst_total = prompt_len + p.req.max_new;
                let pages = pool_profile.as_ref().map_or(0, |pp| match p.resume.as_ref() {
                    Some(rs) if !rs.route.is_empty() => pp.routed_pages(
                        prompt_len,
                        p.req.max_new,
                        &rs.route,
                        p.req.policy.decode_mode(),
                    ),
                    _ => cfg
                        .admission_mode
                        .admission_pages(pp.worst_case_pages(prompt_len, p.req.max_new)),
                });
                let fits = budgets.prefill_tokens + prompt_len <= cfg.max_batch_prefill_tokens
                    && budgets.total_tokens + worst_total <= cfg.max_batch_total_tokens
                    && pool_profile
                        .as_ref()
                        .map_or(true, |pp| budgets.pages + pages <= pp.total_pages);
                if !fits {
                    if active.is_empty() && prefilling.is_empty() {
                        // with nothing running the budgets cannot drain
                        // any further: the only reclaimable charge left
                        // is the parked ring snapshots themselves, so
                        // drop them all (resumes then verify nothing)
                        // and re-evaluate the fit
                        let mut freed = false;
                        for v in std::iter::once(&mut p).chain(victims.iter_mut()) {
                            let Some(rs) = v.resume.as_mut() else { continue };
                            if rs.snap_pages == 0 {
                                continue;
                            }
                            budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
                            if engine.generation() == rs.snap_generation {
                                engine.free_snaps(std::mem::take(&mut rs.snaps));
                            }
                            rs.snaps.clear();
                            rs.snap_pages = 0;
                            freed = true;
                        }
                        if freed {
                            victims.push_front(p);
                            continue;
                        }
                        if pool_profile.as_ref().is_some_and(|pp| pages > pp.total_pages) {
                            // the pinned route's true peak exceeds the
                            // whole pool — optimistic admission let the
                            // request in, the router went dense, and no
                            // amount of preemption can make it fit: fail
                            // typed retryable instead of spinning forever
                            let total = pool_profile.as_ref().map_or(0, |pp| pp.total_pages);
                            dispose_victim(
                                &engine,
                                &metrics,
                                &mut budgets,
                                p,
                                RequestError::Overloaded {
                                    detail: "pages",
                                    message: format!(
                                        "resume needs {pages} KV pages but the pool holds only {total}"
                                    ),
                                },
                            );
                            continue;
                        }
                    }
                    victims.push_front(p);
                    break;
                }
                match open_prefill(&engine, &cfg, &metrics, &mut budgets, p, ctx.index) {
                    OpenOutcome::Opened(mut pf) => {
                        pf.prompt_len = prompt_len;
                        pf.budget_total = worst_total;
                        pf.budget_pages = pages;
                        budgets.prefill_tokens += prompt_len;
                        budgets.total_tokens += worst_total;
                        budgets.pages += pages;
                        prefilling.push_back(pf);
                    }
                    OpenOutcome::Rejected => {}
                    OpenOutcome::PoolDry(p) => {
                        // staging found the pool dry even after prefix
                        // eviction: park the victim back and preempt to
                        // actually free pages for the next attempt
                        victims.push_front(p);
                        preempt_one(
                            &engine,
                            &cfg,
                            &metrics,
                            &mut budgets,
                            &mut active,
                            &mut victims,
                            &[],
                        );
                        break;
                    }
                    OpenOutcome::EngineDead(e) => {
                        engine_down = Some(e);
                        break;
                    }
                }
            }
            // --- admission (DESIGN.md §11): drain arrivals into the
            // prefill pipeline while their worst case fits the
            // token/page budgets. Opening a job validates and allocates
            // staging but runs no compute, so admission never stalls
            // decode; an idle scheduler waits here for the next request
            // (with a short timeout so a drain can wake it) ---
            while active.len() + prefilling.len() < cfg.max_active_requests {
                let p = if let Some(p) = parked.take() {
                    p
                } else if queue_closed {
                    break;
                } else if active.is_empty() && prefilling.is_empty() && parked.is_none() {
                    match queue_rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(p) => {
                            queue_depth.fetch_sub(1, Ordering::Relaxed);
                            p
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                            queue_closed = true;
                            break;
                        }
                    }
                } else {
                    match queue_rx.try_recv() {
                        Ok(p) => {
                            queue_depth.fetch_sub(1, Ordering::Relaxed);
                            p
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            queue_closed = true;
                            break;
                        }
                    }
                };
                // a dead request (cancelled / expired while queued or
                // parked) must not wedge the admission head: open_prefill
                // rejects it with the right terminal event before touching
                // the engine, so no budget is charged (cancel is sticky and
                // time is monotonic, so it cannot admit here)
                if p.cancel.is_cancelled() || p.deadline.is_some_and(|d| Instant::now() >= d) {
                    match open_prefill(&engine, &cfg, &metrics, &mut budgets, p, ctx.index) {
                        OpenOutcome::Opened(pf) => prefilling.push_back(pf),
                        OpenOutcome::Rejected | OpenOutcome::PoolDry(_) => {}
                        OpenOutcome::EngineDead(e) => {
                            engine_down = Some(e);
                            break;
                        }
                    }
                    continue;
                }
                let prompt_len = p.req.prompt.len();
                let worst_total = prompt_len + p.req.max_new;
                let pages = pool_profile.as_ref().map_or(0, |pp| {
                    cfg.admission_mode
                        .admission_pages(pp.worst_case_pages(prompt_len, p.req.max_new))
                });
                let fits = budgets.prefill_tokens + prompt_len <= cfg.max_batch_prefill_tokens
                    && budgets.total_tokens + worst_total <= cfg.max_batch_total_tokens
                    && pool_profile
                        .as_ref()
                        .map_or(true, |pp| budgets.pages + pages <= pp.total_pages);
                if !fits {
                    // enqueue-side feasibility checks guarantee a lone
                    // request always fits an empty batch, so parking can
                    // never deadlock: budgets drain back to zero as the
                    // running batch retires
                    parked = Some(p);
                    break;
                }
                match open_prefill(&engine, &cfg, &metrics, &mut budgets, p, ctx.index) {
                    OpenOutcome::Opened(mut pf) => {
                        pf.prompt_len = prompt_len;
                        pf.budget_total = worst_total;
                        pf.budget_pages = pages;
                        budgets.prefill_tokens += prompt_len;
                        budgets.total_tokens += worst_total;
                        budgets.pages += pages;
                        prefilling.push_back(pf);
                    }
                    OpenOutcome::Rejected => {}
                    OpenOutcome::PoolDry(p) => {
                        // optimism met a dry pool at staging time: hold
                        // the request at the admission head and preempt
                        // a victim so the retry can allocate
                        parked = Some(p);
                        preempt_one(
                            &engine,
                            &cfg,
                            &metrics,
                            &mut budgets,
                            &mut active,
                            &mut victims,
                            &[],
                        );
                        break;
                    }
                    OpenOutcome::EngineDead(e) => {
                        engine_down = Some(e);
                        break;
                    }
                }
            }
            if let Some(err) = engine_down {
                if !supervise_engine_failure(
                    &engine, &cfg, &metrics, &mut budgets, &mut active, &mut prefilling,
                    &mut victims, err, &ctx,
                ) {
                    fail_remaining(
                        &metrics,
                        &queue_rx,
                        &queue_depth,
                        parked.take(),
                        &mut victims,
                        &engine,
                        &ctx,
                    );
                    return;
                }
                continue;
            }
        }

        if active.is_empty() && prefilling.is_empty() && parked.is_none() && victims.is_empty() {
            if queue_closed {
                return;
            }
            continue;
        }

        // --- one batched decode round over the active set: one engine
        // round-trip produces every active request's next token (§9);
        // retirement (cancel / deadline / EOS / stop / max_new) is
        // checked before the batch is formed ---
        sweep_retired(&engine, &metrics, &mut budgets, &mut active);
        if !active.is_empty() {
            let ids: Vec<u64> = active.iter().map(|a| a.engine_id).collect();
            match engine.decode_batch_deadline(ids, round_timeout) {
                Err(e) => {
                    // the engine itself died or stalled mid-round:
                    // typed retirement of everything in flight, then
                    // restart within the retry budget (DESIGN.md §12)
                    if !supervise_engine_failure(
                        &engine, &cfg, &metrics, &mut budgets, &mut active, &mut prefilling,
                        &mut victims, e, &ctx,
                    ) {
                        fail_remaining(
                            &metrics,
                            &queue_rx,
                            &queue_depth,
                            parked.take(),
                            &mut victims,
                            &engine,
                            &ctx,
                        );
                        return;
                    }
                }
                Ok(reply) => {
                    let crate::engine::DecodeBatchReport {
                        tokens,
                        step_us,
                        kv_transfer,
                        fa_group_slots,
                        sa_group_slots,
                        pool_pages,
                        prefix_evictions,
                        prefix_retained_pages,
                        ..
                    } = reply;
                    // one metrics lock per round (not per token), with
                    // the KV totals riding on the batch reply
                    {
                        let mut m = metrics.lock().unwrap();
                        m.decode_rounds += 1;
                        m.decode_batch_size.record_value(active.len() as u64);
                        m.fa_group_slots += fa_group_slots;
                        m.sa_group_slots += sa_group_slots;
                        for (res, &us) in tokens.iter().zip(&step_us) {
                            if res.is_ok() {
                                m.decode.record_us(us);
                            }
                        }
                        m.note_kv_transfer_totals(kv_transfer.0, kv_transfer.1);
                        m.note_pool_pages(pool_pages.0, pool_pages.1, pool_pages.2);
                        // gauges piggybacked on the decode reply, like
                        // the pool pages (cumulative / current values,
                        // not per-round deltas)
                        m.prefix_evictions = prefix_evictions;
                        m.prefix_retained_pages = prefix_retained_pages;
                    }
                    let mut kept = VecDeque::with_capacity(active.len());
                    let mut starved: Vec<u64> = Vec::new();
                    for ((mut a, res), &us) in active.drain(..).zip(tokens).zip(&step_us) {
                        match res {
                            Ok(tok) => {
                                a.decode_us += us;
                                a.generated.push(tok);
                                if a.sink.event(SessionEvent::Token { tok, step_us: us }) {
                                    kept.push_back(a);
                                } else {
                                    // receiver gone: stop decoding
                                    retire(&engine, &metrics, &mut budgets, a, Retire::Cancelled);
                                }
                            }
                            Err(e) => {
                                let msg = e.to_string();
                                if msg.contains("kv pool exhausted") {
                                    // pool starvation is not the
                                    // requester's failure (DESIGN.md
                                    // §15): the append was pre-flight
                                    // reserved so its state is
                                    // untouched — it retries next round
                                    // after a victim is preempted below
                                    starved.push(a.engine_id);
                                    kept.push_back(a);
                                } else {
                                    retire(
                                        &engine,
                                        &metrics,
                                        &mut budgets,
                                        a,
                                        Retire::Failed(msg),
                                    );
                                }
                            }
                        }
                    }
                    active = kept;
                    if !starved.is_empty() {
                        // free real pages for the starved requesters:
                        // youngest-by-arrival victim, never a starved
                        // requester itself unless every active is starved
                        preempt_one(
                            &engine,
                            &cfg,
                            &metrics,
                            &mut budgets,
                            &mut active,
                            &mut victims,
                            &starved,
                        );
                    }
                }
            }
        }

        // --- up to `prefill_chunk_budget` prefill chunks, FIFO across
        // prefilling requests: running streams wait at most this many
        // chunk calls between decode rounds ---
        let t_chunks = Instant::now();
        // snapshot BEFORE chunks run: a final chunk promotes its request
        // into `active`, which must not retroactively count this phase
        // as decode stall when no stream was actually waiting
        let had_decoders = !active.is_empty();
        let mut budget = chunk_budget;
        while budget > 0 {
            // mid-prefill cancellation / deadline eviction: checked
            // between chunks over the WHOLE prefilling set (not just the
            // FIFO front), so a session queued behind a long prefill
            // releases its slot and staged KV the moment it dies
            sweep_prefilling(&engine, &metrics, &mut budgets, &mut prefilling);
            let Some(mut pf) = prefilling.pop_front() else { break };
            budget -= 1;
            // queue time ends when the request's FIRST chunk runs —
            // waiting parked behind other requests' chunks counts
            if pf.queue_us.is_none() {
                pf.queue_us = Some(pf.t_arrival.elapsed().as_micros() as u64);
            }
            match engine.prefill_chunk_deadline(pf.job, round_timeout) {
                Ok(ChunkOutcome::More { .. }) => {
                    metrics.lock().unwrap().prefill_chunks += 1;
                    // front, not back: the oldest request finishes first
                    prefilling.push_front(pf);
                }
                Ok(ChunkOutcome::Done { id, report }) => {
                    metrics.lock().unwrap().prefill_chunks += 1;
                    if let Some(a) = finish_prefill(
                        &engine,
                        &cfg,
                        &metrics,
                        &mut budgets,
                        &mut victims,
                        &pool_profile,
                        pf,
                        id,
                        report,
                        ctx.index,
                    ) {
                        active.push_back(a);
                    }
                }
                Err(e) if e.downcast_ref::<EngineFailed>().is_some() => {
                    // the engine itself died or stalled, not just this
                    // job: put the request back with its peers so the
                    // whole in-flight set retires typed, then supervise
                    prefilling.push_front(pf);
                    if !supervise_engine_failure(
                        &engine, &cfg, &metrics, &mut budgets, &mut active, &mut prefilling,
                        &mut victims, e, &ctx,
                    ) {
                        fail_remaining(
                            &metrics,
                            &queue_rx,
                            &queue_depth,
                            parked.take(),
                            &mut victims,
                            &engine,
                            &ctx,
                        );
                        return;
                    }
                    break;
                }
                Err(e) => {
                    let msg = e.to_string();
                    if msg.contains("kv pool exhausted") {
                        // mid-prefill pool starvation (DESIGN.md §15):
                        // the engine already dropped the job, so the
                        // requester itself parks as a victim (resume
                        // replays the prompt), and a decode victim is
                        // preempted so the retry can actually allocate
                        park_prefilling(&engine, &cfg, &metrics, &mut budgets, &mut victims, pf);
                        preempt_one(
                            &engine,
                            &cfg,
                            &metrics,
                            &mut budgets,
                            &mut active,
                            &mut victims,
                            &[],
                        );
                    } else {
                        // an ADMITTED request dying mid-prefill is an
                        // engine failure (like a mid-decode one), not an
                        // admission rejection; the engine already dropped
                        // the failed job — retire_prefilling's cancel is
                        // belt-and-braces
                        retire_prefilling(&engine, &metrics, &mut budgets, pf, Retire::Failed(msg));
                    }
                }
            }
        }
        if had_decoders && budget < chunk_budget {
            // stall accounting: how long decode streams waited on
            // prefill work this round
            let stall = t_chunks.elapsed().as_micros() as u64;
            if stall > 0 {
                metrics.lock().unwrap().decode_stall_us += stall;
            }
        }

        // finished generations retire before the next admission pass
        // (same sweep as the round start — the policy lives in one place)
        sweep_retired(&engine, &metrics, &mut budgets, &mut active);
    }
}

/// Reject a queued/parked request with a typed terminal error without
/// it ever touching the engine (drain rejection, restart-budget
/// exhaustion).
fn reject_pending(metrics: &Arc<Mutex<ServingMetrics>>, p: Pending, err: RequestError) {
    {
        let mut m = metrics.lock().unwrap();
        m.requests_rejected += 1;
        m.stream_tokens.record_value(0);
    }
    p.sink.error(err);
}

/// The engine died (kernel panic) or stalled (round watchdog): retire
/// every in-flight request with a typed [`RequestError::EngineFailed`],
/// then restart the engine within the configured retry budget with
/// exponential backoff. Arrivals keep queueing meanwhile (the bounded
/// admission queue is the parking lot) and are admitted after the
/// restart. Returns `false` when the budget is exhausted — the caller
/// fails everything left and shuts the scheduler down (DESIGN.md §12).
fn supervise_engine_failure(
    engine: &EngineHandle,
    cfg: &ServingConfig,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    active: &mut VecDeque<Active>,
    prefilling: &mut VecDeque<Prefilling>,
    victims: &mut VecDeque<Pending>,
    err: anyhow::Error,
    ctx: &ReplicaCtx,
) -> bool {
    let (cause, generation, stalled) = match err.downcast_ref::<EngineFailed>() {
        Some(f) => (f.cause.clone(), f.generation, f.stalled),
        None => (err.to_string(), engine.generation(), false),
    };
    // parked preemption victims SURVIVE the lifetime change — their
    // engine-side state was already freed at preemption — but their
    // ring snaps died with the old pool: drop them (no free; the pages
    // are gone) and resume by full recompute on the fresh lifetime
    for p in victims.iter_mut() {
        if let Some(rs) = p.resume.as_mut() {
            budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
            rs.snap_pages = 0;
            rs.snaps.clear();
        }
    }
    if stalled {
        metrics.lock().unwrap().watchdog_trips += 1;
    }
    eprintln!(
        "flux-scheduler-{}: engine {} (generation {generation}): {cause}",
        ctx.index,
        if stalled { "stalled" } else { "failed" }
    );
    let failed = RequestError::EngineFailed { cause, generation, replica: ctx.index };
    // every request of the dead lifetime retires typed — its engine-side
    // state is gone (the release/cancel sends inside retire go to the
    // dead lifetime's channel and are dropped; a merely-stalled engine
    // processes them when it unwedges, freeing its KV before exiting)
    while let Some(a) = active.pop_front() {
        retire(engine, metrics, budgets, a, Retire::EngineDead(failed.clone()));
    }
    while let Some(pf) = prefilling.pop_front() {
        retire_prefilling(engine, metrics, budgets, pf, Retire::EngineDead(failed.clone()));
    }
    let mut backoff = Duration::from_millis(cfg.engine_restart_backoff_ms.max(1));
    for attempt in 1..=cfg.engine_restart_max {
        std::thread::sleep(backoff);
        match engine.respawn() {
            Ok(new_generation) => {
                {
                    let mut m = metrics.lock().unwrap();
                    m.engine_restarts += 1;
                    m.replica_mut(ctx.index).restarts += 1;
                }
                if cfg.prefix_cache {
                    // the dead lifetime's prefix index refers to pages of
                    // a pool that no longer exists: clear it explicitly
                    // before re-arming so a fresh lifetime can never
                    // serve (or retain) pages from the dead pool
                    let _ = engine.prefix_clear();
                    let _ = engine.set_prefix_cache(true, cfg.prefix_cache_pages);
                }
                // coordinator-side mirror of the same staleness: session
                // affinity pointing at this replica promised warm pages
                // that died with the old pool
                ctx.purge_affinity();
                eprintln!(
                    "flux-scheduler-{}: engine restarted (generation {new_generation}, \
                     attempt {attempt}/{})",
                    ctx.index, cfg.engine_restart_max
                );
                return true;
            }
            Err(e) => {
                eprintln!(
                    "flux-scheduler-{}: engine restart attempt {attempt}/{} failed: {e}",
                    ctx.index, cfg.engine_restart_max
                );
                backoff *= 2;
            }
        }
    }
    false
}

/// Restart budget exhausted: mark the replica dead so dispatch stops
/// routing to it, then fail over the parked request and everything
/// still queued — work that never touched this engine completes on a
/// healthy peer; with no peers left it rejects typed. Later submissions
/// are re-routed by dispatch (or get `Shutdown` with no replicas left).
fn fail_remaining(
    metrics: &Arc<Mutex<ServingMetrics>>,
    queue_rx: &Receiver<Pending>,
    queue_depth: &Arc<AtomicUsize>,
    parked: Option<Pending>,
    victims: &mut VecDeque<Pending>,
    engine: &EngineHandle,
    ctx: &ReplicaCtx,
) {
    eprintln!(
        "flux-scheduler-{}: engine restart budget exhausted, shutting down replica",
        ctx.index
    );
    ctx.mark_dead(metrics);
    let failed = RequestError::EngineFailed {
        cause: "engine restart budget exhausted".into(),
        generation: engine.generation(),
        replica: ctx.index,
    };
    if let Some(p) = parked {
        ctx.failover_or_reject(metrics, p, failed.clone());
    }
    // parked preemption victims fail over with their logical snapshot
    // (streamed tokens + pinned route); their ring snaps died with this
    // replica's pool, and failover_or_reject strips them
    while let Some(p) = victims.pop_front() {
        ctx.failover_or_reject(metrics, p, failed.clone());
    }
    while let Ok(p) = queue_rx.try_recv() {
        queue_depth.fetch_sub(1, Ordering::Relaxed);
        ctx.failover_or_reject(metrics, p, failed.clone());
    }
}

/// Worst-case resource reservations of the running batch (DESIGN.md
/// §11). Charged at admission, partially released at prefill→decode
/// promotion (the prompt leaves the prefill budget), fully released at
/// retirement — so admission is O(1) against three counters.
#[derive(Default)]
struct Budgets {
    /// Sum of prompt tokens across requests currently in prefill.
    prefill_tokens: usize,
    /// Sum of worst-case totals (`prompt + max_new`) across the batch.
    total_tokens: usize,
    /// Sum of worst-case KV pages across the batch.
    pages: usize,
}

impl Budgets {
    fn release_prefilling(&mut self, pf: &Prefilling) {
        self.prefill_tokens = self.prefill_tokens.saturating_sub(pf.prompt_len);
        self.total_tokens = self.total_tokens.saturating_sub(pf.budget_total);
        self.pages = self.pages.saturating_sub(pf.budget_pages);
    }

    fn release_active(&mut self, a: &Active) {
        self.total_tokens = self.total_tokens.saturating_sub(a.budget_total);
        self.pages = self.pages.saturating_sub(a.budget_pages);
    }
}

/// Terminate one prefilling request: free the engine-side job (its
/// staged KV) and emit the terminal event, updating the per-outcome
/// counters — the prefilling-side mirror of [`retire`].
fn retire_prefilling(
    engine: &EngineHandle,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    mut pf: Prefilling,
    how: Retire,
) {
    budgets.release_prefilling(&pf);
    engine.prefill_cancel(pf.job);
    // a resume-in-flight still holds its ring snapshots (catch-up never
    // ran): free them, unless they died with an older engine lifetime
    if let Some(rs) = pf.resume.take() {
        budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
        if engine.generation() == rs.snap_generation {
            engine.free_snaps(rs.snaps);
        }
    }
    {
        let mut m = metrics.lock().unwrap();
        m.stream_tokens.record_value(0);
        match &how {
            Retire::Cancelled => m.requests_cancelled += 1,
            Retire::Expired => m.requests_expired += 1,
            Retire::Failed(_) | Retire::EngineDead(_) => m.requests_failed += 1,
            Retire::Done => unreachable!("prefilling requests never retire as Done"),
        }
    }
    match how {
        Retire::Cancelled => pf.sink.error(RequestError::Cancelled),
        Retire::Expired => pf.sink.error(RequestError::DeadlineExceeded),
        Retire::Failed(msg) => pf.sink.error(RequestError::Engine(msg)),
        Retire::EngineDead(err) => pf.sink.error(err),
        Retire::Done => unreachable!("prefilling requests never retire as Done"),
    }
}

/// Terminate every prefilling request whose session was cancelled or
/// whose deadline elapsed — anywhere in the deque, not only the FIFO
/// front — freeing the engine-side partial KV and the active slot.
/// Survivors keep their order.
fn sweep_prefilling(
    engine: &EngineHandle,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    prefilling: &mut VecDeque<Prefilling>,
) {
    let now = Instant::now();
    let mut kept = VecDeque::with_capacity(prefilling.len());
    while let Some(pf) = prefilling.pop_front() {
        if pf.cancel.is_cancelled() {
            retire_prefilling(engine, metrics, budgets, pf, Retire::Cancelled);
            continue;
        }
        if pf.deadline.is_some_and(|d| now >= d) {
            retire_prefilling(engine, metrics, budgets, pf, Retire::Expired);
            continue;
        }
        kept.push_back(pf);
    }
    *prefilling = kept;
}

/// Retire every request the next round must not decode: cancelled
/// sessions, elapsed deadlines, and finished generations (EOS without
/// `ignore_eos`, a stop token, or `max_new`). Shared by the decode
/// round start and the post-reply handling so the retirement policy is
/// written exactly once; survivors keep their order.
fn sweep_retired(
    engine: &EngineHandle,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    active: &mut VecDeque<Active>,
) {
    let now = Instant::now();
    let mut kept = VecDeque::with_capacity(active.len());
    while let Some(a) = active.pop_front() {
        if a.cancel.is_cancelled() {
            retire(engine, metrics, budgets, a, Retire::Cancelled);
            continue;
        }
        if a.deadline.is_some_and(|d| now >= d) {
            retire(engine, metrics, budgets, a, Retire::Expired);
            continue;
        }
        let last = *a.generated.last().unwrap();
        let done = a.generated.len() >= a.max_new
            || (last == EOS && !a.ignore_eos)
            || a.stop_tokens.contains(&last);
        if done {
            retire(engine, metrics, budgets, a, Retire::Done);
            continue;
        }
        kept.push_back(a);
    }
    *active = kept;
}

/// Index of the youngest-by-arrival active request outside `exclude`
/// (the preemption victim-selection policy, DESIGN.md §15).
fn youngest(active: &VecDeque<Active>, exclude: &[u64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, a) in active.iter().enumerate() {
        if exclude.contains(&a.engine_id) {
            continue;
        }
        best = match best {
            Some(j) if active[j].t_arrival >= a.t_arrival => Some(j),
            _ => Some(i),
        };
    }
    best
}

/// Preempt ONE victim to relieve KV-pool pressure (DESIGN.md §15):
/// youngest-by-arrival among decode-phase requests, never one of the
/// requesters whose allocation failed (`exclude`) unless every active
/// request is starved. The victim's caches are freed (sparse rings
/// snapshot first, reusing the prefix cache's `RingSnap`), a
/// `Preempted` event is emitted, and the request parks on the victims
/// queue for a transparent resume. A victim over its `max_preemptions`
/// budget instead fails typed retryable — its retirement still frees
/// its pages. Returns whether any pages were freed.
fn preempt_one(
    engine: &EngineHandle,
    cfg: &ServingConfig,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    active: &mut VecDeque<Active>,
    victims: &mut VecDeque<Pending>,
    exclude: &[u64],
) -> bool {
    let pick = youngest(active, exclude).or_else(|| youngest(active, &[]));
    let Some(i) = pick else { return false };
    let Some(mut a) = active.remove(i) else { return false };
    a.preemptions += 1;
    if a.preemptions > cfg.max_preemptions {
        metrics.lock().unwrap().preemption_exhausted += 1;
        let err = RequestError::PreemptionExhausted { preemptions: a.preemptions - 1 };
        retire(engine, metrics, budgets, a, Retire::EngineDead(err));
        return true;
    }
    match engine.preempt(a.engine_id) {
        Ok(info) => {
            budgets.release_active(&a);
            // the snap blocks stay in the pool while parked
            budgets.pages += info.snap_pages;
            {
                let mut m = metrics.lock().unwrap();
                m.preemptions += 1;
                m.preempted_pages_freed += info.pages_freed as u64;
            }
            let alive = a.sink.event(SessionEvent::Preempted {
                streamed: a.generated.len(),
                preemptions: a.preemptions,
            });
            if !alive {
                // receiver gone: sweep_victims disposes it next round
                a.cancel.cancel();
            }
            let Active {
                generated,
                omsr,
                modes,
                t_arrival,
                t_first_token,
                decode_us,
                queue_us,
                deadline,
                cancel,
                sink,
                load,
                req,
                route,
                preemptions,
                ..
            } = a;
            victims.push_back(Pending {
                req,
                resume: Some(ResumeState {
                    generated,
                    route,
                    snaps: info.ring_snaps,
                    snap_generation: engine.generation(),
                    snap_pages: info.snap_pages,
                    omsr,
                    modes,
                    t_first_token: Some(t_first_token),
                    decode_us,
                    queue_us: Some(queue_us),
                    preemptions,
                    t_preempted: Instant::now(),
                }),
                sink,
                cancel,
                t_arrival,
                deadline,
                load,
            });
            true
        }
        Err(e) => {
            // the preempt round-trip itself failed; engine death
            // surfaces on the next decode round and routes into
            // supervision
            retire(engine, metrics, budgets, a, Retire::Failed(format!("preemption failed: {e}")));
            false
        }
    }
}

/// A prefill job died to pool starvation: the engine already freed the
/// job's staged KV, so the requester itself parks as a resume victim
/// (DESIGN.md §15) — its resume replays the prompt (route pinned if the
/// router had fired on an earlier attempt). Ring snaps a
/// resume-in-flight carried ride along untouched: its catch-up never
/// ran, so they are still live in the pool.
fn park_prefilling(
    engine: &EngineHandle,
    cfg: &ServingConfig,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    victims: &mut VecDeque<Pending>,
    pf: Prefilling,
) {
    budgets.release_prefilling(&pf);
    engine.prefill_cancel(pf.job);
    let Prefilling { queue_us, t_arrival, deadline, cancel, sink, load, req, resume, .. } = pf;
    let mut rs = resume.unwrap_or_else(|| ResumeState {
        generated: vec![],
        route: vec![],
        snaps: vec![],
        snap_generation: engine.generation(),
        snap_pages: 0,
        omsr: 0.0,
        modes: vec![],
        t_first_token: None,
        decode_us: 0,
        queue_us: None,
        preemptions: 0,
        t_preempted: Instant::now(),
    });
    rs.queue_us = queue_us.or(rs.queue_us);
    rs.preemptions += 1;
    rs.t_preempted = Instant::now();
    if rs.preemptions > cfg.max_preemptions {
        budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
        if engine.generation() == rs.snap_generation {
            engine.free_snaps(rs.snaps);
        }
        {
            let mut m = metrics.lock().unwrap();
            m.preemption_exhausted += 1;
            m.requests_failed += 1;
            m.stream_tokens.record_value(rs.generated.len() as u64);
        }
        sink.error(RequestError::PreemptionExhausted { preemptions: rs.preemptions - 1 });
        return;
    }
    metrics.lock().unwrap().preemptions += 1;
    let alive = sink.event(SessionEvent::Preempted {
        streamed: rs.generated.len(),
        preemptions: rs.preemptions,
    });
    if !alive {
        cancel.cancel();
    }
    victims.push_back(Pending { req, resume: Some(rs), sink, cancel, t_arrival, deadline, load });
}

/// Dispose a parked preemption victim WITHOUT touching the engine's
/// request map (its engine-side state was freed at preemption): free
/// the ring snapshots (unless they died with an old engine lifetime),
/// release the page ledger, and emit the terminal event.
fn dispose_victim(
    engine: &EngineHandle,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    p: Pending,
    err: RequestError,
) {
    let Pending { resume, sink, .. } = p;
    let streamed = resume.as_ref().map_or(0, |rs| rs.generated.len());
    if let Some(rs) = resume {
        budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
        if engine.generation() == rs.snap_generation {
            engine.free_snaps(rs.snaps);
        }
    }
    {
        let mut m = metrics.lock().unwrap();
        m.stream_tokens.record_value(streamed as u64);
        match &err {
            RequestError::Cancelled => m.requests_cancelled += 1,
            RequestError::DeadlineExceeded => m.requests_expired += 1,
            _ => m.requests_failed += 1,
        }
    }
    sink.error(err);
}

/// Parked victims honor cancel and deadline while waiting (DESIGN.md
/// §15) — checked every round, like the active and prefilling sweeps.
fn sweep_victims(
    engine: &EngineHandle,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    victims: &mut VecDeque<Pending>,
) {
    let now = Instant::now();
    let mut kept = VecDeque::with_capacity(victims.len());
    while let Some(p) = victims.pop_front() {
        if p.cancel.is_cancelled() {
            dispose_victim(engine, metrics, budgets, p, RequestError::Cancelled);
            continue;
        }
        if p.deadline.is_some_and(|d| now >= d) {
            dispose_victim(engine, metrics, budgets, p, RequestError::DeadlineExceeded);
            continue;
        }
        kept.push_back(p);
    }
    *victims = kept;
}

/// What became of a dequeued request in [`open_prefill`]: admitted into
/// the prefill pipeline, rejected with its terminal event already
/// emitted, handed back intact because the pool is dry (the caller
/// preempts and retries), or stopped by engine death (terminal event
/// emitted; the caller routes the error into supervision).
enum OpenOutcome {
    Opened(Prefilling),
    Rejected,
    /// The staging allocation found the pool dry even after prefix
    /// eviction (DESIGN.md §15): the request is handed back untouched
    /// so the scheduler can preempt a victim and retry.
    PoolDry(Pending),
    EngineDead(anyhow::Error),
}

/// Validate a dequeued request (cancelled / expired while queued) and
/// open its engine-side prefill job. No prefill compute happens here —
/// chunks are scheduled by the round loop.
fn open_prefill(
    engine: &EngineHandle,
    cfg: &ServingConfig,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    p: Pending,
    replica: usize,
) -> OpenOutcome {
    // terminal paths below must release a victim's resume snapshots —
    // a parked victim rejected here would otherwise leak its snap pages
    let dispose_resume = |budgets: &mut Budgets, resume: Option<ResumeState>| {
        if let Some(rs) = resume {
            budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
            if engine.generation() == rs.snap_generation {
                engine.free_snaps(rs.snaps);
            }
        }
    };
    if p.cancel.is_cancelled() {
        let mut m = metrics.lock().unwrap();
        m.requests_cancelled += 1;
        m.stream_tokens.record_value(0);
        drop(m);
        dispose_resume(budgets, p.resume);
        p.sink.error(RequestError::Cancelled);
        return OpenOutcome::Rejected;
    }
    if p.deadline.is_some_and(|d| Instant::now() >= d) {
        let mut m = metrics.lock().unwrap();
        m.requests_expired += 1;
        m.stream_tokens.record_value(0);
        drop(m);
        dispose_resume(budgets, p.resume);
        p.sink.error(RequestError::DeadlineExceeded);
        return OpenOutcome::Rejected;
    }
    // a resume replays the prompt with the route pre-pinned so the
    // router never re-fires (DESIGN.md §15); a prefill-phase victim
    // (empty route) re-runs its original policy — greedy determinism
    // re-derives the same routing decision
    let open_policy = match &p.resume {
        Some(rs) if !rs.route.is_empty() => {
            Policy::Static { modes: rs.route.clone(), decode: p.req.policy.decode_mode() }
        }
        _ => p.req.policy.clone(),
    };
    let policy_label = p.req.policy.label();
    match engine.prefill_open(
        p.req.prompt.clone(),
        open_policy,
        p.req.router.clone(),
        cfg.prefill_chunk_tokens,
    ) {
        Ok(job) => {
            let Pending { req, resume, sink, cancel, t_arrival, deadline, load } = p;
            // a resume keeps its original queue-time stamp (arrival →
            // FIRST chunk of the original run); a fresh request is
            // stamped when its first chunk runs
            let queue_us = resume.as_ref().and_then(|rs| rs.queue_us);
            OpenOutcome::Opened(Prefilling {
                job,
                // budget reservations are stamped by the admission loop
                // (the only caller that charges them)
                prompt_len: 0,
                budget_total: 0,
                budget_pages: 0,
                max_new: req.max_new,
                stop_tokens: req.stop_tokens.clone(),
                ignore_eos: req.ignore_eos,
                policy_label,
                queue_us,
                t_arrival,
                deadline,
                cancel,
                sink,
                load,
                req,
                resume,
            })
        }
        Err(e) => {
            if let Some(f) = e.downcast_ref::<EngineFailed>() {
                // engine death during admission routes into supervision
                // (the caller restarts and resumes admitting); this
                // request is its first typed casualty
                metrics.lock().unwrap().requests_rejected += 1;
                dispose_resume(budgets, p.resume);
                p.sink.error(RequestError::EngineFailed {
                    cause: f.cause.clone(),
                    generation: f.generation,
                    replica,
                });
                OpenOutcome::EngineDead(e)
            } else if e.to_string().contains("kv pool exhausted") {
                // not a rejection: the caller preempts a victim and
                // retries with the request intact
                OpenOutcome::PoolDry(p)
            } else {
                metrics.lock().unwrap().requests_rejected += 1;
                dispose_resume(budgets, p.resume);
                p.sink.error(RequestError::Engine(e.to_string()));
                OpenOutcome::Rejected
            }
        }
    }
}

/// Final-chunk bookkeeping: metrics (TTFT is the real arrival→first-
/// token wall clock, so the histogram reflects chunk interleaving under
/// load), the route-aware ledger correction (DESIGN.md §15), the
/// `Prefilled` event (or the resume catch-up and `Resumed` event), and
/// promotion into the decode set.
fn finish_prefill(
    engine: &EngineHandle,
    cfg: &ServingConfig,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    victims: &mut VecDeque<Pending>,
    pool_profile: &Option<PoolProfile>,
    pf: Prefilling,
    engine_id: u64,
    report: PrefillReport,
    replica: usize,
) -> Option<Active> {
    let Prefilling {
        prompt_len,
        budget_total,
        mut budget_pages,
        max_new,
        stop_tokens,
        ignore_eos,
        policy_label,
        queue_us,
        t_arrival,
        deadline,
        cancel,
        sink,
        load,
        req,
        resume,
        ..
    } = pf;
    // the prompt leaves the prefill budget at promotion; the total-token
    // and page reservations ride on the Active until retirement
    budgets.prefill_tokens = budgets.prefill_tokens.saturating_sub(prompt_len);
    // --- route-aware ledger correction (DESIGN.md §15): the router has
    // fired, so under Optimistic admission the estimated page charge is
    // replaced by the TRUE routed peak — smaller for sparse-routed
    // layouts, larger when the optimism undershot. WorstCase keeps the
    // worst-case charge so §11 admission decisions stay bit-for-bit
    // today's. (A resume was charged its routed peak at re-admission;
    // recomputing it here is identical.) ---
    if let (Some(pp), AdmissionMode::Optimistic { .. }) = (pool_profile.as_ref(), cfg.admission_mode)
    {
        let routed = pp.routed_pages(prompt_len, max_new, &report.modes, req.policy.decode_mode());
        budgets.pages = budgets.pages.saturating_sub(budget_pages) + routed;
        budget_pages = routed;
    }
    // always Some by now for a fresh request (the first chunk stamps it
    // before running); a resume carries its original stamp
    let queue_us = queue_us.unwrap_or(0);
    let t_now = Instant::now();
    let ttft_us = t_now.duration_since(t_arrival).as_micros() as u64;
    // a prefill-phase victim resumes into its FIRST token: TTFT and the
    // per-request routing facts are recorded now, exactly once; a
    // decode-phase victim recorded them at its original promotion
    let first_promotion = resume.as_ref().map_or(true, |rs| rs.generated.is_empty());
    {
        let mut m = metrics.lock().unwrap();
        m.prefill.record_us(report.total_us);
        m.router_overhead.record_us(report.router_us);
        m.prompt_tokens += report.prompt_len as u64;
        if first_promotion {
            m.ttft.record_us(ttft_us);
            m.record_omsr(&policy_label, report.omsr);
            if cfg.prefix_cache {
                if report.cached_prefix_tokens > 0 {
                    m.prefix_hits += 1;
                    m.prefix_tokens_reused += report.cached_prefix_tokens as u64;
                } else {
                    m.prefix_misses += 1;
                }
            }
        }
    }
    let Some(rs) = resume else {
        let modes: Vec<String> = report.modes.iter().map(|m| m.name().into()).collect();
        let a = Active {
            engine_id,
            budget_total,
            budget_pages,
            generated: vec![report.first_token],
            max_new,
            stop_tokens,
            ignore_eos,
            omsr: report.omsr,
            modes: modes.clone(),
            t_arrival,
            t_first_token: t_now,
            decode_us: 0,
            queue_us,
            deadline,
            cancel,
            sink,
            replica,
            load,
            route: report.modes.clone(),
            preemptions: 0,
            req,
        };
        // a session cancelled (or expired) during its FINAL prefill chunk
        // must not receive a `Prefilled` event or hold pages for a round:
        // re-check both before emitting, retiring through the normal path
        // (which releases the engine-side request and its pool pages)
        if a.cancel.is_cancelled() {
            retire(engine, metrics, budgets, a, Retire::Cancelled);
            return None;
        }
        if a.deadline.is_some_and(|d| Instant::now() >= d) {
            retire(engine, metrics, budgets, a, Retire::Expired);
            return None;
        }
        let alive = a.sink.event(SessionEvent::Prefilled {
            first_token: report.first_token,
            omsr: report.omsr,
            modes,
            ttft_us,
            queue_us,
            cached_prefix_tokens: report.cached_prefix_tokens,
        });
        return if alive {
            Some(a)
        } else {
            retire(engine, metrics, budgets, a, Retire::Cancelled);
            None
        };
    };
    // --- resume catch-up (DESIGN.md §15): the replayed prefill rebuilt
    // the prompt KV; teacher-force the already-streamed tokens so the
    // engine state matches the uninterrupted run exactly, then verify
    // the rebuilt sparse rings against the preemption snapshots ---
    // the snapshots leave the ledger here whatever happens next:
    // catch-up frees them on every exit path, and stale ones (older
    // engine lifetime) died with their pool
    budgets.pages = budgets.pages.saturating_sub(rs.snap_pages);
    let verify =
        if engine.generation() == rs.snap_generation { rs.snaps.clone() } else { Vec::new() };
    // greedy decode is deterministic, so the replayed prefill's first
    // token must equal the first token the client already streamed —
    // the bit-identity invariant, checked rather than assumed
    if !rs.generated.is_empty() && report.first_token != rs.generated[0] {
        engine.free_snaps(verify);
        engine.release(engine_id);
        budgets.total_tokens = budgets.total_tokens.saturating_sub(budget_total);
        budgets.pages = budgets.pages.saturating_sub(budget_pages);
        {
            let mut m = metrics.lock().unwrap();
            m.requests_failed += 1;
            m.stream_tokens.record_value(rs.generated.len() as u64);
        }
        sink.error(RequestError::Engine(format!(
            "resume integrity: replayed first token {} diverges from streamed {}",
            report.first_token, rs.generated[0]
        )));
        return None;
    }
    let force: Vec<u32> = rs.generated.get(1..).map_or_else(Vec::new, <[u32]>::to_vec);
    match engine.catch_up(engine_id, force, verify) {
        Ok(()) => {
            let resume_us = rs.t_preempted.elapsed().as_micros() as u64;
            {
                let mut m = metrics.lock().unwrap();
                m.resumes += 1;
                m.resume_latency.record_us(resume_us);
            }
            let (omsr, modes) = if first_promotion {
                (report.omsr, report.modes.iter().map(|m| m.name().into()).collect())
            } else {
                (rs.omsr, rs.modes)
            };
            let a = Active {
                engine_id,
                budget_total,
                budget_pages,
                generated: if first_promotion { vec![report.first_token] } else { rs.generated },
                max_new,
                stop_tokens,
                ignore_eos,
                omsr,
                modes: modes.clone(),
                t_arrival,
                t_first_token: rs.t_first_token.unwrap_or(t_now),
                decode_us: rs.decode_us,
                queue_us,
                deadline,
                cancel,
                sink,
                replica,
                load,
                route: report.modes.clone(),
                preemptions: rs.preemptions,
                req,
            };
            if a.cancel.is_cancelled() {
                retire(engine, metrics, budgets, a, Retire::Cancelled);
                return None;
            }
            if a.deadline.is_some_and(|d| Instant::now() >= d) {
                retire(engine, metrics, budgets, a, Retire::Expired);
                return None;
            }
            let mut alive =
                a.sink.event(SessionEvent::Resumed { resume_us, preemptions: a.preemptions });
            if alive && first_promotion {
                // a prefill-phase victim never got its Prefilled event:
                // the first token only exists now
                alive = a.sink.event(SessionEvent::Prefilled {
                    first_token: report.first_token,
                    omsr: report.omsr,
                    modes,
                    ttft_us,
                    queue_us,
                    cached_prefix_tokens: report.cached_prefix_tokens,
                });
            }
            if alive {
                Some(a)
            } else {
                retire(engine, metrics, budgets, a, Retire::Cancelled);
                None
            }
        }
        Err(e) => {
            // catch-up may have stepped partway: the engine-side state
            // is not resumable, release it (freeing its pages)
            engine.release(engine_id);
            budgets.total_tokens = budgets.total_tokens.saturating_sub(budget_total);
            budgets.pages = budgets.pages.saturating_sub(budget_pages);
            let msg = e.to_string();
            if msg.contains("kv pool exhausted") {
                // starved AGAIN mid-catch-up: park once more (the ring
                // snaps were consumed by the failed catch-up, so the
                // next resume verifies nothing)
                let preemptions = rs.preemptions + 1;
                if preemptions > cfg.max_preemptions {
                    {
                        let mut m = metrics.lock().unwrap();
                        m.preemption_exhausted += 1;
                        m.requests_failed += 1;
                        m.stream_tokens.record_value(rs.generated.len() as u64);
                    }
                    sink.error(RequestError::PreemptionExhausted {
                        preemptions: preemptions - 1,
                    });
                    return None;
                }
                metrics.lock().unwrap().preemptions += 1;
                let alive = sink.event(SessionEvent::Preempted {
                    streamed: rs.generated.len(),
                    preemptions,
                });
                if !alive {
                    cancel.cancel();
                }
                victims.push_front(Pending {
                    req,
                    resume: Some(ResumeState {
                        generated: rs.generated,
                        route: rs.route,
                        snaps: Vec::new(),
                        snap_generation: engine.generation(),
                        snap_pages: 0,
                        omsr: rs.omsr,
                        modes: rs.modes,
                        t_first_token: rs.t_first_token,
                        decode_us: rs.decode_us,
                        queue_us: Some(queue_us),
                        preemptions,
                        t_preempted: Instant::now(),
                    }),
                    sink,
                    cancel,
                    t_arrival,
                    deadline,
                    load,
                });
                None
            } else {
                {
                    let mut m = metrics.lock().unwrap();
                    m.requests_failed += 1;
                    m.stream_tokens.record_value(rs.generated.len() as u64);
                }
                sink.error(RequestError::Engine(msg));
                None
            }
        }
    }
}

enum Retire {
    Done,
    Cancelled,
    Expired,
    /// Per-request engine failure (the message becomes `Error::Engine`);
    /// the engine itself survived and keeps serving its peers.
    Failed(String),
    /// The engine lifetime died under this request: the prebuilt
    /// [`RequestError::EngineFailed`] is emitted verbatim so every
    /// casualty of one failure reports the same cause and generation.
    EngineDead(RequestError),
}

/// Release the engine slot (freeing the KV cache) and emit the terminal
/// event, updating the per-outcome counters.
fn retire(
    engine: &EngineHandle,
    metrics: &Arc<Mutex<ServingMetrics>>,
    budgets: &mut Budgets,
    a: Active,
    how: Retire,
) {
    budgets.release_active(&a);
    engine.release(a.engine_id);
    let e2e = a.t_arrival.elapsed().as_micros() as u64;
    // destructuring drops the LoadGuard here, releasing the replica's
    // committed-token charge on every terminal path at once
    let Active {
        generated,
        omsr,
        modes,
        t_arrival,
        t_first_token,
        decode_us,
        queue_us,
        sink,
        replica,
        ..
    } = a;
    let n_dec = generated.len().saturating_sub(1).max(1);
    let streamed = generated.len() as u64;
    {
        let mut m = metrics.lock().unwrap();
        m.stream_tokens.record_value(streamed);
        match &how {
            Retire::Done => {
                m.requests_completed += 1;
                m.tokens_generated += streamed;
                m.e2e.record_us(e2e);
            }
            Retire::Cancelled => m.requests_cancelled += 1,
            Retire::Expired => m.requests_expired += 1,
            Retire::Failed(_) | Retire::EngineDead(_) => m.requests_failed += 1,
        }
    }
    match how {
        Retire::Done => sink.done(Response {
            omsr,
            modes,
            ttft_us: t_first_token.duration_since(t_arrival).as_micros() as u64,
            e2e_us: e2e,
            decode_us_per_token: decode_us as f64 / n_dec as f64,
            queue_us,
            tokens: generated,
            replica,
        }),
        Retire::Cancelled => sink.error(RequestError::Cancelled),
        Retire::Expired => sink.error(RequestError::DeadlineExceeded),
        Retire::Failed(msg) => sink.error(RequestError::Engine(msg)),
        Retire::EngineDead(err) => sink.error(err),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults_compose() {
        let r = Request {
            prompt: vec![1, 2, 3],
            max_new: 4,
            policy: Policy::Backbone,
            ..Default::default()
        };
        assert_eq!(r.policy.label(), "backbone");
        assert_eq!(r.max_new, 4);
        assert_eq!(r.deadline_ms, None);
        assert!(r.stop_tokens.is_empty());
        assert!(!r.ignore_eos);
    }

    #[test]
    fn request_error_kinds_are_stable() {
        assert_eq!(RequestError::QueueFull.kind(), "queue_full");
        assert_eq!(RequestError::DeadlineExceeded.kind(), "deadline_exceeded");
        assert_eq!(RequestError::Cancelled.kind(), "cancelled");
        assert_eq!(RequestError::PromptTooLong { len: 10, max: 4 }.kind(), "prompt_too_long");
        let msg = RequestError::PromptTooLong { len: 10, max: 4 }.to_string();
        assert!(msg.contains("10") && msg.contains("4"), "{msg}");
        let failed =
            RequestError::EngineFailed { cause: "kaboom".into(), generation: 3, replica: 1 };
        assert_eq!(failed.kind(), "engine_failed");
        assert_eq!(failed.failed_replica(), Some(1));
        let msg = failed.to_string();
        assert!(msg.contains("kaboom") && msg.contains("3") && msg.contains("replica 1"), "{msg}");
        assert_eq!(RequestError::Draining.kind(), "draining");
        let over = RequestError::Overloaded {
            detail: "queue_watermark",
            message: "all queues saturated".into(),
        };
        assert_eq!(over.kind(), "overloaded");
        assert_eq!(over.overload_detail(), Some("queue_watermark"));
        let msg = over.to_string();
        assert!(msg.contains("queue_watermark") && msg.contains("saturated"), "{msg}");
        let exhausted = RequestError::PreemptionExhausted { preemptions: 4 };
        assert_eq!(exhausted.kind(), "preemption_exhausted");
        let msg = exhausted.to_string();
        assert!(msg.contains('4'), "{msg}");
    }

    /// The retryable taxonomy (DESIGN.md §12): transient load and
    /// lifecycle states invite a resubmission; request defects and
    /// terminal outcomes do not. The wire `retryable` flag and
    /// `StreamClient::retry_with_backoff` both key on this.
    #[test]
    fn retryable_classification() {
        assert!(RequestError::QueueFull.retryable());
        assert!(
            RequestError::Overloaded { detail: "pages", message: "busy".into() }.retryable()
        );
        assert!(RequestError::Draining.retryable());
        assert!(RequestError::PreemptionExhausted { preemptions: 4 }.retryable());
        assert!(
            RequestError::EngineFailed { cause: "x".into(), generation: 0, replica: 0 }
                .retryable()
        );
        assert!(!RequestError::Invalid("bad".into()).retryable());
        assert!(!RequestError::PromptTooLong { len: 9, max: 8 }.retryable());
        assert!(!RequestError::DeadlineExceeded.retryable());
        assert!(!RequestError::Cancelled.retryable());
        assert!(!RequestError::Engine("kernel".into()).retryable());
        assert!(!RequestError::Shutdown.retryable());
    }

    #[test]
    fn cancel_token_is_sticky_and_shared() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled());
        t.cancel(); // idempotent
        assert!(t2.is_cancelled());
    }
}
