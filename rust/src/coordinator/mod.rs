//! The serving coordinator: request lifecycle, admission control,
//! continuous batching and the prefill/decode scheduler.
//!
//! This is the L3 systems half of the paper: the Layer Router decides
//! *what* to compute per layer; the coordinator decides *when*, keeping
//! decode latency low (decode-priority round-robin over the active set)
//! while admitting new prefills, and tracking per-request routing
//! decisions cached at prefill time (paper section 3.3 — zero per-token
//! routing overhead).
//!
//! Threading model (no async runtime in the offline vendor set): one
//! scheduler thread owns the active set and drives the engine thread;
//! clients block on a per-request reply channel. This matches the
//! single-device execution reality — the engine serializes all kernel
//! launches regardless.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::EngineHandle;
use crate::metrics::ServingMetrics;
use crate::router::Policy;
use crate::tokenizer::EOS;

/// A client-facing request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub policy: Policy,
    pub router: String,
}

/// Completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<u32>,
    pub omsr: f64,
    pub modes: Vec<String>,
    pub ttft_us: u64,
    pub e2e_us: u64,
    pub decode_us_per_token: f64,
    pub queue_us: u64,
}

struct Active {
    engine_id: u64,
    generated: Vec<u32>,
    max_new: usize,
    omsr: f64,
    modes: Vec<String>,
    t_arrival: Instant,
    t_first_token: Instant,
    decode_us: u64,
    queue_us: u64,
    reply: Sender<Result<Response>>,
}

struct Pending {
    req: Request,
    reply: Sender<Result<Response>>,
    t_arrival: Instant,
}

/// Continuous-batching coordinator handle. `submit` blocks until the
/// request completes; clients use one thread per in-flight request
/// (see `submit_async` for a non-blocking variant returning a channel).
pub struct Coordinator {
    queue_tx: SyncSender<Pending>,
    queue_depth: Arc<AtomicUsize>,
    pub metrics: Arc<Mutex<ServingMetrics>>,
}

impl Coordinator {
    /// Start the scheduler thread.
    pub fn start(engine: EngineHandle, cfg: ServingConfig) -> Arc<Self> {
        let (queue_tx, queue_rx) = std::sync::mpsc::sync_channel(cfg.queue_capacity);
        let metrics = Arc::new(Mutex::new(ServingMetrics::default()));
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let coord = Arc::new(Self {
            queue_tx,
            queue_depth: queue_depth.clone(),
            metrics: metrics.clone(),
        });
        std::thread::Builder::new()
            .name("flux-scheduler".into())
            .spawn(move || scheduler_loop(engine, cfg, queue_rx, queue_depth, metrics))
            .expect("spawn scheduler");
        coord
    }

    /// Submit and wait for completion. Fails fast when the admission
    /// queue is full (backpressure).
    pub fn submit(&self, req: Request) -> Result<Response> {
        self.submit_async(req)?
            .recv()
            .map_err(|_| anyhow::anyhow!("scheduler shut down"))?
    }

    /// Submit and get the reply channel immediately.
    pub fn submit_async(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        let (reply, rx) = std::sync::mpsc::channel();
        let pending = Pending { req, reply, t_arrival: Instant::now() };
        match self.queue_tx.try_send(pending) {
            Ok(()) => {
                self.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.lock().unwrap().requests_rejected += 1;
                anyhow::bail!("admission queue full: request rejected (backpressure)")
            }
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("scheduler shut down"),
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }
}

fn scheduler_loop(
    engine: EngineHandle,
    cfg: ServingConfig,
    queue_rx: Receiver<Pending>,
    queue_depth: Arc<AtomicUsize>,
    metrics: Arc<Mutex<ServingMetrics>>,
) {
    let mut active: VecDeque<Active> = VecDeque::new();
    let mut queue_closed = false;
    loop {
        // --- admission: take at most one prefill per outer iteration
        // (decode-priority), more if the active set is empty ---
        while !queue_closed && active.len() < cfg.max_active_requests {
            let pending = if active.is_empty() {
                match queue_rx.recv() {
                    Ok(p) => Some(p),
                    Err(_) => {
                        queue_closed = true;
                        None
                    }
                }
            } else {
                match queue_rx.try_recv() {
                    Ok(p) => Some(p),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        queue_closed = true;
                        None
                    }
                }
            };
            let Some(Pending { req, reply, t_arrival }) = pending else { break };
            queue_depth.fetch_sub(1, Ordering::Relaxed);
            let queue_us = t_arrival.elapsed().as_micros() as u64;
            match engine.prefill(req.prompt.clone(), req.policy.clone(), req.router.clone()) {
                Ok((engine_id, report)) => {
                    {
                        let mut m = metrics.lock().unwrap();
                        m.prefill.record_us(report.total_us);
                        m.router_overhead.record_us(report.router_us);
                        m.ttft.record_us(queue_us + report.total_us);
                        m.prompt_tokens += report.prompt_len as u64;
                        m.record_omsr(&req.policy.label(), report.omsr);
                    }
                    active.push_back(Active {
                        engine_id,
                        generated: vec![report.first_token],
                        max_new: req.max_new.max(1),
                        omsr: report.omsr,
                        modes: report.modes.iter().map(|m| m.name().into()).collect(),
                        t_arrival,
                        t_first_token: Instant::now(),
                        decode_us: 0,
                        queue_us,
                        reply,
                    });
                }
                Err(e) => {
                    let _ = reply.send(Err(e));
                    metrics.lock().unwrap().requests_rejected += 1;
                }
            }
            // decode-priority: stop admitting once something is active
            break;
        }

        if active.is_empty() {
            if queue_closed {
                return;
            }
            continue;
        }

        // --- decode rounds over the active set ---
        for _ in 0..cfg.decode_steps_per_prefill {
            let mut still_active = VecDeque::new();
            while let Some(mut a) = active.pop_front() {
                let done =
                    a.generated.len() >= a.max_new || *a.generated.last().unwrap() == EOS;
                if done {
                    finish(&engine, &metrics, a);
                    continue;
                }
                let t0 = Instant::now();
                match engine.decode_step(a.engine_id) {
                    Ok(tok) => {
                        let dt = t0.elapsed().as_micros() as u64;
                        a.decode_us += dt;
                        metrics.lock().unwrap().decode.record_us(dt);
                        a.generated.push(tok);
                        still_active.push_back(a);
                    }
                    Err(e) => {
                        let _ = a.reply.send(Err(e));
                        engine.release(a.engine_id);
                    }
                }
            }
            active = still_active;
            if active.is_empty() {
                break;
            }
        }

        // refresh the zero-copy KV accounting (absolute engine totals)
        if let Ok((moved, borrowed)) = engine.kv_transfer_totals() {
            let mut m = metrics.lock().unwrap();
            m.kv_bytes_moved = moved;
            m.kv_bytes_borrowed = borrowed;
        }
    }
}

fn finish(engine: &EngineHandle, metrics: &Arc<Mutex<ServingMetrics>>, a: Active) {
    engine.release(a.engine_id);
    let e2e = a.t_arrival.elapsed().as_micros() as u64;
    let n_dec = a.generated.len().saturating_sub(1).max(1);
    let resp = Response {
        omsr: a.omsr,
        modes: a.modes,
        ttft_us: a.t_first_token.duration_since(a.t_arrival).as_micros() as u64,
        e2e_us: e2e,
        decode_us_per_token: a.decode_us as f64 / n_dec as f64,
        queue_us: a.queue_us,
        tokens: a.generated,
    };
    {
        let mut m = metrics.lock().unwrap();
        m.requests_completed += 1;
        m.tokens_generated += resp.tokens.len() as u64;
        m.e2e.record_us(e2e);
    }
    let _ = a.reply.send(Ok(resp));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults_compose() {
        let r = Request {
            prompt: vec![1, 2, 3],
            max_new: 4,
            policy: Policy::Backbone,
            router: "balanced".into(),
        };
        assert_eq!(r.policy.label(), "backbone");
        assert_eq!(r.max_new, 4);
    }
}
