//! Workload generators: the LongBench-E proxy suite, the RULER needle
//! ladder, the reasoning/math proxies and Poisson request-arrival traces.
//!
//! Mirrors `python/compile/data.py` (same task taxonomy, same layout
//! `[BOS TAG ctx.. QUERY q.. ANSWER a.. EOS]`, same sparsity-sensitivity
//! classes); distributional equivalence is what matters — the backbone
//! was pretrained on the python generators.

use crate::util::rng::Rng;

use crate::tokenizer::{ANSWER, BOS, CONTENT, QUERY, SEP, TAG_BASE, VOCAB};

pub const NCONTENT: u32 = VOCAB - CONTENT; // 480

/// LongBench-E category (paper Table 1 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    SDocQA,
    MDocQA,
    Summ,
    Icl,
    Synthetic,
    Code,
    Ruler,
    Reasoning,
    Math,
}

/// Every generatable task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Qasper,
    MFen,
    HotQA,
    Wiki2,
    Gov,
    MNews,
    Trec,
    Tqa,
    Sams,
    PCount,
    PRe,
    Rbp,
    Lcc,
    Ruler,
    Lbv2Easy,
    Lbv2Hard,
    Gsm,
    Aime,
}

pub const LONGBENCH_TASKS: [Task; 13] = [
    Task::Qasper,
    Task::MFen,
    Task::HotQA,
    Task::Wiki2,
    Task::Gov,
    Task::MNews,
    Task::Trec,
    Task::Tqa,
    Task::Sams,
    Task::PCount,
    Task::PRe,
    Task::Rbp,
    Task::Lcc,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Qasper => "qasper",
            Task::MFen => "mf-en",
            Task::HotQA => "hotqa",
            Task::Wiki2 => "2wiki",
            Task::Gov => "gov",
            Task::MNews => "m.news",
            Task::Trec => "trec",
            Task::Tqa => "tqa",
            Task::Sams => "sams",
            Task::PCount => "pcount",
            Task::PRe => "pre",
            Task::Rbp => "rb-p",
            Task::Lcc => "lcc",
            Task::Ruler => "ruler",
            Task::Lbv2Easy => "lbv2-easy",
            Task::Lbv2Hard => "lbv2-hard",
            Task::Gsm => "gsm8k",
            Task::Aime => "aime24",
        }
    }

    pub fn category(&self) -> Category {
        match self {
            Task::Qasper | Task::MFen => Category::SDocQA,
            Task::HotQA | Task::Wiki2 => Category::MDocQA,
            Task::Gov | Task::MNews => Category::Summ,
            Task::Trec | Task::Tqa | Task::Sams => Category::Icl,
            Task::PCount | Task::PRe => Category::Synthetic,
            Task::Rbp | Task::Lcc => Category::Code,
            Task::Ruler => Category::Ruler,
            Task::Lbv2Easy | Task::Lbv2Hard => Category::Reasoning,
            Task::Gsm | Task::Aime => Category::Math,
        }
    }

    /// Retrieval-intensive tasks need dense token interactions (paper
    /// section 2.3); holistic tasks survive aggressive sparsity.
    pub fn is_retrieval(&self) -> bool {
        !matches!(
            self,
            Task::Gov
                | Task::MNews
                | Task::Trec
                | Task::Tqa
                | Task::Sams
                | Task::Rbp
                | Task::Lcc
        )
    }

    fn tag(&self) -> u32 {
        let idx = match self {
            Task::Qasper => 0,
            Task::MFen => 1,
            Task::HotQA => 2,
            Task::Wiki2 => 3,
            Task::Gov => 4,
            Task::MNews => 5,
            Task::Trec => 6,
            Task::Tqa => 7,
            Task::Sams => 8,
            Task::PCount => 9,
            Task::PRe => 10,
            Task::Rbp => 11,
            Task::Lcc => 12,
            Task::Ruler => 13,
            Task::Lbv2Easy => 14,
            Task::Lbv2Hard => 15,
            Task::Gsm => 16,
            Task::Aime => 17,
        };
        TAG_BASE + idx
    }
}

/// One generated request: the prompt ends right after the ANSWER marker;
/// `answer` is the expected continuation (excluding EOS).
#[derive(Debug, Clone)]
pub struct Sample {
    pub task: Task,
    pub prompt: Vec<u32>,
    pub answer: Vec<u32>,
}

fn tok(i: i64) -> u32 {
    CONTENT + (i.rem_euclid(NCONTENT as i64)) as u32
}

fn filler(rng: &mut Rng, n: usize) -> Vec<u32> {
    (0..n).map(|_| rng.range_u32(CONTENT, VOCAB)).collect()
}

/// Spread token groups over `n` filler tokens at random non-overlapping
/// depths (mirrors data.py `_scatter`).
fn scatter(rng: &mut Rng, n: usize, items: &[Vec<u32>]) -> Vec<u32> {
    let mut out = filler(rng, n);
    let total: usize = items.iter().map(|i| i.len()).sum();
    assert!(total <= n, "scatter overflow: {total} > {n}");
    let free = n - total;
    // sample gap sizes ~ uniform multinomial
    let mut gaps = vec![0usize; items.len() + 1];
    for _ in 0..free {
        let g = rng.range(0, gaps.len());
        gaps[g] += 1;
    }
    let mut cursor = 0usize;
    for (gap, item) in gaps.iter().zip(items.iter()) {
        cursor += gap;
        out[cursor..cursor + item.len()].copy_from_slice(item);
        cursor += item.len();
    }
    out.truncate(n);
    out
}

/// Assemble `[BOS TAG ctx.. QUERY q.. ANSWER]` + expected answer.
fn assemble(task: Task, ctx: Vec<u32>, query: Vec<u32>, answer: Vec<u32>) -> Sample {
    let mut prompt = Vec::with_capacity(ctx.len() + query.len() + 4);
    prompt.push(BOS);
    prompt.push(task.tag());
    prompt.extend_from_slice(&ctx);
    prompt.push(QUERY);
    prompt.extend_from_slice(&query);
    prompt.push(ANSWER);
    Sample { task, prompt, answer }
}

/// Context budget for a target *prompt* length (the python generators
/// size full sequences; here the answer+EOS live on the generation side).
fn ctx_len(seq_len: usize, qlen: usize) -> usize {
    // prompt = BOS + TAG + ctx + QUERY + q + ANSWER  ->  ctx = len - 4 - qlen
    seq_len.saturating_sub(4 + qlen).max(8)
}

pub fn generate(task: Task, rng: &mut Rng, seq_len: usize) -> Sample {
    match task {
        Task::Qasper => gen_qasper(rng, seq_len),
        Task::MFen => gen_mfen(rng, seq_len),
        Task::HotQA => gen_hotqa(rng, seq_len),
        Task::Wiki2 => gen_wiki2(rng, seq_len),
        Task::Gov => gen_majority(Task::Gov, rng, seq_len, 3, &[0.6, 0.25, 0.15], 0),
        Task::MNews => gen_majority(Task::MNews, rng, seq_len, 4, &[0.55, 0.2, 0.15, 0.1], 2),
        Task::Trec => gen_icl(Task::Trec, rng, seq_len, 6),
        Task::Tqa => gen_icl(Task::Tqa, rng, seq_len, 10),
        Task::Sams => gen_majority(Task::Sams, rng, seq_len, 3, &[0.55, 0.25, 0.2], 3),
        Task::PCount => gen_pcount(rng, seq_len),
        Task::PRe | Task::Ruler => gen_pre(task, rng, seq_len),
        Task::Lbv2Easy => gen_chain(Task::Lbv2Easy, rng, seq_len, 2),
        Task::Lbv2Hard => gen_chain(Task::Lbv2Hard, rng, seq_len, 4),
        Task::Gsm => gen_arith(Task::Gsm, rng, seq_len, 6, false),
        Task::Aime => gen_arith(Task::Aime, rng, seq_len, 10, true),
        Task::Rbp => gen_rbp(rng, seq_len),
        Task::Lcc => gen_lcc(rng, seq_len),
    }
}

fn gen_qasper(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let nfacts = (n / 48).clamp(2, 16);
    let mut keys: Vec<u32> = (0..NCONTENT).collect();
    rng.shuffle(&mut keys);
    keys.truncate(nfacts);
    let vals: Vec<u32> = (0..nfacts).map(|_| rng.range_u32(0, NCONTENT) ).collect();
    let facts: Vec<Vec<u32>> = keys
        .iter()
        .zip(&vals)
        .map(|(&k, &v)| vec![SEP, CONTENT + k, CONTENT + v])
        .collect();
    let t = rng.gen_range(nfacts as usize);
    let ctx = scatter(rng, n, &facts);
    assemble(Task::Qasper, ctx, vec![CONTENT + keys[t]], vec![CONTENT + vals[t]])
}

fn gen_mfen(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 2);
    let nent = (n / 64).clamp(2, 10);
    let half = NCONTENT / 2;
    let mut ents: Vec<u32> = (0..half).collect();
    rng.shuffle(&mut ents);
    ents.truncate(nent);
    let f1: Vec<u32> = (0..nent).map(|_| rng.range_u32(0, NCONTENT) ).collect();
    let f2: Vec<u32> = (0..nent).map(|_| rng.range_u32(0, NCONTENT) ).collect();
    let field_tags = [half, half + 1];
    let mut facts = Vec::new();
    for i in 0..nent {
        facts.push(vec![SEP, CONTENT + ents[i], CONTENT + field_tags[0], CONTENT + f1[i]]);
        facts.push(vec![SEP, CONTENT + ents[i], CONTENT + field_tags[1], CONTENT + f2[i]]);
    }
    let t = rng.gen_range(nent as usize);
    let fs = rng.gen_range(2usize as usize);
    let val = if fs == 0 { f1[t] } else { f2[t] };
    let ctx = scatter(rng, n, &facts);
    assemble(
        Task::MFen,
        ctx,
        vec![CONTENT + ents[t], CONTENT + field_tags[fs]],
        vec![CONTENT + val],
    )
}

fn gen_hotqa(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let nchains = (n / 96).clamp(2, 8);
    let third = NCONTENT / 3;
    let mut a: Vec<u32> = (0..third).collect();
    rng.shuffle(&mut a);
    a.truncate(nchains);
    let mut b: Vec<u32> = (third..2 * third).collect();
    rng.shuffle(&mut b);
    b.truncate(nchains);
    let c: Vec<u32> = (0..nchains).map(|_| rng.range_u32(0, NCONTENT) ).collect();
    let mut hops = Vec::new();
    for i in 0..nchains {
        hops.push(vec![SEP, CONTENT + a[i], CONTENT + b[i]]);
        hops.push(vec![SEP, CONTENT + b[i], CONTENT + c[i]]);
    }
    let t = rng.gen_range(nchains as usize);
    let ctx = scatter(rng, n, &hops);
    assemble(Task::HotQA, ctx, vec![CONTENT + a[t]], vec![CONTENT + c[t]])
}

fn gen_wiki2(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let nchains = (n / 128).clamp(2, 6);
    let q = NCONTENT / 4;
    let mut pick = |lo: u32| {
        let mut v: Vec<u32> = (lo..lo + q).collect();
        rng.shuffle(&mut v);
        v.truncate(nchains);
        v
    };
    let a = pick(0);
    let b = pick(q);
    let c = pick(2 * q);
    let d: Vec<u32> = (0..nchains).map(|_| rng.range_u32(0, NCONTENT) ).collect();
    let mut hops = Vec::new();
    for i in 0..nchains {
        hops.push(vec![SEP, CONTENT + a[i], CONTENT + b[i]]);
        hops.push(vec![SEP, CONTENT + b[i], CONTENT + c[i]]);
        hops.push(vec![SEP, CONTENT + c[i], CONTENT + d[i]]);
    }
    let t = rng.gen_range(nchains as usize);
    let ctx = scatter(rng, n, &hops);
    assemble(Task::Wiki2, ctx, vec![CONTENT + a[t]], vec![CONTENT + d[t]])
}

/// Majority-marker family (gov / m.news / sams): answer = most frequent
/// marker; markers are spread uniformly so a local window sees enough.
fn gen_majority(task: Task, rng: &mut Rng, seq_len: usize, k: usize, probs: &[f64], extra: usize) -> Sample {
    let qlen = match task {
        Task::Gov => 1,
        Task::MNews => 2,
        _ => 2,
    };
    let n = ctx_len(seq_len, qlen);
    let mut topics: Vec<u32> = (0..NCONTENT).collect();
    rng.shuffle(&mut topics);
    topics.truncate(k);
    let per = 2 + extra;
    let nmark = (n / (per * 8)).max(6);
    let mut counts = vec![0usize; k];
    let mut marks = Vec::new();
    for _ in 0..nmark {
        let pick = rng.categorical(probs).min(k - 1);
        counts[pick] += 1;
        let mut m = vec![SEP, CONTENT + topics[pick]];
        m.extend(filler(rng, extra));
        marks.push(m);
    }
    let maj = topics[counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i)
        .unwrap()];
    let ctx = scatter(rng, n, &marks);
    let query = match task {
        Task::Gov => vec![SEP],
        Task::MNews => vec![SEP, SEP],
        _ => vec![SEP, QUERY],
    };
    assemble(task, ctx, query, vec![CONTENT + maj])
}

/// In-context-learning family: repeated (pattern -> label) pairs; the
/// queried pattern recurs densely, so a recent example is in-window.
fn gen_icl(task: Task, rng: &mut Rng, seq_len: usize, npat: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let half = NCONTENT / 2;
    let mut pats: Vec<u32> = (0..half).collect();
    rng.shuffle(&mut pats);
    pats.truncate(npat);
    let mut labels: Vec<u32> = (half..NCONTENT).collect();
    rng.shuffle(&mut labels);
    labels.truncate(npat);
    let t = rng.gen_range(npat as usize);
    let mut ctx = Vec::with_capacity(n);
    while ctx.len() + 3 <= n {
        let i = if rng.f64() > 0.3 { rng.gen_range(npat as usize) } else { t };
        ctx.extend_from_slice(&[SEP, CONTENT + pats[i], CONTENT + labels[i]]);
    }
    ctx.extend(filler(rng, n - ctx.len()));
    assemble(task, ctx, vec![CONTENT + pats[t]], vec![CONTENT + labels[t]])
}

fn gen_pcount(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let marker = CONTENT + rng.range_u32(0, NCONTENT) ;
    let count = rng.range(1, 24);
    let items: Vec<Vec<u32>> = (0..count).map(|_| vec![marker]).collect();
    let ctx = scatter(rng, n, &items);
    assemble(Task::PCount, ctx, vec![marker], vec![tok(count as i64)])
}

fn gen_pre(task: Task, rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let key = CONTENT + rng.range_u32(0, NCONTENT) ;
    let val = CONTENT + rng.range_u32(0, NCONTENT) ;
    let mut ctx = filler(rng, n);
    let pos = rng.range(0, n.saturating_sub(3).max(1));
    ctx[pos] = SEP;
    ctx[pos + 1] = key;
    ctx[pos + 2] = val;
    assemble(task, ctx, vec![key], vec![val])
}

fn gen_chain(task: Task, rng: &mut Rng, seq_len: usize, hops: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let nchains = 4usize;
    let per = NCONTENT / (hops as u32 + 1);
    let mut heads: Vec<u32> = (0..per).collect();
    rng.shuffle(&mut heads);
    heads.truncate(nchains);
    let mut triples = Vec::new();
    let mut finals = Vec::new();
    for &h in &heads {
        let mut cur = h;
        for hp in 0..hops {
            let nxt = rng.range_u32(0, per) + (hp as u32 + 1) * per;
            triples.push(vec![SEP, CONTENT + cur, CONTENT + nxt]);
            cur = nxt;
        }
        finals.push(cur);
    }
    let t = rng.gen_range(nchains as usize);
    let ctx = scatter(rng, n, &triples);
    assemble(task, ctx, vec![CONTENT + heads[t]], vec![CONTENT + finals[t]])
}

fn gen_arith(task: Task, rng: &mut Rng, seq_len: usize, ops: usize, mul: bool) -> Sample {
    let n = ctx_len(seq_len, 1);
    let modn: i64 = 97;
    let mut val = rng.gen_range(modn as usize) as i64;
    let mut flat = vec![SEP, QUERY, tok(val)];
    let add_tag = tok(NCONTENT as i64 - 1);
    let mul_tag = tok(NCONTENT as i64 - 2);
    for _ in 0..ops {
        let x = rng.range(1, 10) as i64;
        if mul && rng.f64() < 0.3 {
            val = (val * x) % modn;
            flat.extend_from_slice(&[SEP, mul_tag, tok(x)]);
        } else {
            val = (val + x) % modn;
            flat.extend_from_slice(&[SEP, add_tag, tok(x)]);
        }
    }
    let mut ctx = flat;
    if ctx.len() < n {
        let extra = filler(rng, n - ctx.len());
        ctx.extend(extra);
    }
    ctx.truncate(n);
    assemble(task, ctx, vec![SEP], vec![tok(val)])
}

fn gen_rbp(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let step = rng.range(1, 7) as i64;
    let start = rng.range_u32(0, NCONTENT)  as i64;
    let width = 4usize;
    let nlines = n / (width + 1);
    let mut ctx = Vec::with_capacity(n);
    for i in 0..nlines {
        ctx.push(SEP);
        ctx.push(tok(start + i as i64 * step));
        ctx.extend(filler(rng, width - 1));
    }
    while ctx.len() < n {
        ctx.push(SEP);
    }
    ctx.truncate(n);
    let next = tok(start + nlines as i64 * step);
    assemble(Task::Rbp, ctx, vec![SEP], vec![next])
}

fn gen_lcc(rng: &mut Rng, seq_len: usize) -> Sample {
    let n = ctx_len(seq_len, 1);
    let period = rng.range(3, 8);
    let motif: Vec<u32> = (0..period).map(|_| CONTENT + rng.range_u32(0, NCONTENT) ).collect();
    let ctx: Vec<u32> = (0..n).map(|i| motif[i % period]).collect();
    let next = motif[n % period];
    assemble(Task::Lcc, ctx, vec![SEP], vec![next])
}

// ---------------------------------------------------------------------------
// request arrival traces (serving benchmarks)
// ---------------------------------------------------------------------------

/// A serving trace: request index, arrival time offset, and sample.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    pub arrival_ms: u64,
    pub sample: Sample,
}

/// Poisson arrivals over a task mixture — the workload for the
/// end-to-end serving benchmarks (Fig 3a uses the batch variant).
pub fn poisson_trace(
    seed: u64,
    tasks: &[Task],
    n_requests: usize,
    seq_len: usize,
    rate_per_s: f64,
) -> Vec<TraceEntry> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t_ms = 0f64;
    (0..n_requests)
        .map(|i| {
            let dt = -(1.0 - rng.f64()).ln() / rate_per_s * 1000.0;
            t_ms += dt;
            let task = tasks[i % tasks.len()];
            TraceEntry {
                arrival_ms: t_ms as u64,
                sample: generate(task, &mut rng, seq_len),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(42)
    }

    #[test]
    fn all_tasks_generate_within_length() {
        let all = [
            Task::Qasper, Task::MFen, Task::HotQA, Task::Wiki2, Task::Gov,
            Task::MNews, Task::Trec, Task::Tqa, Task::Sams, Task::PCount,
            Task::PRe, Task::Rbp, Task::Lcc, Task::Ruler, Task::Lbv2Easy,
            Task::Lbv2Hard, Task::Gsm, Task::Aime,
        ];
        let mut r = rng();
        for task in all {
            for len in [128usize, 256, 512, 1024] {
                let s = generate(task, &mut r, len);
                assert!(s.prompt.len() <= len, "{task:?} at {len}: {}", s.prompt.len());
                assert!(s.prompt.len() >= len / 2, "{task:?} too short at {len}");
                assert_eq!(s.prompt[0], BOS);
                assert_eq!(*s.prompt.last().unwrap(), ANSWER);
                assert!(!s.answer.is_empty());
                assert!(s.answer.iter().all(|&a| a >= CONTENT && a < VOCAB));
            }
        }
    }

    #[test]
    fn qasper_answer_is_retrievable() {
        let mut r = rng();
        for _ in 0..20 {
            let s = generate(Task::Qasper, &mut r, 256);
            let qpos = s.prompt.iter().rposition(|&t| t == QUERY).unwrap();
            let key = s.prompt[qpos + 1];
            let found = (0..qpos).any(|i| {
                s.prompt[i] == SEP
                    && s.prompt.get(i + 1) == Some(&key)
                    && s.prompt.get(i + 2) == Some(&s.answer[0])
            });
            assert!(found, "fact not found in context");
        }
    }

    #[test]
    fn pre_needle_depth_is_uniform() {
        let mut r = rng();
        let mut depths = vec![];
        for _ in 0..50 {
            let s = generate(Task::PRe, &mut r, 512);
            let qpos = s.prompt.iter().rposition(|&t| t == QUERY).unwrap();
            let key = s.prompt[qpos + 1];
            depths.push(s.prompt.iter().position(|&t| t == key).unwrap() as f64);
        }
        let mean = depths.iter().sum::<f64>() / depths.len() as f64;
        let var = depths.iter().map(|d| (d - mean).powi(2)).sum::<f64>() / depths.len() as f64;
        assert!(var.sqrt() > 50.0, "needle depths not spread: sd={}", var.sqrt());
    }

    #[test]
    fn trec_example_in_local_window() {
        let mut r = rng();
        let mut hits = 0;
        for _ in 0..20 {
            let s = generate(Task::Trec, &mut r, 512);
            let qpos = s.prompt.iter().rposition(|&t| t == QUERY).unwrap();
            let pat = s.prompt[qpos + 1];
            let lo = qpos.saturating_sub(128);
            if s.prompt[lo..qpos].contains(&pat) {
                hits += 1;
            }
        }
        assert!(hits >= 16, "only {hits}/20 queries had in-window examples");
    }

    #[test]
    fn poisson_trace_is_monotone() {
        let tr = poisson_trace(7, &[Task::PRe, Task::Gov], 32, 256, 10.0);
        assert_eq!(tr.len(), 32);
        for w in tr.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
    }

    #[test]
    fn category_split_matches_design() {
        assert!(Task::PRe.is_retrieval());
        assert!(Task::HotQA.is_retrieval());
        assert!(!Task::Gov.is_retrieval());
        assert!(!Task::Lcc.is_retrieval());
        assert_eq!(LONGBENCH_TASKS.len(), 13);
    }
}
