//! Property-based tests on coordinator-side invariants (KV-cache
//! accounting, ring-buffer semantics, routing policy algebra, tokenizer
//! round-trips, workload layout, simulator monotonicity, eigensolver
//! conservation laws) plus end-to-end engine properties over synthetic
//! `RefBackend` artifacts (teacher-forcing parity as a property).
//!
//! Uses the in-crate property runner (`util::prop`): seeded random
//! cases; failures report the replayable seed.

use std::path::PathBuf;

use flux_attention::baselines::{entropy_ranked_modes, jacobi_eigenvalues};
use flux_attention::config::MetaConfig;
use flux_attention::engine::Engine;
use flux_attention::gpu_sim::{decode_latency_s, GpuSimConfig, SimPolicy};
use flux_attention::kvcache::{FullCache, KvPool, SparseCache};
use flux_attention::router::{pool_descriptor, AttnMode, DecodeMode, Policy};
use flux_attention::runtime::{synthetic, Arg, Backend, HostTensor, RefBackend};
use flux_attention::tokenizer::Tokenizer;
use flux_attention::util::prop::check;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};
use flux_attention::{prop_assert, prop_assert_eq};

#[test]
fn full_cache_accounting() {
    check("full_cache_accounting", 64, |rng| {
        let n = rng.range(1, 300);
        let cap = rng.range(1, 64);
        let mut pool = KvPool::new(64, 1 << 20);
        let mut c = FullCache::new(&mut pool, 2, 4, cap).map_err(|e| e.to_string())?;
        for i in 0..n {
            let k = vec![i as f32; 8];
            c.append(&mut pool, &k, &k).map_err(|e| e.to_string())?;
        }
        prop_assert_eq!(c.len(), n);
        prop_assert!(c.capacity() >= n);
        let bucket = c.len().next_power_of_two();
        let (kt, _) = c.as_tensors(&pool, bucket);
        for i in 0..n {
            prop_assert_eq!(kt.data[i * 4], i as f32);
        }
        Ok(())
    });
}

#[test]
fn sparse_cache_window_invariant() {
    check("sparse_cache_window_invariant", 64, |rng| {
        let n = rng.range(1, 400);
        let sink = rng.range(1, 8);
        let local = rng.range(1, 16);
        let buf = sink + local + 1;
        let mut pool = KvPool::new(8, 1 << 20);
        let mut c = SparseCache::new(&mut pool, 1, 1, sink, local, buf).map_err(|e| e.to_string())?;
        for i in 0..n {
            c.append(&mut pool, &[i as f32], &[i as f32]);
        }
        prop_assert!(c.len() <= sink + local);
        prop_assert_eq!(c.total_seen(), n);
        let (kt, _, valid) = c.as_tensors(&pool);
        let n_sink = n.min(sink);
        for t in 0..n_sink {
            prop_assert_eq!(kt.data[t], t as f32);
        }
        let n_win = (n - n_sink).min(local);
        prop_assert_eq!(valid, n_sink + n_win);
        // the window is a ring in executable layout: the surviving token
        // t sits at slot n_sink + (t - n_sink) % local, and only the
        // last n_win tokens survive
        for t in (n - n_win)..n {
            let slot = n_sink + (t - n_sink) % local;
            prop_assert_eq!(kt.data[slot], t as f32);
        }
        Ok(())
    });
}

#[test]
fn sparse_prefill_equals_appends() {
    check("sparse_prefill_equals_appends", 64, |rng| {
        let valid = rng.range(1, 64);
        let (sink, local, buf) = (4usize, 8usize, 16usize);
        let mk = |t: usize| vec![t as f32];
        let mut pool = KvPool::new(8, 1 << 20);
        let mut by_append =
            SparseCache::new(&mut pool, 1, 1, sink, local, buf).map_err(|e| e.to_string())?;
        for t in 0..valid {
            by_append.append(&mut pool, &mk(t), &mk(t));
        }
        let data: Vec<f32> = (0..64).map(|t| t as f32).collect();
        let kt = HostTensor::new(vec![1, 64, 1], data);
        let mut by_prefill =
            SparseCache::new(&mut pool, 1, 1, sink, local, buf).map_err(|e| e.to_string())?;
        by_prefill.load_prefill(&mut pool, &kt, &kt.clone(), valid);
        let (a, _, va) = by_append.as_tensors(&pool);
        let (p, _, vp) = by_prefill.as_tensors(&pool);
        prop_assert_eq!(va, vp);
        prop_assert_eq!(&a.data[..va], &p.data[..vp]);
        Ok(())
    });
}

#[test]
fn pooling_bounds() {
    check("pooling_bounds", 64, |rng| {
        let s = rng.range(1, 256);
        let d = rng.range(1, 16);
        let pool = rng.range(1, 32);
        let data: Vec<f32> = (0..s * d).map(|i| (i % 7) as f32 - 3.0).collect();
        let h = HostTensor::new(vec![s, d], data.clone());
        let desc = pool_descriptor(&h, s, pool);
        prop_assert_eq!(desc.shape, vec![2 * d]);
        let lo = data.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for &x in &desc.data {
            prop_assert!(x >= lo - 1e-5 && x <= hi + 1e-5, "desc value {x} out of [{lo},{hi}]");
        }
        Ok(())
    });
}

#[test]
fn entropy_ranking_budget() {
    check("entropy_ranking_budget", 64, |rng| {
        let l = rng.range(2, 32);
        let omega = rng.f64();
        let scores: Vec<f64> = (0..l).map(|i| (i * 37 % 11) as f64).collect();
        let modes = entropy_ranked_modes(&scores, omega, AttnMode::Ssa);
        let n_fa = modes.iter().filter(|m| **m == AttnMode::Fa).count();
        prop_assert_eq!(n_fa, ((1.0 - omega) * l as f64).floor() as usize);
        Ok(())
    });
}

#[test]
fn tokenizer_roundtrip() {
    check("tokenizer_roundtrip", 64, |rng| {
        let t = Tokenizer::new();
        let n = rng.range(0, 64);
        let ids: Vec<u32> = (0..n).map(|_| rng.range_u32(0, 512)).collect();
        let text = t.decode(&ids);
        prop_assert_eq!(t.encode(&text), ids);
        Ok(())
    });
}

#[test]
fn workload_layout() {
    check("workload_layout", 48, |rng| {
        let len = rng.range(64, 1024);
        for task in [Task::Qasper, Task::PRe, Task::Gov, Task::Trec, Task::Gsm] {
            let s = generate(task, rng, len);
            prop_assert!(s.prompt.len() <= len, "{:?} too long", task);
            prop_assert_eq!(*s.prompt.last().unwrap(), 5u32); // ANSWER
            prop_assert!(!s.answer.is_empty());
        }
        Ok(())
    });
}

#[test]
fn gpu_sim_monotonicity() {
    check("gpu_sim_monotonicity", 64, |rng| {
        let cfg = GpuSimConfig::default();
        let c1 = rng.range(1024, 100_000);
        let c2 = c1 * rng.range(2, 8);
        for p in [
            SimPolicy::Dense,
            SimPolicy::HeadLevel { sparse_frac: 0.5, window: 2048 },
            SimPolicy::LayerLevel { sparse_frac: 0.5, window: 2048 },
        ] {
            prop_assert!(
                decode_latency_s(&cfg, &p, c2) >= decode_latency_s(&cfg, &p, c1),
                "latency not monotone for {p:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn jacobi_trace_preserved() {
    check("jacobi_trace_preserved", 64, |rng| {
        // symmetric PSD A = B B^T for random 3x3 B
        let d = 3;
        let vals: Vec<f64> = (0..9).map(|_| rng.f64() * 4.0 - 2.0).collect();
        let mut a = vec![0.0; 9];
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += vals[i * d + k] * vals[j * d + k];
                }
                a[i * d + j] = s;
            }
        }
        let trace: f64 = (0..d).map(|i| a[i * d + i]).sum();
        let ev = jacobi_eigenvalues(&a, d, 16);
        let sum: f64 = ev.iter().sum();
        prop_assert!(
            (sum - trace).abs() < 1e-8 * (1.0 + trace.abs()),
            "trace {trace} vs eigensum {sum}"
        );
        for &e in &ev {
            prop_assert!(e > -1e-9, "negative eigenvalue {e} from PSD matrix");
        }
        Ok(())
    });
}

/// Teacher-forcing parity as a *property*, not one seed: for random
/// tasks and prompt lengths, every token the dense decode path emits
/// must equal the first token of a naive full-prefill recompute over
/// the extended context. This pins the RefBackend decode attention
/// (cache append + `decode_attend_fa_*`) to the prefill rows exactly —
/// where routed serving paths silently diverge first.
#[test]
fn dense_decode_matches_full_prefill_recompute_property() {
    let dir = synthetic::ensure_default().expect("synthetic artifacts");
    let mut engine = Engine::load(&dir).unwrap();
    let tasks = [Task::PRe, Task::Qasper, Task::Gov, Task::Trec];
    check("dense_decode_equals_prefill_recompute", 6, |rng| {
        let len = rng.range(24, 96);
        let task = tasks[rng.gen_range(tasks.len())];
        let s = generate(task, rng, len);

        let (id, report) = engine
            .prefill(&s.prompt, &Policy::Backbone, "balanced")
            .map_err(|e| e.to_string())?;
        let mut toks = vec![report.first_token];
        let n_steps = 3;
        for _ in 0..n_steps {
            toks.push(engine.decode_step(id).map_err(|e| e.to_string())?);
        }
        engine.release(id);

        let mut ctx = s.prompt.clone();
        for m in 1..=n_steps {
            ctx.push(toks[m - 1]);
            let (id2, r2) = engine
                .prefill(&ctx, &Policy::Backbone, "balanced")
                .map_err(|e| e.to_string())?;
            engine.release(id2);
            prop_assert_eq!(r2.first_token, toks[m]);
        }
        Ok(())
    });
}

/// Multi-threaded kernels must be bit-identical to `FLUX_THREADS=1`:
/// both at the kernel level (full prefill-layer output tensors over a
/// bucket big enough to engage the parallel paths) and end-to-end
/// (routed generation through two engines pinned to 1 vs N workers).
#[test]
fn multithreaded_kernels_bit_identical_to_serial() {
    let cfg = MetaConfig::from_json_str(synthetic::DEFAULT_META, PathBuf::from("/tmp")).unwrap();
    let m = cfg.model.clone();
    let s = 512usize;
    let mk = |shape: Vec<usize>, seed: u64| {
        let mut rng = Rng::seed_from_u64(seed);
        let n: usize = shape.iter().product();
        HostTensor::new(shape, (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect())
    };
    let x = mk(vec![s, m.d_model], 1);
    let n1 = HostTensor::new(vec![m.d_model], vec![1.0; m.d_model]);
    let wq = mk(vec![m.d_model, m.d_model], 2);
    let wk = mk(vec![m.d_model, m.d_model], 3);
    let wv = mk(vec![m.d_model, m.d_model], 4);
    let wo = mk(vec![m.d_model, m.d_model], 5);
    let f1 = mk(vec![m.d_model, m.d_ff], 6);
    let f2 = mk(vec![m.d_ff, m.d_model], 7);
    let valid_arr = [490i32];
    for mode in ["fa", "ssa", "ta", "xa"] {
        let exe = format!("layer_{mode}_prefill_{s}");
        let mut serial: Option<Vec<HostTensor>> = None;
        for threads in [1usize, 4, 7] {
            let mut b = RefBackend::with_threads(cfg.clone(), threads);
            b.load(&exe).unwrap();
            let out = b
                .run(
                    &exe,
                    &[
                        Arg::F32(&x), Arg::F32(&n1), Arg::F32(&wq), Arg::F32(&wk),
                        Arg::F32(&wv), Arg::F32(&wo), Arg::F32(&n1), Arg::F32(&f1),
                        Arg::F32(&f2), Arg::I32(&valid_arr),
                    ],
                )
                .unwrap();
            match &serial {
                None => serial = Some(out),
                Some(base) => assert_eq!(
                    base, &out,
                    "{exe} with {threads} workers diverged from the serial path"
                ),
            }
        }
    }

    // end-to-end: same prompts, 1 vs 4 workers, identical generations
    let dir = synthetic::ensure_default().unwrap();
    let mut e1 = Engine::load(&dir).unwrap();
    e1.set_threads(1);
    let mut e4 = Engine::load(&dir).unwrap();
    e4.set_threads(4);
    let mut rng = Rng::seed_from_u64(13);
    for task in [Task::PRe, Task::Gov] {
        let sample = generate(task, &mut rng, 300);
        let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
        let (g1, r1) = e1.generate(&sample.prompt, &policy, "balanced", 6).unwrap();
        let (g4, r4) = e4.generate(&sample.prompt, &policy, "balanced", 6).unwrap();
        assert_eq!(g1, g4, "multi-threaded generation diverged");
        assert_eq!(r1.modes, r4.modes, "multi-threaded routing diverged");
    }
}

/// Zero-copy property: staging the KV cache as borrowed views must
/// produce byte-identical decode logits to the cloning path, across
/// random cache lengths spanning capacity-growth and bucket-boundary
/// edges.
#[test]
fn zero_copy_views_match_clone_path_logits() {
    let cfg = MetaConfig::from_json_str(synthetic::DEFAULT_META, PathBuf::from("/tmp")).unwrap();
    let m = cfg.model.clone();
    let (d, h, dd, ff) = (m.d_model, m.n_heads, m.head_dim, m.d_ff);
    check("zero_copy_view_vs_clone", 24, |rng| {
        let threads = 1 + rng.gen_range(6);
        let mut b = RefBackend::with_threads(cfg.clone(), threads);
        // random length across the 128-capacity growth edge and the
        // 128/256 bucket boundary
        let len = rng.range(100, 280);
        let mut pool = KvPool::new(32 * h * dd, 1 << 16);
        let mut cache = FullCache::new(&mut pool, h, dd, 128).map_err(|e| e.to_string())?;
        for t in 0..len {
            let kv: Vec<f32> = (0..h * dd).map(|i| ((t * 31 + i) % 17) as f32 * 0.1 - 0.8).collect();
            cache.append(&mut pool, &kv, &kv).map_err(|e| e.to_string())?;
        }
        let bucket = cfg
            .decode_attend_bucket(cache.len(), cache.capacity())
            .ok_or("no decode bucket")?;
        prop_assert!(
            bucket == cache.capacity(),
            "growth must stay bucket-aligned (bucket {bucket}, capacity {})",
            cache.capacity()
        );
        let exe = format!("decode_attend_fa_{bucket}");
        b.load(&exe).map_err(|e| e.to_string())?;

        let mut mk = |shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            HostTensor::new(shape, (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect())
        };
        let x = mk(vec![d]);
        let q = mk(vec![h, dd]);
        let wo = mk(vec![d, d]);
        let f1 = mk(vec![d, ff]);
        let f2 = mk(vec![ff, d]);
        let n2 = HostTensor::new(vec![d], vec![1.0; d]);
        let valid_arr = [cache.len() as i32];

        let (kt, vt) = cache.as_tensors(&pool, bucket);
        let owned = b
            .run(
                &exe,
                &[
                    Arg::F32(&x), Arg::F32(&q), Arg::F32(&kt), Arg::F32(&vt),
                    Arg::I32(&valid_arr), Arg::F32(&wo), Arg::F32(&n2),
                    Arg::F32(&f1), Arg::F32(&f2),
                ],
            )
            .map_err(|e| e.to_string())?;
        let (kv, vv) = cache.view(&pool);
        let viewed = b
            .run(
                &exe,
                &[
                    Arg::F32(&x), Arg::F32(&q), Arg::F32View(kv), Arg::F32View(vv),
                    Arg::I32(&valid_arr), Arg::F32(&wo), Arg::F32(&n2),
                    Arg::F32(&f1), Arg::F32(&f2),
                ],
            )
            .map_err(|e| e.to_string())?;
        prop_assert_eq!(&owned, &viewed);
        Ok(())
    });
}

/// DESIGN.md §15 route-aware footprint math as a property over the
/// REAL engine: once the route is pinned, the promotion-time ledger
/// charge (`PoolProfile::routed_pages`) must equal the number of pool
/// pages the request actually peaks at through a full decode — across
/// the 128 -> 256 bucket-growth edge, the exact capacity boundary
/// (`prompt + max_new - 1` hitting a bucket), and the sparse-ring
/// wrap. The prefix cache is disabled so `pages_allocated` is
/// attributable to the single live request, and the worst-case bound
/// must dominate the peak everywhere.
#[test]
fn promotion_charge_equals_actual_decode_page_peak() {
    let dir = synthetic::ensure_default().expect("synthetic artifacts");
    // 32-token pages, room for any single request in the sweep
    let mut engine = Engine::load_with_pool(&dir, Some((32, 32 * 2048))).unwrap();
    engine.set_prefix_cache(false, None);
    let pp = engine.pool_profile();

    let mut run = |plen: usize, max_new: usize, policy: &Policy| -> Result<(), String> {
        let prompt: Vec<u32> = (0..plen).map(|i| 7 + (i % 400) as u32).collect();
        let (id, report) =
            engine.prefill(&prompt, policy, "balanced").map_err(|e| e.to_string())?;
        let mut peak = engine.pool().pages_allocated();
        for _ in 0..max_new.saturating_sub(1) {
            engine.decode_step(id).map_err(|e| e.to_string())?;
            peak = peak.max(engine.pool().pages_allocated());
        }
        engine.release(id);
        let charge = pp.routed_pages(plen, max_new, &report.modes, policy.decode_mode());
        let worst = pp.worst_case_pages(plen, max_new);
        if charge != peak {
            return Err(format!(
                "routed charge {charge} != actual page peak {peak} \
                 (prompt {plen}, max_new {max_new}, route {:?}, {policy:?})",
                report.modes
            ));
        }
        if worst < peak {
            return Err(format!(
                "worst case {worst} under actual peak {peak} (prompt {plen}, max_new {max_new})"
            ));
        }
        Ok(())
    };

    // deterministic knife edges first: exact bucket fits, the one-token
    // overflow into the next bucket, growth mid-decode, and ring wrap
    let sparse_mix = Policy::Static {
        modes: vec![AttnMode::Fa, AttnMode::Ssa, AttnMode::Fa, AttnMode::Ssa],
        decode: DecodeMode::Sparse,
    };
    for (plen, max_new) in
        [(128, 1), (129, 1), (100, 29), (100, 30), (100, 100), (64, 65), (64, 66)]
    {
        run(plen, max_new, &Policy::Backbone).unwrap();
        run(plen, max_new, &sparse_mix).unwrap();
    }

    // random sweep over lengths and routed layouts
    check("promotion_charge_equals_peak", 16, |rng| {
        let plen = 100 + rng.gen_range(60);
        let max_new = 1 + rng.gen_range(60);
        let pick = rng.gen_range(4);
        let modes: Vec<AttnMode> = (0..4)
            .map(|_| if rng.gen_range(2) == 0 { AttnMode::Fa } else { AttnMode::Ssa })
            .collect();
        let policy = match pick {
            0 => Policy::Backbone,
            1 => Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse },
            2 => Policy::Static { modes, decode: DecodeMode::Sparse },
            _ => Policy::Static { modes, decode: DecodeMode::Dense },
        };
        run(plen, max_new, &policy)
    });
}

/// `WorstCase` admission is the identity on the worst-case bound — it
/// reproduces pre-§15 admission decisions exactly — and `Optimistic`
/// charges are clamped to `[1, worst]`, monotone in the factor, with
/// exact endpoints at 0.0 and 1.0 (out-of-range factors clamp).
#[test]
fn admission_mode_charge_bounds() {
    use flux_attention::config::AdmissionMode;
    check("admission_mode_charge_bounds", 64, |rng| {
        let worst = 1 + rng.gen_range(9999);
        prop_assert_eq!(AdmissionMode::WorstCase.admission_pages(worst), worst);
        let f = rng.f64() * 2.0 - 0.5;
        let charge = AdmissionMode::Optimistic { factor: f }.admission_pages(worst);
        prop_assert!(charge >= 1 && charge <= worst, "charge {charge} outside [1, {worst}]");
        let c2 = AdmissionMode::Optimistic { factor: f + 0.3 }.admission_pages(worst);
        prop_assert!(c2 >= charge, "optimistic charge not monotone in factor");
        prop_assert_eq!(AdmissionMode::Optimistic { factor: 0.0 }.admission_pages(worst), 1);
        prop_assert_eq!(
            AdmissionMode::Optimistic { factor: 1.0 }.admission_pages(worst),
            worst
        );
        Ok(())
    });
}

#[test]
fn json_roundtrip_numbers_and_strings() {
    use flux_attention::util::json::Json;
    check("json_roundtrip", 64, |rng| {
        let mut o = Json::obj();
        let n = rng.range(1, 12);
        for i in 0..n {
            match rng.gen_range(3) {
                0 => {
                    o.set(&format!("k{i}"), Json::from(rng.gen_range(100000)));
                }
                1 => {
                    o.set(&format!("k{i}"), Json::from(rng.f64()));
                }
                _ => {
                    o.set(&format!("k{i}"), Json::from(format!("v\"{}\\n", rng.gen_range(99))));
                }
            }
        }
        let text = o.to_string();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert_eq!(back.to_string(), text);
        Ok(())
    });
}
