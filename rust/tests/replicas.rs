//! Replica-set dispatch invariants (DESIGN.md §14): load-aware
//! dispatch is deterministic, session affinity routes warm prefixes to
//! the replica that owns their cached pages, queue watermarks reject
//! typed-and-retryable under saturation and recover on drain-down, a
//! killed replica's queued work completes on survivors bit-identical to
//! a no-fault run, and `drain_replica` rolls one replica without
//! interrupting streams on its peers.
//!
//! Determinism in these tests leans on two properties pinned elsewhere:
//! greedy decode is bit-exact regardless of batching, and dispatch
//! breaks committed-token ties toward the lowest replica index.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use flux_attention::config::ServingConfig;
use flux_attention::coordinator::{
    Coordinator, Request, RequestError, Response, SessionEvent, SessionHandle,
};
use flux_attention::engine::EngineHandle;
use flux_attention::runtime::chaos::{FaultKind, FaultPlan};
use flux_attention::runtime::synthetic;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

fn start_set(n: usize, cfg: ServingConfig) -> (Arc<Coordinator>, Vec<EngineHandle>) {
    let engines: Vec<EngineHandle> =
        (0..n).map(|i| EngineHandle::spawn_replica(artifacts(), i).unwrap()).collect();
    let coord = Coordinator::start_replicas(engines.clone(), cfg).unwrap();
    (coord, engines)
}

/// Drain one session to its single terminal event.
fn finish(h: &SessionHandle) -> Result<Response, RequestError> {
    let mut done = None;
    let mut error = None;
    let mut terminals = 0;
    while let Some(ev) = h.recv_timeout(TIMEOUT) {
        match ev {
            SessionEvent::Done { stats } => {
                terminals += 1;
                done = Some(stats);
            }
            SessionEvent::Error { error: e } => {
                terminals += 1;
                error = Some(e);
            }
            _ => {}
        }
    }
    assert_eq!(terminals, 1, "every session must see exactly one terminal event");
    match (done, error) {
        (Some(d), None) => Ok(d),
        (None, Some(e)) => Err(e),
        other => panic!("inconsistent terminal state {other:?}"),
    }
}

/// Committed-token gauges return to zero once every stream retires —
/// the `LoadGuard` accounting leaks nothing. Retirement sends the
/// terminal event before the guard drops, so poll briefly.
fn assert_loads_drain(coord: &Coordinator) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let loads = coord.replica_loads();
        if loads.iter().all(|&l| l == 0) {
            return;
        }
        assert!(Instant::now() < deadline, "committed-token load leaked: {loads:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Least-loaded dispatch is a pure function of the committed-token
/// gauges: with a seeded arrival order submitted faster than anything
/// can retire, the replica assignment matches a greedy simulation of
/// `argmin(committed, tie → lowest index)` — and an identical re-run
/// reproduces it exactly.
#[test]
fn least_loaded_dispatch_matches_greedy_simulation_deterministically() {
    let mut rng = Rng::seed_from_u64(91);
    let reqs: Vec<Request> = [96usize, 64, 80, 72, 88, 68]
        .iter()
        .map(|&len| Request {
            prompt: generate(Task::PRe, &mut rng, len).prompt,
            max_new: 16,
            ignore_eos: true,
            ..Default::default()
        })
        .collect();

    // greedy reference simulation over the ACTUAL prompt lengths
    let mut loads = [0usize; 2];
    let expected: Vec<usize> = reqs
        .iter()
        .map(|r| {
            let pick = if loads[1] < loads[0] { 1 } else { 0 };
            loads[pick] += r.prompt.len() + r.max_new;
            pick
        })
        .collect();
    assert!(expected.contains(&1), "the sweep must exercise both replicas");

    let mut runs = Vec::new();
    for _ in 0..2 {
        let (coord, _engines) = start_set(2, ServingConfig::default());
        // open everything back-to-back: dispatch happens at admission,
        // and the first retirement is many decode rounds away
        let handles: Vec<SessionHandle> =
            reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();
        let assigned: Vec<usize> = handles
            .iter()
            .map(|h| {
                let done = finish(h).expect("fault-free streams must complete");
                assert_eq!(done.tokens.len(), 16);
                done.replica
            })
            .collect();
        assert_eq!(assigned, expected, "dispatch diverged from the greedy simulation");
        assert_loads_drain(&coord);
        runs.push(assigned);
    }
    assert_eq!(runs[0], runs[1], "seeded arrivals must dispatch identically across runs");
}

/// Session affinity: once a prompt's prefix pages are warm on one
/// replica, re-submissions route back to that OWNER even when the
/// committed-token tie-break would pick a different replica — that is
/// the whole point of affinity (a warm hit beats an idle peer).
#[test]
fn session_affinity_routes_warm_prefixes_to_the_owning_replica() {
    let mut rng = Rng::seed_from_u64(92);
    let filler_prompt = generate(Task::Gov, &mut rng, 128).prompt;
    let prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let (coord, _engines) = start_set(
        2,
        ServingConfig { prefix_cache: true, ..Default::default() },
    );

    // pin replica 0 under a long filler stream so the probe prompt's
    // first dispatch goes least-loaded to replica 1
    let filler = coord
        .open(Request {
            prompt: filler_prompt,
            max_new: 64,
            ignore_eos: true,
            ..Default::default()
        })
        .unwrap();
    let req = || Request {
        prompt: prompt.clone(),
        max_new: 8,
        ignore_eos: true,
        ..Default::default()
    };
    let cold = coord.submit(req()).unwrap();
    assert_eq!(cold.replica, 1, "least-loaded dispatch must avoid the busy replica");

    // by now replica 1 owns the prompt's prefix pages; the re-submission
    // must follow them there (and decode bit-identically off the cache)
    let warm = coord.submit(req()).unwrap();
    assert_eq!(warm.replica, 1, "affinity must route the warm hit to the owner");
    assert_eq!(warm.tokens, cold.tokens, "warm-hit stream diverged");

    let filler_done = finish(&filler).expect("the filler must stream to completion undisturbed");
    assert_eq!(filler_done.tokens.len(), 64);
    assert_eq!(filler_done.replica, 0);

    let m = coord.metrics.lock().unwrap();
    assert!(m.dispatch_affinity_hits >= 1, "no affinity routing recorded: {}", m.summary());
    assert!(m.prefix_hits >= 1, "the warm re-submission must hit the prefix cache");
    drop(m);
    assert_loads_drain(&coord);
}

/// Queue-depth watermarks (DESIGN.md §14): when the only replica's
/// queue reaches the high watermark, admission fails with the typed,
/// retryable `Overloaded("queue_watermark")`; once the backlog drains
/// below the low watermark the latch clears and admission resumes.
#[test]
fn queue_watermark_rejects_typed_and_recovers_below_low_watermark() {
    let mut rng = Rng::seed_from_u64(93);
    let prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let req = || Request { prompt: prompt.clone(), max_new: 12, ignore_eos: true, ..Default::default() };
    let (coord, _engines) = start_set(
        1,
        ServingConfig {
            // one active stream; everything behind it queues in-channel
            // (the scheduler only pops arrivals while it has a free slot)
            max_active_requests: 1,
            queue_high_watermark: Some(3),
            queue_low_watermark: Some(1),
            ..Default::default()
        },
    );

    // pin s0 mid-decode first — once it holds the only active slot the
    // scheduler pops nothing more, so queue depth is exactly the number
    // of backlogged opens (no race against admission)
    let s0 = coord.open(req()).unwrap();
    while let Some(ev) = s0.recv_timeout(TIMEOUT) {
        if matches!(ev, SessionEvent::Token { .. }) {
            break;
        }
    }
    let mut backlog: Vec<SessionHandle> = (0..3).map(|_| coord.open(req()).unwrap()).collect();
    backlog.insert(0, s0);
    assert_eq!(coord.queue_depth(), 3, "the backlog must sit in the admission queue");
    let err = coord.open(req()).expect_err("admission above the high watermark must fail");
    assert!(
        matches!(err, RequestError::Overloaded { .. }),
        "expected a typed Overloaded, got {err:?}"
    );
    assert_eq!(err.overload_detail(), Some("queue_watermark"));
    assert!(err.retryable(), "watermark pressure is transient — clients should back off");

    // drain the backlog; depth falls to 0 ≤ low, clearing the latch
    for h in &backlog {
        let done = finish(h).expect("backlogged streams must still complete");
        assert_eq!(done.tokens.len(), 12);
    }
    let recovered = coord.submit(req()).unwrap();
    assert_eq!(recovered.tokens.len(), 12);

    let m = coord.metrics.lock().unwrap();
    assert!(m.watermark_rejections >= 1, "the rejection must be attributed: {}", m.summary());
    assert!(m.requests_overloaded >= 1);
    drop(m);
    assert_loads_drain(&coord);
}

/// The ISSUE's failover invariant, dispatch-side: kill one replica of
/// two mid-stream (restart budget zero) and every request that was
/// QUEUED on it completes on the survivor with tokens bit-identical to
/// a run where the fault never happened. Only the in-flight victim
/// fails, typed with the dead replica's index.
#[test]
fn killed_replica_queued_work_completes_on_survivors_bit_identical() {
    let mut rng = Rng::seed_from_u64(94);
    let prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let req = || Request { prompt: prompt.clone(), max_new: 12, ignore_eos: true, ..Default::default() };

    let clean_engine = EngineHandle::spawn(artifacts()).unwrap();
    let clean = Coordinator::start(clean_engine, ServingConfig::default()).unwrap();
    let reference = clean.submit(req()).unwrap().tokens;

    let engine0 = EngineHandle::spawn_replica(artifacts(), 0).unwrap();
    let engine1 = EngineHandle::spawn_replica_with(
        artifacts(),
        None,
        // call 30 is deep inside replica 1's FIRST stream (prefill ≈ 9
        // calls, each decode round well past one) — its other two
        // requests are still queued when it dies
        Some(FaultPlan::new().with(30, FaultKind::Panic)),
        1,
    )
    .unwrap();
    let coord = Coordinator::start_replicas(
        vec![engine0, engine1],
        ServingConfig {
            max_active_requests: 1,
            engine_restart_max: 0,
            ..Default::default()
        },
    )
    .unwrap();

    // identical committed sizes ⇒ dispatch alternates r0,r1,r0,r1,r0,r1
    let handles: Vec<SessionHandle> = (0..6).map(|_| coord.open(req()).unwrap()).collect();
    let mut completed = 0;
    let mut failed_on = Vec::new();
    for (i, h) in handles.iter().enumerate() {
        match finish(h) {
            Ok(done) => {
                completed += 1;
                assert_eq!(done.tokens, reference, "session {i}: failover stream diverged");
            }
            Err(RequestError::EngineFailed { replica, .. }) => failed_on.push(replica),
            Err(other) => panic!("session {i}: expected EngineFailed, got {other:?}"),
        }
    }
    // replica 1 held one in-flight stream (the casualty) and two queued
    // ones (the failovers); replica 0's three were never at risk
    assert_eq!(failed_on, vec![1], "exactly the in-flight stream on replica 1 may fail");
    assert_eq!(completed, 5);
    let m = coord.metrics.lock().unwrap();
    assert!(m.dispatch_failovers >= 2, "both queued requests must fail over: {}", m.summary());
    assert_eq!(m.replicas[1].deaths, 1);
    drop(m);
    assert_loads_drain(&coord);
}

/// Rolling restart: `drain_replica` takes one replica out, respawns its
/// engine (generation bump, cold caches) and rejoins it — while a
/// stream on the OTHER replica keeps decoding uninterrupted, and the
/// rejoined replica serves new work afterwards.
#[test]
fn drain_replica_rolls_one_replica_without_interrupting_its_peer() {
    let mut rng = Rng::seed_from_u64(95);
    let long_prompt = generate(Task::Gov, &mut rng, 128).prompt;
    let prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let (coord, engines) = start_set(2, ServingConfig::default());

    // occupy replica 0 (tie-break target) with a long-lived stream
    let pinned = coord
        .open(Request { prompt: long_prompt.clone(), max_new: 96, ignore_eos: true, ..Default::default() })
        .unwrap();

    // roll the idle replica 1: drains immediately, respawns, rejoins
    assert!(coord.drain_replica(1, Duration::from_secs(30)).unwrap());
    assert_eq!(coord.replica_generations(), vec![0, 1], "only replica 1 may bump");
    assert!(coord.drain_replica(7, Duration::from_secs(1)).is_err(), "bounds-checked");

    // the rejoined replica is back in the dispatch set: replica 0 is
    // still busy, so least-loaded sends new work to fresh replica 1
    let probe = coord
        .submit(Request { prompt: prompt.clone(), max_new: 8, ignore_eos: true, ..Default::default() })
        .unwrap();
    assert_eq!(probe.replica, 1, "the rejoined replica must serve again");

    // ...and the peer's stream was never interrupted
    let done = finish(&pinned).expect("the pinned stream must survive the roll");
    assert_eq!(done.tokens.len(), 96);
    assert_eq!(done.replica, 0);

    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.replicas[1].drains, 1, "the roll must be accounted: {}", m.summary());
    drop(m);
    assert_loads_drain(&coord);
    for e in &engines {
        common::assert_pool_drained(e);
    }
}
